"""Dev smoke: forward + loss + prefill/decode on every reduced arch (CPU)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import RunPolicy, decode_step, forward, init_params, loss_fn, prefill
from repro.models.cache import init_cache

B, S = 2, 32


def run(name):
    cfg = get_config(name).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, dtype=jnp.float32)
    policy = RunPolicy()
    if cfg.input_kind == "embeddings":
        tokens = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, aux = jax.jit(lambda p, t: forward(cfg, p, t, policy))(params, tokens)
    assert logits.shape == (B, S, cfg.vocab_size), logits.shape
    assert not np.any(np.isnan(np.asarray(logits, np.float32))), "NaN logits"
    loss, m = jax.jit(lambda p, b: loss_fn(cfg, p, b, policy))(
        params, {"tokens": tokens, "labels": labels})
    assert np.isfinite(float(loss))
    # prefill + one decode step
    lg, cache = jax.jit(lambda p, t: prefill(cfg, p, t, policy))(params, tokens)
    cache2 = init_cache(cfg, B, S + 8, tp=1, dtype=jnp.float32)
    lg2, cache2 = jax.jit(lambda p, t, pos, c: decode_step(cfg, p, t, pos, c, policy))(
        params,
        tokens[:, :1] if cfg.input_kind != "embeddings" else tokens[:, :1, :],
        jnp.zeros((B,), jnp.int32),
        cache2,
    )
    assert lg2.shape == (B, 1, cfg.vocab_size)
    print(f"  {name}: OK loss={float(loss):.3f}")


if __name__ == "__main__":
    names = sys.argv[1:] or list_archs()
    for n in names:
        run(n)
    print("ALL OK")
