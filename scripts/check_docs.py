"""Check that intra-repo markdown links resolve.

Scans every tracked *.md file for [text](target) links and verifies that
relative targets (after stripping any #anchor) exist on disk. External
schemes (http/https/mailto) and pure anchors are skipped. Exits non-zero
listing every broken link, so CI can gate on it.

    python scripts/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def md_files() -> list[Path]:
    return [p for p in REPO.rglob("*.md")
            if not any(part.startswith(".") or part in ("node_modules",)
                       for part in p.relative_to(REPO).parts)]


def check(path: Path) -> list[str]:
    broken = []
    text = path.read_text(encoding="utf-8")
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (REPO / rel.lstrip("/")) if rel.startswith("/") \
            else (path.parent / rel)
        if not resolved.exists():
            broken.append(f"{path.relative_to(REPO)}: broken link -> {target}")
    return broken


def main() -> int:
    broken = [b for p in md_files() for b in check(p)]
    for b in broken:
        print(b, file=sys.stderr)
    print(f"check_docs: {len(md_files())} markdown files, "
          f"{len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
