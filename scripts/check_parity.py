"""Golden charge-parity harness: the 66 fig3/fig11 configurations must stay
bit-identical across refactors of the memory runtime and the app front-end.

Runs every fig3 config (6 apps x explicit/managed/system at the AppSpec
"fig3" sizes) and every fig11 config (6 apps x 4 oversubscription ratios x
system/managed at 4 KB pages), snapshots *full-precision* phase times
(float hex) and per-phase + total traffic counters, and diffs them against
the committed fixture. Any modeled-charge drift — a reordered float
accumulation, a changed extent, a different eviction decision — fails with
the exact counters that moved.

    PYTHONPATH=src python scripts/check_parity.py            # verify (CI)
    PYTHONPATH=src python scripts/check_parity.py --write    # regenerate
    PYTHONPATH=src python scripts/check_parity.py --only fig3/hotspot

The fixture lives at tests/fixtures/parity.json; tests/test_parity.py pins
a representative subset in tier-1. Regenerating the fixture is a deliberate
act — only do it when a charge-model change is intended, and say so in the
commit.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.apps import APPS, charge_snapshot  # noqa: E402

KB = 1024
FIXTURE = Path(__file__).resolve().parent.parent / "tests" / "fixtures" / "parity.json"

FIG11_RATIOS = (1.2, 1.5, 2.0, 3.0)


def configs():
    """Yield (key, app_name, policy, kwargs) for all 66 parity configs."""
    for name, spec in APPS.items():
        for pol in ("explicit", "managed", "system"):
            yield f"fig3/{name}/{pol}", name, pol, dict(spec.sizes["fig3"])
    for name, spec in APPS.items():
        for ratio in FIG11_RATIOS:
            for pol in ("system", "managed"):
                yield (f"fig11/{name}/oversub{ratio}/{pol}", name, pol,
                       dict(spec.sizes["fig11"],
                            oversub_ratio=ratio, page_size=4 * KB))


def select_configs(only: str = "", policies=()):
    """Filter the parity configs by key prefix and/or policy subset —
    ``--policies system,managed`` lets a contributor re-verify a single
    ported backend without paying for the full 66-config run."""
    pols = set(policies)
    return [(k, n, p, kw) for k, n, p, kw in configs()
            if k.startswith(only) and (not pols or p in pols)]


def run_config(name: str, pol: str, kw: dict) -> dict:
    return charge_snapshot(APPS[name].run(pol, **kw))


def diff(key: str, got: dict, want: dict) -> list:
    lines = []
    for section in sorted(set(got) | set(want)):
        g, w = got.get(section, {}), want.get(section, {})
        if g == w:
            continue
        for k in sorted(set(g) | set(w)):
            if g.get(k) != w.get(k):
                lines.append(f"  {key} {section}.{k}: got={g.get(k)!r} "
                             f"want={w.get(k)!r}")
    return lines


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--write", action="store_true",
                    help="regenerate the fixture instead of verifying")
    ap.add_argument("--only", default="",
                    help="only run configs whose key starts with this prefix")
    ap.add_argument("--policies", default="",
                    help="comma-separated policy subset (e.g. system,managed)"
                         " — re-verify one backend quickly")
    args = ap.parse_args()

    policies = tuple(p.strip() for p in args.policies.split(",") if p.strip())
    todo = select_configs(args.only, policies)
    if not todo:
        print(f"check_parity: no configs match prefix {args.only!r} "
              f"policies {policies!r}", file=sys.stderr)
        return 2

    fixture = {}
    if not args.write:
        if not FIXTURE.exists():
            print(f"check_parity: missing fixture {FIXTURE} "
                  "(run with --write first)", file=sys.stderr)
            return 2
        fixture = json.loads(FIXTURE.read_text())

    t0 = time.time()
    out, broken = {}, []
    for key, name, pol, kw in todo:
        snap = run_config(name, pol, kw)
        out[key] = snap
        if not args.write:
            if key not in fixture:
                broken.append(f"  {key}: not in fixture (regenerate?)")
            else:
                broken.extend(diff(key, snap, fixture[key]))

    if args.write:
        if args.only or policies:  # subset regen must not drop the rest
            merged = json.loads(FIXTURE.read_text()) if FIXTURE.exists() else {}
            merged.update(out)
            out = merged
        FIXTURE.parent.mkdir(parents=True, exist_ok=True)
        FIXTURE.write_text(json.dumps(out, indent=1, sort_keys=True) + "\n")
        print(f"check_parity: wrote {len(out)} configs to "
              f"{FIXTURE} in {time.time() - t0:.1f}s")
        return 0

    status = "BIT-IDENTICAL" if not broken else "DRIFTED"
    print(f"check_parity: {len(todo)} configs in {time.time() - t0:.1f}s "
          f"-> {status}")
    for line in broken:
        print(line, file=sys.stderr)
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
