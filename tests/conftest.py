import os
import sys

# tests see ONE device (the dry-run sets its own 512-device flag in a
# subprocess); keep retracing costs down
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)


def pytest_sessionfinish(session, exitstatus):
    """MAX_SKIPS=<n>: fail an otherwise-green run when more than n tests
    skipped. CI sets this so coverage cannot silently erode — e.g. a
    dependency (hypothesis) failing to install turns its whole property
    suite into skips, which would otherwise still exit 0."""
    ceiling = os.environ.get("MAX_SKIPS")
    if ceiling is None or exitstatus != 0:
        return
    tr = session.config.pluginmanager.get_plugin("terminalreporter")
    if tr is None:
        return
    skipped = len(tr.stats.get("skipped", []))
    if skipped > int(ceiling):
        tr.write_line(
            f"MAX_SKIPS exceeded: {skipped} tests skipped > ceiling "
            f"{ceiling} — a dependency failed to install or a new skip "
            "crept in", red=True)
        session.exitstatus = 1
