import os
import sys

# tests see ONE device (the dry-run sets its own 512-device flag in a
# subprocess); keep retracing costs down
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
