"""MoE: routing exactness, capacity behavior, expert padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.layers import RunPolicy
from repro.models import moe as moe_mod


def _cfg(n_experts=8, top_k=2):
    cfg = get_config("olmoe-1b-7b").reduced()
    return dataclasses.replace(cfg, num_experts=n_experts, top_k=top_k)


def test_moe_matches_dense_routing_at_high_capacity():
    """With capacity >= T, dense-dispatch MoE == explicit per-token gather."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p = moe_mod.moe_init(cfg, key, jnp.float32, tp=1)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    pol = RunPolicy(moe_capacity_factor=64.0)  # no drops
    y, aux = moe_mod.moe_apply(cfg, p, x, pol, tp=1)

    # reference: per-token explicit computation
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    g, idx = jax.lax.top_k(probs, cfg.top_k)
    g = g / g.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for s in range(cfg.top_k):
            e = int(idx[t, s])
            h = jax.nn.silu(xt[t] @ p["w_gate"][e]) * (xt[t] @ p["w_up"][e])
            acc = acc + g[t, s] * (h @ p["w_down"][e])
        ref = ref.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=1e-4)
    assert float(aux) > 0


def test_expert_padding_never_routed():
    """granite: 40 experts padded to 48 — pads get -inf logits, zero traffic."""
    cfg = _cfg(n_experts=6, top_k=2)  # 6 pads to 8 at tp=8
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32, tp=8)
    assert p["router"].shape[1] == 8
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    xt = x.reshape(-1, cfg.d_model)
    logits = (xt @ p["router"]).astype(jnp.float32)
    pad = jnp.arange(8) >= 6
    logits = jnp.where(pad[None], moe_mod.NEG_INF, logits)
    _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    assert int(jnp.max(idx)) < 6
    # and apply() equals the tp=1 (unpadded) result
    p1 = moe_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32, tp=1)
    y8, _ = moe_mod.moe_apply(cfg, p, x, RunPolicy(), tp=8)
    y1, _ = moe_mod.moe_apply(cfg, p1, x, RunPolicy(), tp=1)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y1), atol=1e-5)


def test_capacity_drops_pass_through():
    """Over-capacity tokens are dropped (residual passes through unchanged)."""
    cfg = _cfg(n_experts=2, top_k=1)
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32, tp=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    tight = RunPolicy(moe_capacity_factor=0.25)
    loose = RunPolicy(moe_capacity_factor=64.0)
    y_t, _ = moe_mod.moe_apply(cfg, p, x, tight, tp=1)
    y_l, _ = moe_mod.moe_apply(cfg, p, x, loose, tp=1)
    # tight capacity zeroes some tokens' outputs
    zt = np.asarray(jnp.sum(jnp.abs(y_t), axis=-1))[0]
    zl = np.asarray(jnp.sum(jnp.abs(y_l), axis=-1))[0]
    assert (zt == 0).sum() > (zl == 0).sum()


def test_sorted_dispatch_matches_dense():
    """Beyond-paper sorted (scatter) dispatch == dense GShard dispatch at
    every capacity regime, including identical drop priority."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    cfg = _cfg(n_experts=8, top_k=2)
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32, tp=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    for cf in (64.0, 1.25, 0.5):
        yd, _ = moe_mod.moe_apply_dense(cfg, p, x, RunPolicy(moe_capacity_factor=cf))
        ys, _ = moe_mod.moe_apply_sorted(cfg, p, x, RunPolicy(moe_capacity_factor=cf))
        np.testing.assert_allclose(np.asarray(yd), np.asarray(ys), atol=2e-5)
