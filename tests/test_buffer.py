"""The typed buffer front-end: slice->extent resolution equals raw byte
math, um.staged() charges exactly what manual explicit copies charged, the
staging buffer honors the app page size, and the apps are grep-clean of raw
byte-range plumbing."""
import pathlib
import re

import numpy as np
import pytest

from repro.core import (
    Actor,
    BufferView,
    Tier,
    UMBuffer,
    UnifiedMemory,
    explicit_policy,
    system_policy,
)

KB = 1024
MB = 1024 * 1024

DTYPES = [np.int8, np.int16, np.int32, np.int64,
          np.float32, np.float64, np.complex64]


# --------------------------------------------------------------- resolution
def test_basic_slice_and_rows_resolution():
    um = UnifiedMemory()
    buf = um.array("m", (128, 64), np.float32, system_policy(4 * KB))
    row = 64 * 4
    assert (buf[3:17].lo, buf[3:17].hi) == (3 * row, 17 * row)
    assert (buf.rows(3, 17).lo, buf.rows(3, 17).hi) == (3 * row, 17 * row)
    assert (buf[:].lo, buf[:].hi) == (0, 128 * row)
    assert (buf[...].lo, buf[...].hi) == (0, 128 * row)
    assert (buf[5].lo, buf[5].hi) == (5 * row, 6 * row)
    assert (buf[-1].lo, buf[-1].hi) == (127 * row, 128 * row)
    assert buf.byterange(100, 200).nbytes == 100
    with pytest.raises(ValueError):
        buf[::2]
    with pytest.raises(TypeError):
        buf[1, 2]
    with pytest.raises(IndexError):
        buf[128]


def test_1d_slices_are_element_granular():
    um = UnifiedMemory()
    buf = um.array("v", (1 << 12,), np.complex64, system_policy(64 * KB))
    v = buf[100:900]
    assert (v.lo, v.hi) == (100 * 8, 900 * 8)
    assert v.page_extent() == buf.alloc.table.page_range(800, 7200)


# ------------------------------------------------- hypothesis property tests
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=120, deadline=None)
@given(data=st.data())
def test_slice_to_extent_equals_raw_byte_math(data):
    """Arbitrary dtype / offset / step-1 slice: the view's byte range and
    resolved page extent equal the hand-written byte math exactly."""
    dtype = np.dtype(data.draw(st.sampled_from(DTYPES)))
    if data.draw(st.booleans()):
        shape = (data.draw(st.integers(1, 4096)),)
    else:
        shape = (data.draw(st.integers(1, 512)), data.draw(st.integers(1, 64)))
    page = data.draw(st.sampled_from([4 * KB, 64 * KB]))
    um = UnifiedMemory()
    buf = um.array("b", shape, dtype, system_policy(page))
    row_bytes = (int(np.prod(shape[1:], dtype=np.int64)) * dtype.itemsize
                 if len(shape) > 1 else dtype.itemsize)
    n0 = shape[0]
    lo = data.draw(st.integers(-n0 - 2, n0 + 2))
    hi = data.draw(st.integers(-n0 - 2, n0 + 2))
    v = buf[lo:hi]
    elo, ehi, _ = slice(lo, hi).indices(n0)
    ehi = max(elo, ehi)
    assert (v.lo, v.hi) == (elo * row_bytes, ehi * row_bytes)
    assert v.resolve(Actor.GPU) == (buf.alloc, elo * row_bytes, ehi * row_bytes)
    if v.hi > v.lo:
        # the page extent kernel() will operate on == raw page_range math
        assert v.page_extent() == (
            (elo * row_bytes) // page,
            -(-(ehi * row_bytes) // page))


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_2d_row_bands_equal_raw_byte_math(data):
    rows = data.draw(st.integers(1, 512))
    cols = data.draw(st.integers(1, 128))
    dtype = np.dtype(data.draw(st.sampled_from(DTYPES)))
    lo = data.draw(st.integers(0, rows))
    hi = data.draw(st.integers(lo, rows))
    um = UnifiedMemory()
    buf = um.array("b", (rows, cols), dtype, system_policy(4 * KB))
    band = buf.rows(lo, hi)
    row_bytes = cols * dtype.itemsize
    assert (band.lo, band.hi) == (lo * row_bytes, hi * row_bytes)
    assert band.nbytes == (hi - lo) * row_bytes


# -------------------------------------------------------- staging / staged()
def _manual_explicit(page_size: int) -> UnifiedMemory:
    """The pre-buffer-API explicit pattern: hand-allocated staging pair,
    hand-placed h2d/d2h copies."""
    um = UnifiedMemory(staging_page_size=page_size)
    nbytes = 640 * KB
    dev = um.alloc("x", nbytes, explicit_policy())
    host = um.alloc("x__host", nbytes,
                    system_policy(page_size, auto_migrate=False))
    with um.phase("cpu_init"):
        um.kernel(writes=[(host, 0, nbytes)], actor=Actor.CPU, name="init")
    with um.phase("h2d"):
        um.copy(dev, 0, nbytes, "h2d")
    with um.phase("compute"):
        um.kernel(reads=[(dev, 0, nbytes)], writes=[(dev, 0, nbytes)],
                  flops=1e6, actor=Actor.GPU, name="k")
    with um.phase("d2h"):
        um.copy(dev, 0, nbytes, "d2h")
    with um.phase("dealloc"):
        um.free(dev)
        um.free(host)
    return um


def _staged_explicit(page_size: int) -> UnifiedMemory:
    """The same app on the buffer front-end: from_host + staged() + launch."""
    um = UnifiedMemory(staging_page_size=page_size)
    buf = um.from_host("x", (640 * KB,), np.uint8, explicit_policy())
    with um.phase("cpu_init"):
        um.launch("init", writes=[buf[:]], actor=Actor.CPU)
    with um.staged(h2d=[buf], d2h=[buf]):
        with um.phase("compute"):
            um.launch("k", reads=[buf[:]], writes=[buf[:]],
                      flops=1e6, actor=Actor.GPU)
    with um.phase("dealloc"):
        um.free_live()
    return um


@pytest.mark.parametrize("page_size", [4 * KB, 64 * KB])
def test_staged_charges_match_manual_copies(page_size):
    """um.staged() must charge the exact h2d/d2h the manual copies did —
    same phases, same order, bit-identical times and traffic."""
    manual, staged = _manual_explicit(page_size), _staged_explicit(page_size)
    assert dict(manual.prof.phase_times) == dict(staged.prof.phase_times)
    assert ({k: vars(v) for k, v in manual.prof.phase_traffic.items()}
            == {k: vars(v) for k, v in staged.prof.phase_traffic.items()})


def test_staged_is_noop_for_paged_policies():
    um = UnifiedMemory()
    buf = um.from_host("x", (256 * KB,), np.uint8, system_policy(64 * KB))
    assert buf.host is None  # no staging pair outside the explicit policy
    with um.staged(h2d=[buf], d2h=[buf]):
        pass
    assert "h2d" not in um.prof.phase_times
    assert "d2h" not in um.prof.phase_times


def test_from_host_staging_honors_app_page_size():
    """Regression for the explicit staging buffer ignoring the app's
    page_size (it used to hard-wire the 64 KB system default)."""
    um = UnifiedMemory(staging_page_size=4 * KB)
    buf = um.from_host("x", (512 * KB,), np.uint8, explicit_policy())
    assert buf.host is not None
    assert buf.host.policy.page_size == 4 * KB
    assert buf.host.table.page_size == 4 * KB
    # and make_um threads the app page size through
    from repro.apps.common import make_um
    um2, pol = make_um("explicit", page_size=4 * KB)
    assert um2.staging_page_size == 4 * KB


def test_launch_routes_cpu_actor_to_staging_side():
    um = UnifiedMemory()
    buf = um.from_host("x", (256 * KB,), np.uint8, explicit_policy())
    um.launch("init", writes=[buf[:]], actor=Actor.CPU)
    # CPU write landed in the staging table, not the device allocation
    assert buf.host.table.resident_bytes(Tier.HOST) == 256 * KB
    assert buf.alloc.table is None  # device side is explicit (no PTEs)
    t = um.launch("k", reads=[buf[:]], actor=Actor.GPU)
    assert t > 0
    assert um.prof.traffic().device_local == 256 * KB


def test_launch_default_label_derived_from_buffer_names():
    """Regression: unnamed launches used to all share the "kernel" label,
    making per-kernel profiler reports ambiguous. The default now derives
    from the operand buffer names (reads->writes)."""
    um = UnifiedMemory()
    a = um.array("temp", (64 * KB,), np.uint8, system_policy(4 * KB))
    b = um.array("power", (64 * KB,), np.uint8, system_policy(4 * KB))
    c = um.array("temp_out", (64 * KB,), np.uint8, system_policy(4 * KB))
    um.launch(writes=[a[:], b[:]], actor=Actor.CPU)
    um.launch(reads=[a[:], b[:]], writes=[c[:]], actor=Actor.GPU)
    um.launch(reads=[c[:]], actor=Actor.GPU)
    kt = um.prof.kernel_times
    assert set(kt) == {"temp+power", "temp+power->temp_out", "temp_out"}
    assert "kernel" not in kt  # two different unnamed kernels never collide
    assert um.prof.kernel_counts["temp+power->temp_out"] == 1
    # an explicit name still wins, and repeated names aggregate
    um.launch("sweep", reads=[a[:]], actor=Actor.GPU)
    um.launch("sweep", reads=[a[:]], actor=Actor.GPU)
    assert um.prof.kernel_counts["sweep"] == 2
    # operand-free launches keep the legacy fallback label
    um.launch(actor=Actor.GPU)
    assert "kernel" in um.prof.kernel_times
    # report() surfaces the per-kernel breakdown
    rep = um.report()
    assert rep["kernel_counts"]["sweep"] == 2


def test_free_live_keeps_reserved_names():
    um = UnifiedMemory()
    um.alloc("__ballast__", 1 * MB, explicit_policy())
    buf = um.from_host("x", (64 * KB,), np.uint8, explicit_policy())
    um.free_live()
    assert buf.alloc.freed and buf.host.freed
    assert not um.allocs["__ballast__"].freed


# ---------------------------------------------------------- sparse BFS mode
def test_frontier_views_coalesce_touched_pages():
    from repro.apps.bfs import _frontier_views
    um = UnifiedMemory()
    edges = um.array("e", (1 << 12,), np.int32, system_policy(4 * KB))
    per_page = 4 * KB // 4
    # deg=4: nodes 0..2 and 200 touch page 0, node 300 touches page 1 ->
    # one coalesced run over pages [0, 2)
    views = _frontier_views(edges, np.array([0, 1, 2, 200, 300]), 4, 4 * KB)
    assert [(v.lo, v.hi) for v in views] == [(0, 2 * per_page * 4)]
    # distant nodes stay separate runs
    views = _frontier_views(edges, np.array([0, 1000]), 4, 4 * KB)
    assert [(v.lo, v.hi) for v in views] == [(0, 4 * KB), (3 * 4 * KB, 4 * 4 * KB)]
    # a block spanning 3+ pages keeps its interior pages (regression)
    views = _frontier_views(edges, np.array([0]), 3 * per_page, 4 * KB)
    assert [(v.lo, v.hi) for v in views] == [(0, 3 * 4 * KB)]


@pytest.mark.parametrize("policy", ["system", "managed", "explicit"])
def test_bfs_sparse_access_same_math_different_extents(policy):
    from repro.apps import run_bfs
    kw = dict(n_nodes=1 << 12, page_size=4 * KB)
    dense = run_bfs(policy, sparse_access=False, **kw)
    sparse = run_bfs(policy, sparse_access=True, **kw)
    assert sparse.checksum == dense.checksum  # the BFS itself is unchanged
    assert sparse.extra["sparse"] and not dense.extra["sparse"]
    assert sparse.extra["levels"] == dense.extra["levels"]
    # extent resolution actually changed what the kernels read, under
    # every policy — frontier-exact extents != the hand-estimated prefix
    assert (sparse.report["traffic_total"]
            != dense.report["traffic_total"]), policy


# ------------------------------------------------------- grep-clean apps
def test_apps_contain_no_raw_byte_range_plumbing():
    """Acceptance: no app hand-writes (alloc, lo, hi) tuples, raw kernel()
    calls, manual copies, or explicit-policy staging branches."""
    import repro.apps
    app_dir = pathlib.Path(repro.apps.__file__).parent
    for f in sorted(app_dir.glob("*.py")):
        src = f.read_text()
        assert "um.kernel(" not in src, f"{f.name}: raw kernel() call"
        assert "um.copy(" not in src, f"{f.name}: manual cudaMemcpy"
        assert "explicit_pair" not in src, f"{f.name}: staging pair helper"
        if f.name != "common.py":  # the policy factory itself may dispatch
            assert not re.search(r"policy_kind\s*==", src), \
                f"{f.name}: policy-kind branch"
            assert "explicit_policy" not in src, f"{f.name}: policy construction"
