"""Unit tests for the extent-based BlockTable APIs (tentpole surface)."""
import numpy as np

from repro.core import Actor, BlockTable, Tier, UnifiedMemory, system_policy

KB = 1024
MB = 1024 * 1024


def test_range_bytes_and_tail_page():
    t = BlockTable("x", 10 * KB, 4 * KB)  # 3 pages, tail = 2 KB
    assert t.num_pages == 3
    assert t.tail_bytes == 2 * KB
    assert t.range_bytes(0, 3) == 10 * KB
    assert t.range_bytes(0, 2) == 8 * KB
    assert t.range_bytes(2, 3) == 2 * KB
    assert t.range_bytes(1, 1) == 0
    assert t.page_bytes_slice(0, 3).tolist() == [4 * KB, 4 * KB, 2 * KB]
    assert t.page_bytes_slice(0, 2).tolist() == [4 * KB, 4 * KB]
    # matches the scattered-index variant
    assert t.page_bytes(np.arange(3)).sum() == 10 * KB


def test_cached_tier_counters_follow_mutations():
    t = BlockTable("x", 64 * KB, 4 * KB)  # 16 full pages
    assert t.resident_bytes(Tier.UNMAPPED) == 64 * KB
    mask = np.zeros(16, bool)
    mask[:4] = True
    dh, dd = t.map_mask(0, 16, mask, Tier.HOST)
    assert (dh, dd) == (16 * KB, 0)
    assert t.resident_bytes(Tier.HOST) == 16 * KB
    assert t.resident_pages(Tier.HOST) == 4
    dh, dd = t.move_pages(np.arange(4), Tier.DEVICE)
    assert (dh, dd) == (-16 * KB, 16 * KB)
    assert t.resident_bytes(Tier.DEVICE) == 16 * KB
    assert t.resident_bytes(Tier.HOST) == 0
    assert abs(t.mapped_fraction() - 4 / 16) < 1e-12
    # counters match a full rescan
    for tier in (Tier.UNMAPPED, Tier.HOST, Tier.DEVICE):
        assert t.resident_pages(tier) == len(t.pages_in(tier))


def test_move_pages_scattered_vs_extent_equivalent():
    t1 = BlockTable("a", 64 * KB, 4 * KB)
    t2 = BlockTable("b", 64 * KB, 4 * KB)
    for t in (t1, t2):
        t.map_mask(0, 16, np.ones(16, bool), Tier.HOST)
    t1.move_pages(np.arange(4, 12), Tier.DEVICE)  # contiguous -> extent path
    t2.move_pages(np.array([4, 6, 8, 10, 5, 7, 9, 11]), Tier.DEVICE)  # scattered
    assert (t1.tier == t2.tier).all()
    assert t1.resident_bytes(Tier.DEVICE) == t2.resident_bytes(Tier.DEVICE) == 32 * KB


def test_tier_runs_interval_view():
    t = BlockTable("x", 64 * KB, 4 * KB)
    t.map_mask(0, 16, np.ones(16, bool), Tier.HOST)
    t.move_pages(np.arange(4, 8), Tier.DEVICE)
    starts, ends, tiers = t.tier_runs()
    assert starts.tolist() == [0, 4, 8]
    assert ends.tolist() == [4, 8, 16]
    assert tiers.tolist() == [int(Tier.HOST), int(Tier.DEVICE), int(Tier.HOST)]
    # windowed view
    starts, ends, tiers = t.tier_runs(6, 10)
    assert starts.tolist() == [6, 8]
    assert ends.tolist() == [8, 10]


def test_touch_range_sets_epoch_and_dirty():
    t = BlockTable("x", 64 * KB, 4 * KB)
    t.touch_range(2, 6, epoch=7, write=False)
    assert (t.last_access_epoch[2:6] == 7).all()
    assert not t.dirty.any()
    t.touch_range(4, 8, epoch=9, write=True)
    assert t.dirty[4:8].all() and not t.dirty[:4].any()


def test_clipped_extent_bytes_interior():
    """Exact-page boundaries and interior clips on full-size pages."""
    t = BlockTable("x", 64 * KB, 4 * KB)  # 16 full pages, no partial tail
    # exact-page boundaries: no clip
    assert t.clipped_extent_bytes(0, 16, 0, 64 * KB) == 64 * KB
    assert t.clipped_extent_bytes(2, 5, 8 * KB, 20 * KB) == 12 * KB
    # head clip only
    assert t.clipped_extent_bytes(0, 2, 1 * KB, 8 * KB) == 7 * KB
    # tail clip only
    assert t.clipped_extent_bytes(0, 2, 0, 7 * KB) == 7 * KB
    # both clips, single-page extent
    assert t.clipped_extent_bytes(3, 4, 13 * KB, 15 * KB) == 2 * KB
    # sub-run of a larger extent: only its own boundary is clipped
    assert t.clipped_extent_bytes(1, 3, 2 * KB, 30 * KB) == 8 * KB


def test_clipped_extent_bytes_tail_page_quirk():
    """The historical dense math applies the full-page overhang clip to the
    *partial* tail page, under-counting it by (page_size - tail_bytes) —
    pinned by the golden parity fixture, so the helper must reproduce it."""
    t = BlockTable("x", 10 * KB, 4 * KB)  # 3 pages, tail = 2 KB
    # extent ending exactly at nbytes: the tail page contributes
    # tail_bytes - (page_size - tail_bytes) = 0 KB, not 2 KB
    assert t.clipped_extent_bytes(0, 3, 0, 10 * KB) == 8 * KB
    # single-page extent on the tail page alone
    assert t.clipped_extent_bytes(2, 3, 8 * KB, 10 * KB) == 0
    # stopping mid-tail goes negative (dense pb[-1] did too)
    assert t.clipped_extent_bytes(2, 3, 8 * KB, 9 * KB) == -1 * KB
    # extents not touching the tail page are unaffected
    assert t.clipped_extent_bytes(0, 2, 0, 8 * KB) == 8 * KB


def test_tier_runs_vals_are_read_only():
    """Mutating tier state through the run view would bypass the cached
    residency counters — the returned tiers array must refuse writes."""
    t = BlockTable("x", 64 * KB, 4 * KB)
    t.map_mask(0, 16, np.ones(16, bool), Tier.HOST)
    _, _, v = t.tier_runs()
    with np.testing.assert_raises(ValueError):
        v[0] = int(Tier.DEVICE)
    assert t.resident_bytes(Tier.HOST) == 64 * KB


def test_runs_of_and_unmapped_stats():
    t = BlockTable("x", 64 * KB, 4 * KB)
    t.map_mask(0, 16, np.ones(16, bool), Tier.HOST)
    t.move_pages(np.arange(4, 8), Tier.DEVICE)
    s, e = t.runs_of(Tier.HOST)
    assert list(zip(s.tolist(), e.tolist())) == [(0, 4), (8, 16)]
    s, e = t.runs_of(Tier.DEVICE, 0, 6)
    assert list(zip(s.tolist(), e.tolist())) == [(4, 6)]
    assert t.unmapped_stats(0, 16) == (0, 0)
    t2 = BlockTable("y", 64 * KB, 4 * KB)
    t2.map_mask(0, 16, np.arange(16) < 4, Tier.HOST)
    assert t2.unmapped_stats(0, 16) == (12, 48 * KB)
    assert t2.unmapped_stats(0, 4) == (0, 0)


def test_move_runs_accounting_and_counter_reset():
    t = BlockTable("x", 64 * KB, 4 * KB)
    t.map_mask(0, 16, np.ones(16, bool), Tier.HOST)
    t._gpu_counter.set_range(0, 16, 99)
    dh, dd = t.move_runs([2, 10], [5, 12], Tier.DEVICE)
    assert (dh, dd) == (-20 * KB, 20 * KB)
    assert t.resident_bytes(Tier.DEVICE) == 20 * KB
    assert (t.gpu_counter[2:5] == 0).all() and (t.gpu_counter[10:12] == 0).all()
    assert (t.gpu_counter[:2] == 99).all()
    # cached counters agree with the run-structure recount
    pages, nbytes = t.recount()
    assert (pages == t._tier_pages).all() and (nbytes == t._tier_bytes).all()


def test_metadata_stays_o_fragmentation():
    """A 4 GiB table at 4 KB pages (1M PTEs) carries only O(runs) metadata."""
    t = BlockTable("x", 4 * 1024 * MB, 4 * KB)
    assert t.num_pages == 1024 * 1024
    base = t.metadata_nbytes()
    assert base < 512  # four single-run maps
    t.map_unmapped(0, t.num_pages, Tier.HOST)
    t.move_extent(1000, 2000, Tier.DEVICE)
    assert t.metadata_nbytes() < base + 256


def test_kernel_epoch_batching_in_paged_kv_touch():
    """PagedKVCache._touch batches a sequence's pages into ONE kernel call."""
    from repro.serve.paged import PagedKVCache

    class _Cfg:
        head_dim = 4
        num_layers = 2

    class _Layout:
        n_kv_eff = 1

    um = UnifiedMemory()
    kv = PagedKVCache(_Cfg(), _Layout(), max_seqs=2, max_len=64,
                      page_size=8, um=um)
    sid = kv.new_seq()
    kv.lengths[sid] = 40  # 5 pages
    kv.alloc_range(sid, 0, 40)
    e0 = um.epoch
    kv._touch(sid)
    assert um.epoch == e0 + 1  # one kernel op, not one per page
    tbl = kv.alloc.table
    assert tbl.resident_bytes(Tier.DEVICE) + tbl.resident_bytes(Tier.HOST) \
        == 5 * kv.page_bytes
