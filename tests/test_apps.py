"""The six paper apps: all three memory-management versions run through the
one buffer-centric code path and the paper's qualitative claims hold on the
modeled Grace Hopper. Sizes come from each AppSpec's "small" preset."""
import pytest

from repro.apps import APPS, run_app, run_hotspot, run_qsim, run_srad

SMALL = {name: dict(spec.sizes["small"]) for name, spec in APPS.items()}


@pytest.mark.parametrize("app", sorted(APPS))
@pytest.mark.parametrize("policy", ["explicit", "managed", "system"])
def test_app_runs_all_policies(app, policy):
    r = run_app(app, policy, preset="small")
    assert r.total > 0
    assert r.checksum == run_app(app, "explicit", preset="small").checksum \
        or policy == "explicit"  # same math regardless of memory policy


@pytest.mark.parametrize(
    "app", [n for n, s in APPS.items() if s.init_actor == "cpu"])
def test_cpu_init_apps_prefer_system_memory(app):
    """Paper Fig. 3 class 1: system >= managed for CPU-initialized apps."""
    t = {p: run_app(app, p, preset="small").time_excluding_cpu_init()
         for p in ("managed", "system")}
    assert t["system"] < t["managed"]


def test_gpu_init_apps_prefer_managed_memory():
    """Paper Fig. 3 class 2 / §5.1.2: GPU-side init (srad) favors managed
    (GPU first-touch of system pages round-trips to the CPU for PTEs)."""
    kw = dict(SMALL["srad"], iters=2)  # init-dominated regime
    t = {p: run_srad(p, **kw).time_excluding_cpu_init()
         for p in ("managed", "system")}
    assert t["managed"] < t["system"]


def test_srad_migration_warmup_crossover():
    """Paper Fig. 10: system-memory iteration time decreases as access-counter
    migrations move the working set to HBM; late iterations beat managed."""
    kw = dict(rows=512, cols=512, iters=12)
    rs = run_srad("system", **kw)
    rm = run_srad("managed", **kw)
    per_s = [d["seconds"] for d in rs.extra["per_iter"]]
    per_m = [d["seconds"] for d in rm.extra["per_iter"]]
    assert per_s[0] > per_s[-1]  # warm-up
    assert per_s[-1] <= per_m[0]  # late system beats managed's fault iteration
    # remote traffic decays to ~zero once the working set is resident
    h2d = [d["link_h2d"] for d in rs.extra["per_iter"]]
    assert h2d[-1] < h2d[1] / 10 or h2d[-1] == 0


def test_oversubscription_system_graceful_managed_thrashes():
    """Paper Fig. 11: at >1x oversubscription system memory degrades gracefully
    while managed pays eviction+migration storms."""
    kw = dict(rows=512, cols=512, iters=4)
    speedups = {}
    for ratio in (1.5, 3.0):
        ts = run_hotspot("system", oversub_ratio=ratio, **kw).time_excluding_cpu_init()
        tm = run_hotspot("managed", oversub_ratio=ratio, **kw).time_excluding_cpu_init()
        speedups[ratio] = tm / ts
    assert speedups[1.5] > 1.0
    assert speedups[3.0] >= speedups[1.5] * 0.9  # non-collapsing with pressure


def test_qiskit_prefetch_rescues_managed_oversubscription():
    """Paper Fig. 12/13: explicit prefetch restores managed-memory throughput
    under (simulated) oversubscription."""
    kw = dict(n_qubits=14, depth=2, oversub_ratio=1.3)
    slow = run_qsim("managed", **kw).phase_times["compute"]
    fast = run_qsim("managed", use_prefetch=True, **kw).phase_times["compute"]
    assert fast < slow


def test_page_size_alloc_dealloc():
    """Paper Fig. 6: 64KB pages cut alloc+dealloc cost vs 4KB by >4.6x."""
    KB = 1024
    t = {}
    for ps in (4 * KB, 64 * KB):
        r = run_hotspot("system", page_size=ps, **SMALL["hotspot"])
        t[ps] = r.phase_times["alloc"] + r.phase_times["dealloc"]
    assert t[4 * KB] / t[64 * KB] > 4.6
