"""Trace capture/replay: record -> replay round-trip identity.

A recorded app stream must replay to bit-identical charges (phase times as
float hex, traffic counters as ints) — with no overrides against the very
run that produced it, and with ``policy=`` overrides against a native run
of the same app under that backend (valid for the directly-CPU-accessible
backends, whose op stream is policy-independent)."""
import pytest

from repro.apps import APPS, charge_snapshot
from repro.core.trace import record, record_app, replay


def _fingerprint(um) -> dict:
    """The charge_snapshot sections, computed from a replayed runtime."""
    rep = um.report()
    return {
        "phase_times": {k: float(v).hex()
                        for k, v in sorted(um.prof.phase_times.items())},
        "traffic_total": {k: int(v)
                          for k, v in sorted(rep["traffic_total"].items())},
        "traffic_phases": {ph: {k: int(v) for k, v in sorted(tr.items())}
                           for ph, tr in sorted(rep["traffic"].items())},
    }


def _assert_same(got: dict, want: dict) -> None:
    for section in want:
        assert got[section] == want[section], f"{section} drifted in replay"


@pytest.mark.parametrize("app,policy", [
    ("srad", "system"),       # GPU-init regular, batched inner loop
    ("bfs", "managed"),       # CPU-init graph app, fault/migration path
])
def test_record_replay_round_trip(app, policy, tmp_path):
    path = tmp_path / f"{app}.trace"
    kw = dict(APPS[app].sizes["small"])
    native = record_app(app, policy, path, **kw)
    um = replay(path)
    _assert_same(_fingerprint(um), charge_snapshot(native))


def test_replay_two_policy_backends(tmp_path):
    """One recorded srad stream re-charges bit-identically under two
    backends: its native system policy and an mi300a_unified override."""
    path = tmp_path / "srad.trace"
    kw = dict(APPS["srad"].sizes["small"])
    native_sys = record_app("srad", "system", path, **kw)
    _assert_same(_fingerprint(replay(path)), charge_snapshot(native_sys))
    native_mi = APPS["srad"].run("mi300a_unified", **kw)
    um = replay(path, policy="mi300a_unified")
    _assert_same(_fingerprint(um), charge_snapshot(native_mi))


def test_record_gzip_round_trip(tmp_path):
    path = tmp_path / "hotspot.trace.gz"
    kw = dict(APPS["hotspot"].sizes["small"])
    native = record_app("hotspot", "system", path, **kw)
    _assert_same(_fingerprint(replay(path)), charge_snapshot(native))


def test_record_with_oversub_ballast(tmp_path):
    """The oversubscription ballast predates the recorder attach (it is
    allocated before the app hook fires): attach re-emits it, so replay
    rebuilds the squeezed device capacity too."""
    path = tmp_path / "srad_oversub.trace"
    kw = dict(APPS["srad"].sizes["small"], oversub_ratio=2.0,
              page_size=4 * 1024)
    native = record_app("srad", "managed", path, **kw)
    um = replay(path)
    _assert_same(_fingerprint(um), charge_snapshot(native))
    assert "__ballast__" in um.allocs


def test_recorder_detaches_on_close(tmp_path):
    from repro.core import Actor, UnifiedMemory, system_policy

    um = UnifiedMemory()
    a = um.alloc("x", 64 * 1024, system_policy(4 * 1024))
    with record(um, tmp_path / "t.trace"):
        um.kernel(writes=[(a, 0, 64 * 1024)], actor=Actor.CPU, name="w")
    assert um._trace is None
    um.kernel(reads=[(a, 0, 64 * 1024)], actor=Actor.GPU, name="r")  # silent
    um2 = replay(tmp_path / "t.trace")
    assert "r" not in um2.prof.kernel_counts  # post-close op not recorded
    assert um2.prof.kernel_counts["w"] == 1
