"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.paged_attention import paged_attention, paged_attention_ref
from repro.kernels.qv_gate import apply_two_qubit_gate, apply_two_qubit_gate_ref
from repro.kernels.stencil5 import stencil5, stencil5_ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 256, 8, 2, 64),
    (1, 512, 4, 4, 128),
    (2, 128, 16, 1, 64),
    (1, 256, 6, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention(B, S, H, Hkv, D, dtype, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    o = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                        interpret=True)
    r = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("B,H,Hkv,D,P,PS,NP", [
    (2, 8, 2, 64, 16, 16, 4),
    (3, 4, 4, 128, 32, 8, 6),
    (1, 16, 1, 64, 8, 32, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(B, H, Hkv, D, P, PS, NP, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (P, PS, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (P, PS, Hkv, D), dtype)
    pt = jax.random.permutation(ks[3], P)[:B * NP].reshape(B, NP).astype(jnp.int32)
    lengths = jnp.asarray([NP * PS - 3] + [max(1, (NP - 1) * PS)] * (B - 1),
                          jnp.int32)[:B]
    o = paged_attention(q, kp, vp, pt, lengths, interpret=True)
    r = paged_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("n,q1,q2", [(10, 0, 1), (12, 3, 9), (12, 11, 2), (11, 7, 6)])
def test_qv_gate(n, q1, q2):
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    st = jax.random.normal(k1, (2 ** n,)) + 1j * jax.random.normal(k2, (2 ** n,))
    st = (st / jnp.linalg.norm(st)).astype(jnp.complex64)
    g = jax.random.normal(k1, (4, 4)) + 1j * jax.random.normal(k2, (4, 4))
    u, _ = jnp.linalg.qr(g)
    u = u.astype(jnp.complex64)
    o = apply_two_qubit_gate(st, u, q1, q2, n, interpret=True)
    r = apply_two_qubit_gate_ref(st, u, q1, q2, n)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-5)
    assert abs(float(jnp.linalg.norm(o)) - 1.0) < 1e-5  # unitarity


@pytest.mark.parametrize("H,W,th", [(256, 128, 64), (128, 256, 128), (512, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_stencil5(H, W, th, dtype):
    g = jax.random.normal(jax.random.PRNGKey(3), (H, W), dtype)
    o = stencil5(g, 0.1, tile_h=th, interpret=True)
    r = stencil5_ref(g, 0.1)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-6)


def test_flash_matches_model_blocked_path():
    """The Pallas kernel and the model's pure-JAX blocked path agree."""
    from repro.models.attention import _blocked_causal
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, S, N, P, D = 1, 256, 2, 3, 32
    q = jax.random.normal(ks[0], (B, S, N, P, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, N, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, N, D), jnp.float32)
    o_model = _blocked_causal(q, k, v, 64, 64, 0).reshape(B, S, N * P, D)
    o_kernel = flash_attention(q.reshape(B, S, N * P, D), k, v,
                               block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel), atol=2e-5)
