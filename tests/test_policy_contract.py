"""Backend-API tier-1 coverage: the policy contract over every registered
backend, the registry seam itself, and the grep-enforced absence of
policy-kind string branches outside the policy module."""
from __future__ import annotations

import pathlib
import re

import pytest

import repro.core
from repro.core import (
    GRACE_HOPPER,
    MI300A,
    Actor,
    MemPolicy,
    OutOfDeviceMemory,
    Tier,
    UnifiedMemory,
    available_hardware,
    available_policies,
    get_hardware,
    make_policy,
    register_policy,
)
from repro.core.registry import _POLICIES

from policy_contract import CONTRACTS

KB = 1024


# ------------------------------------------------------------- the contract
@pytest.mark.parametrize("contract", CONTRACTS, ids=lambda c: c.__name__)
@pytest.mark.parametrize("name", available_policies())
def test_policy_contract(name, contract):
    contract(make_policy(name, page_size=4 * KB))


def test_contract_covers_mi300a():
    # the suite must pick up newly registered backends automatically
    assert "mi300a_unified" in available_policies()


# --------------------------------------------------------------- registry
def test_registry_roundtrip_and_knob_filtering():
    pol = make_policy("system", page_size=4 * KB, threshold=32,
                      speculative_prefetch=9)  # not a system knob: filtered
    assert pol.kind == "system"
    assert pol.page_size == 4 * KB
    assert pol.counter_threshold == 32
    man = make_policy("managed", page_size=4 * KB, speculative_prefetch=9,
                      threshold=32)  # threshold is not a managed knob
    assert man.speculative_prefetch == 9
    assert man.counter_threshold == 256  # untouched default
    with pytest.raises(KeyError, match="unknown memory policy"):
        make_policy("does-not-exist")
    # capability flags: only the explicit backend is table-less
    assert not make_policy("explicit").paged
    assert all(make_policy(n, page_size=4 * KB).paged
               for n in available_policies() if n != "explicit")


def test_register_policy_extends_the_seam():
    class NullPolicy(MemPolicy):
        kind = "null_test"

        def on_first_touch(self, um, a, p0, p1, actor, n_unmapped, need):
            return actor.home_tier

    register_policy("null_test", lambda **kw: NullPolicy())
    try:
        assert "null_test" in available_policies()
        um = UnifiedMemory()
        a = um.alloc("x", 64 * KB, make_policy("null_test"))
        um.kernel(writes=[(a, 0, 64 * KB)], actor=Actor.CPU)
        assert a.table.resident_bytes(Tier.HOST) == 64 * KB
    finally:
        _POLICIES.pop("null_test", None)


def test_hardware_registry():
    assert {"grace-hopper", "mi300a", "tpu-v5e"} <= set(available_hardware())
    assert get_hardware("mi300a") is MI300A
    assert get_hardware(None) is GRACE_HOPPER
    assert get_hardware(MI300A) is MI300A
    with pytest.raises(KeyError, match="unknown hardware"):
        get_hardware("does-not-exist")


# ----------------------------------------------------------- MI300A backend
def test_mi300a_unified_no_migration_uniform_cost():
    um = UnifiedMemory(hw=MI300A)
    pol = make_policy("mi300a_unified", page_size=4 * KB)
    a = um.alloc("pool", 1 << 20, pol)
    um.kernel(writes=[(a, 0, 1 << 20)], actor=Actor.CPU, name="init")
    # first touch maps into the single physical pool, regardless of actor
    assert a.table.resident_bytes(Tier.DEVICE) == 1 << 20
    t_gpu = um.kernel(reads=[(a, 0, 1 << 20)], actor=Actor.GPU, name="g")
    t_cpu = um.kernel(reads=[(a, 0, 1 << 20)], actor=Actor.CPU, name="c")
    # uniform-latency pool: both actors stream the same bytes in the same time
    assert t_gpu == pytest.approx(t_cpu, rel=1e-12)
    um.sync()
    # explicit migration APIs are placement no-ops: there is nowhere to
    # move a page to in a single physical pool
    um.prefetch(a, 0, 1 << 20)
    um.demote(a, 0, 1 << 20)
    assert a.table.resident_bytes(Tier.DEVICE) == 1 << 20
    tr = um.report()["traffic_total"]
    assert tr["migrated_in"] == 0 and tr["migrated_out"] == 0
    assert tr["faults"] == 0 and tr["notifications"] == 0


def test_mi300a_unified_pool_exhaustion_is_oom():
    um = UnifiedMemory(hw=MI300A)
    pol = make_policy("mi300a_unified", page_size=4 * KB)
    too_big = MI300A.device_capacity + (1 << 20)
    a = um.alloc("big", too_big, pol)  # lazy: allocation itself is fine
    with pytest.raises(OutOfDeviceMemory, match="cannot oversubscribe"):
        um.kernel(writes=[(a, 0, too_big)], actor=Actor.GPU)


def test_mi300a_runs_an_app_end_to_end():
    from repro.apps import run_app

    r = run_app("hotspot", "mi300a_unified", preset="small", hw="mi300a")
    assert r.policy == "mi300a_unified"
    assert r.extra["hw"] == "mi300a"
    assert r.report["traffic_total"]["migrated_in"] == 0
    assert r.total > 0


# ------------------------------------------------- grep-enforced seam purity
def test_no_policy_kind_branches_outside_policy_module():
    """Acceptance: the runtime dispatches through MemPolicy hooks — no
    `policy.kind == "..."` string branch survives outside core/policy.py."""
    src_dir = pathlib.Path(repro.core.__file__).parent.parent
    # != and `in (...)` comparisons are branches too — the seam stays shut
    pat = re.compile(r"policy\.kind\s*[!=]=|policy\.kind\s+in\b|"
                     r"policy_kind\s*[!=]=|policy_kind\s+in\b")
    offenders = []
    for f in sorted(src_dir.rglob("*.py")):
        if f.name == "policy.py" and f.parent.name == "core":
            continue
        for i, line in enumerate(f.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{f.relative_to(src_dir)}:{i}: {line.strip()}")
    assert not offenders, "policy-kind branches outside core/policy.py:\n" \
        + "\n".join(offenders)
