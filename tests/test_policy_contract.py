"""Backend-API tier-1 coverage: the policy contract over every registered
backend, the registry seam itself, and the grep-enforced absence of
policy-kind string branches outside the policy module."""
from __future__ import annotations

import pathlib
import re

import pytest

import repro.core
from repro.core import (
    GRACE_HOPPER,
    MI300A,
    Actor,
    MemPolicy,
    OutOfDeviceMemory,
    Tier,
    UnifiedMemory,
    available_hardware,
    available_policies,
    get_hardware,
    make_policy,
    register_policy,
)
from repro.core.registry import _POLICIES

from policy_contract import CLUSTER_CONTRACTS, CONTRACTS

KB = 1024


# ------------------------------------------------------------- the contract
@pytest.mark.parametrize("contract", CONTRACTS, ids=lambda c: c.__name__)
@pytest.mark.parametrize("name", available_policies())
def test_policy_contract(name, contract):
    contract(make_policy(name, page_size=4 * KB))


# ------------------------------------------------- cluster (node-aware) clause
def _node_aware_policies():
    return tuple(n for n in available_policies()
                 if getattr(make_policy(n, page_size=4 * KB),
                            "node_aware", False))


@pytest.mark.parametrize("contract", CLUSTER_CONTRACTS,
                         ids=lambda c: c.__name__)
@pytest.mark.parametrize("name", _node_aware_policies())
def test_cluster_policy_contract(name, contract):
    """Node-aware backends additionally keep the per-node invariants on a
    multi-superchip model — auto-parametrized, so a newly registered
    cluster backend is covered the moment it sets ``node_aware``."""
    contract(make_policy(name, page_size=4 * KB))


def test_cluster_clause_covers_the_cluster_backends():
    assert {"cluster_system", "cluster_striped"} <= set(_node_aware_policies())


def test_contract_covers_mi300a():
    # the suite must pick up newly registered backends automatically
    assert "mi300a_unified" in available_policies()


# --------------------------------------------------------------- registry
def test_registry_roundtrip_and_knob_filtering():
    pol = make_policy("system", page_size=4 * KB, threshold=32,
                      speculative_prefetch=9)  # not a system knob: filtered
    assert pol.kind == "system"
    assert pol.page_size == 4 * KB
    assert pol.counter_threshold == 32
    man = make_policy("managed", page_size=4 * KB, speculative_prefetch=9,
                      threshold=32)  # threshold is not a managed knob
    assert man.speculative_prefetch == 9
    assert man.counter_threshold == 256  # untouched default
    with pytest.raises(KeyError, match="unknown memory policy"):
        make_policy("does-not-exist")
    # capability flags: only the explicit backend is table-less
    assert not make_policy("explicit").paged
    assert all(make_policy(n, page_size=4 * KB).paged
               for n in available_policies() if n != "explicit")


def test_register_policy_extends_the_seam():
    class NullPolicy(MemPolicy):
        kind = "null_test"

        def on_first_touch(self, um, a, p0, p1, actor, n_unmapped, need):
            return actor.home_tier

    register_policy("null_test", lambda **kw: NullPolicy())
    try:
        assert "null_test" in available_policies()
        um = UnifiedMemory()
        a = um.alloc("x", 64 * KB, make_policy("null_test"))
        um.kernel(writes=[(a, 0, 64 * KB)], actor=Actor.CPU)
        assert a.table.resident_bytes(Tier.HOST) == 64 * KB
    finally:
        _POLICIES.pop("null_test", None)


def test_hardware_registry():
    assert {"grace-hopper", "mi300a", "tpu-v5e",
            "gh200_x2", "gh200_x4"} <= set(available_hardware())
    assert get_hardware("mi300a") is MI300A
    assert get_hardware(None) is GRACE_HOPPER
    assert get_hardware(MI300A) is MI300A
    with pytest.raises(KeyError, match="unknown hardware"):
        get_hardware("does-not-exist")


def test_hardware_registry_is_complete():
    """``--hw`` accepts every model the code defines: each HardwareModel
    instance in core/hardware.py and the cluster package is registered
    under its own name (the TPU_V5E gap that once let a defined model slip
    out of the registry stays closed)."""
    import repro.cluster as cluster_mod
    import repro.core.hardware as hw_mod
    from repro.core.hardware import HardwareModel

    defined = {v.name for mod in (hw_mod, cluster_mod)
               for v in vars(mod).values() if isinstance(v, HardwareModel)}
    missing = defined - set(available_hardware())
    assert not missing, f"defined but unregistered hardware models: {missing}"
    for name in sorted(defined):
        assert get_hardware(name).name == name


# ----------------------------------------------------------- MI300A backend
def test_mi300a_unified_no_migration_uniform_cost():
    um = UnifiedMemory(hw=MI300A)
    pol = make_policy("mi300a_unified", page_size=4 * KB)
    a = um.alloc("pool", 1 << 20, pol)
    um.kernel(writes=[(a, 0, 1 << 20)], actor=Actor.CPU, name="init")
    # first touch maps into the single physical pool, regardless of actor
    assert a.table.resident_bytes(Tier.DEVICE) == 1 << 20
    t_gpu = um.kernel(reads=[(a, 0, 1 << 20)], actor=Actor.GPU, name="g")
    t_cpu = um.kernel(reads=[(a, 0, 1 << 20)], actor=Actor.CPU, name="c")
    # uniform-latency pool: both actors stream the same bytes in the same time
    assert t_gpu == pytest.approx(t_cpu, rel=1e-12)
    um.sync()
    # explicit migration APIs are placement no-ops: there is nowhere to
    # move a page to in a single physical pool
    um.prefetch(a, 0, 1 << 20)
    um.demote(a, 0, 1 << 20)
    assert a.table.resident_bytes(Tier.DEVICE) == 1 << 20
    tr = um.report()["traffic_total"]
    assert tr["migrated_in"] == 0 and tr["migrated_out"] == 0
    assert tr["faults"] == 0 and tr["notifications"] == 0


def test_mi300a_unified_pool_exhaustion_is_oom():
    um = UnifiedMemory(hw=MI300A)
    pol = make_policy("mi300a_unified", page_size=4 * KB)
    too_big = MI300A.device_capacity + (1 << 20)
    a = um.alloc("big", too_big, pol)  # lazy: allocation itself is fine
    with pytest.raises(OutOfDeviceMemory, match="cannot oversubscribe"):
        um.kernel(writes=[(a, 0, too_big)], actor=Actor.GPU)


def test_mi300a_runs_an_app_end_to_end():
    from repro.apps import run_app

    r = run_app("hotspot", "mi300a_unified", preset="small", hw="mi300a")
    assert r.policy == "mi300a_unified"
    assert r.extra["hw"] == "mi300a"
    assert r.report["traffic_total"]["migrated_in"] == 0
    assert r.total > 0


# ------------------------------------------------- grep-enforced seam purity
def test_no_policy_kind_branches_outside_policy_module():
    """Acceptance: the runtime dispatches through MemPolicy hooks — no
    `policy.kind == "..."` string branch survives outside core/policy.py."""
    src_dir = pathlib.Path(repro.core.__file__).parent.parent
    # != and `in (...)` comparisons are branches too — the seam stays shut
    pat = re.compile(r"policy\.kind\s*[!=]=|policy\.kind\s+in\b|"
                     r"policy_kind\s*[!=]=|policy_kind\s+in\b")
    offenders = []
    for f in sorted(src_dir.rglob("*.py")):
        if f.name == "policy.py" and f.parent.name == "core":
            continue
        for i, line in enumerate(f.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{f.relative_to(src_dir)}:{i}: {line.strip()}")
    assert not offenders, "policy-kind branches outside core/policy.py:\n" \
        + "\n".join(offenders)


def test_no_topology_branches_outside_cluster():
    """Cluster seam purity: node-identity comparisons and link-topology
    access stay inside the cluster package, the policy hook surface
    (core/policy.py) and the (node, tier) encoding module
    (core/pagetable.py). The engines, serve stack and launch layer route
    everything through MemPolicy hooks and ``um.on_node`` — no
    ``node == ...`` or ``.topology`` branch leaks out."""
    src_dir = pathlib.Path(repro.core.__file__).parent.parent
    pat = re.compile(r"\bnode\s*==|\bClusterTopology\b|\.topology\b")
    offenders = []
    for f in sorted(src_dir.rglob("*.py")):
        rel = f.relative_to(src_dir)
        if rel.parts[0] == "cluster" or str(rel) in ("core/policy.py",
                                                     "core/pagetable.py"):
            continue
        for i, line in enumerate(f.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, "cluster-topology branches outside the cluster " \
        "seam:\n" + "\n".join(offenders)
