"""Fault tolerance: checkpoint round-trip, failure recovery, elastic reshard."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointCorruptError, CheckpointManager
from repro.configs import get_config
from repro.data import DataLoader, SyntheticLM
from repro.models import RunPolicy, init_params
from repro.runtime import (FailureInjector, StragglerMonitor, poisson_steps,
                           reshard_tree)
from repro.train import Trainer, TrainerConfig, make_train_state, make_train_step


def _setup(tmp, ckpt_every=4, fail_at=(), injector=None):
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = make_train_state(cfg, params)
    tc = TrainerConfig(grad_accum=2, total_steps=50, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, RunPolicy(), tc))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    loader = DataLoader(ds)
    cm = CheckpointManager(tmp, keep_last=2)
    inj = injector or (FailureInjector.at(fail_at) if fail_at else None)
    return cfg, Trainer(cfg, state, step, loader, ckpt=cm, ckpt_every=ckpt_every,
                        injector=inj)


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as tmp:
        cfg = get_config("olmoe-1b-7b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        state = make_train_state(cfg, params)
        cm = CheckpointManager(tmp, async_save=False)
        cm.save(7, state)
        step, restored = cm.restore(state)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_recovery_bitwise_replay():
    """A failed-and-restored run produces the same losses as an uninterrupted
    one (deterministic data pipeline + logical checkpoints)."""
    with tempfile.TemporaryDirectory() as t1, tempfile.TemporaryDirectory() as t2:
        _, tr_plain = _setup(t1)
        out_plain = tr_plain.run(12)
        tr_plain.loader.close()

        _, tr_fail = _setup(t2, fail_at=[6, 9])
        out_fail = tr_fail.run(18)  # budget covers the replayed segments
        tr_fail.loader.close()

        assert out_fail["restarts"] == 2
        plain = {h["step"]: h["loss"] for h in out_plain["history"]}
        replayed = {}
        for h in out_fail["history"]:
            if h["step"] in replayed:  # replayed step: must be bit-identical
                assert h["loss"] == replayed[h["step"]], h
            replayed[h["step"]] = h["loss"]
        for s, l in plain.items():
            assert replayed[s] == l, (s, l, replayed[s])


def test_keep_last_pruning_and_atomicity():
    with tempfile.TemporaryDirectory() as tmp:
        cfg = get_config("yi-6b").reduced()
        state = make_train_state(cfg, init_params(cfg, jax.random.PRNGKey(0)))
        cm = CheckpointManager(tmp, keep_last=2, async_save=False)
        for s in (1, 2, 3, 4):
            cm.save(s, state)
        assert cm.all_steps() == [3, 4]
        assert not any(d.startswith(".tmp") for d in os.listdir(tmp))


def test_elastic_reshard_across_device_counts():
    """Checkpoint written 'on' one sharding restores to another (1 device:
    shardings degenerate but the tree/device_put path is exercised)."""
    with tempfile.TemporaryDirectory() as tmp:
        cfg = get_config("yi-6b").reduced()
        state = make_train_state(cfg, init_params(cfg, jax.random.PRNGKey(0)))
        cm = CheckpointManager(tmp, async_save=False)
        cm.save(1, state)
        dev = jax.devices()[0]
        shardings = jax.tree.map(lambda a: jax.sharding.SingleDeviceSharding(dev),
                                 state)
        step, restored = cm.restore(state, shardings=shardings)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection_and_hints():
    mon = StragglerMonitor(window=16, k_mad=4.0)
    rng = np.random.default_rng(0)
    for i in range(16):
        for w in range(4):
            base = 0.10 + 0.001 * rng.standard_normal()
            mon.record(f"w{w}", base * (4.0 if (w == 3 and i >= 8) else 1.0))
    assert mon.stragglers() == ["w3"]
    hints = mon.rebalance_hint()
    assert hints["w3"] <= 0.5  # slow worker told to shed microbatches
    assert hints["w0"] > 0.9
    assert mon.deadline() > 0.1


def test_poisson_schedule_deterministic_per_seed():
    """Same (rate, seed) -> identical schedule, different seed -> a
    different one; the trainer injector and the serve fault plan both draw
    from poisson_steps, so this pins the shared schedule family."""
    a = poisson_steps(rate=0.1, seed=7, horizon=200)
    b = poisson_steps(rate=0.1, seed=7, horizon=200)
    assert a == b and a, "seeded Poisson schedule must be reproducible"
    assert a == sorted(set(a)) and all(s >= 1 for s in a)
    assert poisson_steps(rate=0.1, seed=8, horizon=200) != a
    # the injector classmethod wraps the same steps
    inj = FailureInjector.poisson(rate=0.1, seed=7, horizon=200)
    assert inj.fail_at_steps == set(a)
    # MTBF sanity: mean gap tracks 1/rate within sampling noise
    gaps = np.diff([0] + a)
    assert 4.0 < float(gaps.mean()) < 25.0  # nominal MTBF = 10 steps


def test_failure_recovery_with_poisson_injector():
    """The trainer replays bit-identically under a seeded-MTBF injector,
    not just fixed-step schedules."""
    inj = FailureInjector.poisson(rate=0.25, seed=1, horizon=12)
    # seed 1 -> failures at steps {4, 5}: after the first checkpoint (step
    # 4) and within the replayed window, so both fire and both recover
    assert inj.fail_at_steps == {4, 5}
    with tempfile.TemporaryDirectory() as t1, tempfile.TemporaryDirectory() as t2:
        _, tr_plain = _setup(t1)
        out_plain = tr_plain.run(10)
        tr_plain.loader.close()

        _, tr_fail = _setup(t2, injector=inj)
        out_fail = tr_fail.run(10 + 8 * len(inj.fail_at_steps))
        tr_fail.loader.close()

        assert out_fail["restarts"] == len(inj.fail_at_steps)
        plain = {h["step"]: h["loss"] for h in out_plain["history"]}
        replayed = {h["step"]: h["loss"] for h in out_fail["history"]}
        for s, l in plain.items():
            assert replayed[s] == l, (s, l, replayed[s])


def _tiny_state():
    cfg = get_config("yi-6b").reduced()
    return make_train_state(cfg, init_params(cfg, jax.random.PRNGKey(0)))


def test_restore_rejects_truncated_archive():
    with tempfile.TemporaryDirectory() as tmp:
        state = _tiny_state()
        cm = CheckpointManager(tmp, async_save=False)
        cm.save(3, state)
        npz = os.path.join(tmp, "step-000000003", "tensors.npz")
        with open(npz, "r+b") as f:
            f.truncate(os.path.getsize(npz) // 2)
        with pytest.raises(CheckpointCorruptError):
            cm.restore(state)


def test_restore_rejects_flipped_tensor_bytes():
    """A bit flip that keeps the archive readable (same shape/dtype) must
    still be caught — by the per-tensor crc32, not the structure checks."""
    with tempfile.TemporaryDirectory() as tmp:
        state = _tiny_state()
        cm = CheckpointManager(tmp, async_save=False)
        cm.save(5, state)
        path = os.path.join(tmp, "step-000000005")
        npz = os.path.join(path, "tensors.npz")
        with np.load(npz) as z:
            flat = {k: np.array(z[k]) for k in z.files}
        victim = sorted(flat)[0]
        v = flat[victim].reshape(-1).view(np.uint8)
        v[0] ^= 0xFF  # same shape, same dtype, different content
        np.savez(npz, **flat)
        with pytest.raises(CheckpointCorruptError, match="crc32"):
            cm.restore(state)
        # an intact checkpoint alongside still restores fine
        cm.save(6, state)
        step, _ = cm.restore(state)
        assert step == 6


def test_restore_without_crc_still_checks_structure():
    """Checkpoints from an older writer (no crc32 in the manifest) restore,
    but a shape drift is still rejected."""
    import json

    with tempfile.TemporaryDirectory() as tmp:
        state = _tiny_state()
        cm = CheckpointManager(tmp, async_save=False)
        cm.save(1, state)
        man = os.path.join(tmp, "step-000000001", "manifest.json")
        with open(man) as f:
            manifest = json.load(f)
        for meta in manifest["keys"].values():
            meta.pop("crc32")
        with open(man, "w") as f:
            json.dump(manifest, f)
        step, restored = cm.restore(state)  # no crc -> content check skipped
        assert step == 1
        victim = sorted(manifest["keys"])[0]
        manifest["keys"][victim]["shape"] = [1, 2, 3]
        with open(man, "w") as f:
            json.dump(manifest, f)
        with pytest.raises(CheckpointCorruptError):
            cm.restore(state)


def test_data_pipeline_determinism_and_resume():
    ds = SyntheticLM(vocab_size=1000, seq_len=64, global_batch=2, seed=3)
    a = ds.batch(5)
    b = ds.batch(5)
    np.testing.assert_array_equal(a[0], b[0])
    loader = DataLoader(ds, start_step=0)
    first = [next(loader)[0] for _ in range(3)]
    loader.seek(1)
    again = [next(loader)[0] for _ in range(2)]
    loader.close()
    assert first == [0, 1, 2] and again == [1, 2]
