"""Fault tolerance: checkpoint round-trip, failure recovery, elastic reshard."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataLoader, SyntheticLM
from repro.models import RunPolicy, init_params
from repro.runtime import FailureInjector, StragglerMonitor, reshard_tree
from repro.train import Trainer, TrainerConfig, make_train_state, make_train_step


def _setup(tmp, ckpt_every=4, fail_at=()):
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = make_train_state(cfg, params)
    tc = TrainerConfig(grad_accum=2, total_steps=50, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, RunPolicy(), tc))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    loader = DataLoader(ds)
    cm = CheckpointManager(tmp, keep_last=2)
    inj = FailureInjector.at(fail_at) if fail_at else None
    return cfg, Trainer(cfg, state, step, loader, ckpt=cm, ckpt_every=ckpt_every,
                        injector=inj)


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as tmp:
        cfg = get_config("olmoe-1b-7b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        state = make_train_state(cfg, params)
        cm = CheckpointManager(tmp, async_save=False)
        cm.save(7, state)
        step, restored = cm.restore(state)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_recovery_bitwise_replay():
    """A failed-and-restored run produces the same losses as an uninterrupted
    one (deterministic data pipeline + logical checkpoints)."""
    with tempfile.TemporaryDirectory() as t1, tempfile.TemporaryDirectory() as t2:
        _, tr_plain = _setup(t1)
        out_plain = tr_plain.run(12)
        tr_plain.loader.close()

        _, tr_fail = _setup(t2, fail_at=[6, 9])
        out_fail = tr_fail.run(18)  # budget covers the replayed segments
        tr_fail.loader.close()

        assert out_fail["restarts"] == 2
        plain = {h["step"]: h["loss"] for h in out_plain["history"]}
        replayed = {}
        for h in out_fail["history"]:
            if h["step"] in replayed:  # replayed step: must be bit-identical
                assert h["loss"] == replayed[h["step"]], h
            replayed[h["step"]] = h["loss"]
        for s, l in plain.items():
            assert replayed[s] == l, (s, l, replayed[s])


def test_keep_last_pruning_and_atomicity():
    with tempfile.TemporaryDirectory() as tmp:
        cfg = get_config("yi-6b").reduced()
        state = make_train_state(cfg, init_params(cfg, jax.random.PRNGKey(0)))
        cm = CheckpointManager(tmp, keep_last=2, async_save=False)
        for s in (1, 2, 3, 4):
            cm.save(s, state)
        assert cm.all_steps() == [3, 4]
        assert not any(d.startswith(".tmp") for d in os.listdir(tmp))


def test_elastic_reshard_across_device_counts():
    """Checkpoint written 'on' one sharding restores to another (1 device:
    shardings degenerate but the tree/device_put path is exercised)."""
    with tempfile.TemporaryDirectory() as tmp:
        cfg = get_config("yi-6b").reduced()
        state = make_train_state(cfg, init_params(cfg, jax.random.PRNGKey(0)))
        cm = CheckpointManager(tmp, async_save=False)
        cm.save(1, state)
        dev = jax.devices()[0]
        shardings = jax.tree.map(lambda a: jax.sharding.SingleDeviceSharding(dev),
                                 state)
        step, restored = cm.restore(state, shardings=shardings)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection_and_hints():
    mon = StragglerMonitor(window=16, k_mad=4.0)
    rng = np.random.default_rng(0)
    for i in range(16):
        for w in range(4):
            base = 0.10 + 0.001 * rng.standard_normal()
            mon.record(f"w{w}", base * (4.0 if (w == 3 and i >= 8) else 1.0))
    assert mon.stragglers() == ["w3"]
    hints = mon.rebalance_hint()
    assert hints["w3"] <= 0.5  # slow worker told to shed microbatches
    assert hints["w0"] > 0.9
    assert mon.deadline() > 0.1


def test_data_pipeline_determinism_and_resume():
    ds = SyntheticLM(vocab_size=1000, seq_len=64, global_batch=2, seed=3)
    a = ds.batch(5)
    b = ds.batch(5)
    np.testing.assert_array_equal(a[0], b[0])
    loader = DataLoader(ds, start_step=0)
    first = [next(loader)[0] for _ in range(3)]
    loader.seek(1)
    again = [next(loader)[0] for _ in range(2)]
    loader.close()
    assert first == [0, 1, 2] and again == [1, 2]
