"""Fault-tolerant serving: node loss, lane degradation, spill failure.

The recovery guarantee under test: greedy decode is per-row batch-
independent, so a sequence whose KV is lost (dead node) or unsavable
(spill-failure window) replays from its prompt to the exact same tokens —
every fault run here is pinned bit-identical to a fault-free run of the
same schedule, while the recovery counters prove the fault actually hit.
"""
import numpy as np
import pytest

from repro.cluster import GH200_X2, ClusterTPPlan, device_free_on
from repro.core import Actor, UnifiedMemory, make_policy
from repro.runtime import FailureInjector, FaultEvent, FaultPlan, poisson_steps

KB = 1024
NBYTES = 512 * KB

CLUSTER_POLICIES = ("cluster_system", "cluster_striped")


# --------------------------------------------------------------- fixtures
@pytest.fixture(scope="module")
def micro_model():
    import jax

    from repro.configs.base import ArchConfig
    from repro.models import init_params

    cfg = ArchConfig(name="micro", family="dense", source="test",
                     num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                     head_dim=16, d_ff=64, vocab_size=64)
    return {"micro": (cfg, init_params(cfg, jax.random.PRNGKey(0)))}


def _micro_scenario(oversub=1.0, num_pages=None):
    from repro.serve import ArrivalProcess, LengthDist, Scenario, TenantSpec

    return Scenario(
        name="micro",
        tenants=tuple(TenantSpec(
            name=f"t{i}", arch="micro", num_requests=5,
            arrival=ArrivalProcess("poisson", rate=2e5),
            prompt=LengthDist("lognormal", lo=4, hi=24, mean=10.0),
            output=LengthDist("lognormal", lo=1, hi=8, mean=4.0))
            for i in range(2)),
        oversub=oversub, page_size=4, max_seqs=4, max_len=48,
        prefill_chunk=12, num_pages=num_pages, admit_device_fraction=0.5)


# -------------------------------------------------------------- the plan
def test_fault_plan_builders_sorted_and_deterministic():
    plan = FaultPlan.node_loss([(9, 1), (3, 0)]) \
        + FaultPlan.lane_degrade(5, 4, nvlink_factor=0.5) \
        + FaultPlan.spill_failure(1, 2)
    assert [e.step for e in plan.events] == [1, 3, 5, 9]
    assert bool(plan) and not bool(FaultPlan())
    # seeded-MTBF plans: same seed -> same schedule; never more losses
    # than nodes - 1; dying nodes drawn without replacement
    p1 = FaultPlan.poisson(rate=0.05, seed=11, num_nodes=4, horizon=100)
    p2 = FaultPlan.poisson(rate=0.05, seed=11, num_nodes=4, horizon=100)
    assert p1.events == p2.events
    assert 1 <= len(p1.events) <= 3
    nodes = [e.node for e in p1.events]
    assert len(set(nodes)) == len(nodes)
    assert all(e.kind == "node_loss" for e in p1.events)
    # the trainer injector draws from the same schedule family
    steps = poisson_steps(rate=0.05, seed=11, horizon=100)
    assert [e.step for e in p1.events] == steps[:3]
    assert FailureInjector.poisson(rate=0.05, seed=11,
                                   horizon=100).fail_at_steps == set(steps)


# ------------------------------------------------------------ runtime unit
@pytest.mark.parametrize("policy", CLUSTER_POLICIES)
def test_fail_node_poisons_pages_and_capacity(policy):
    um = UnifiedMemory(hw=GH200_X2)
    pol = make_policy(policy, page_size=4 * KB)
    a = um.alloc("x", NBYTES, pol)
    half = NBYTES // 2
    for k in (0, 1):
        with um.on_node(k):
            um.kernel(writes=[(a, k * half, (k + 1) * half)],
                      actor=Actor.GPU, name=f"init_n{k}")
    um.sync()
    free0 = um.device_free()

    lost = um.fail_node(1)
    assert "x" in lost and lost["x"], "node 1's resident runs must be lost"
    # the dead node's pages are unmapped, its capacity gone, and the
    # survivor's residency is untouched
    t = a.table
    assert int(t._tier_bytes[2 * 1 + 0 + 1]) == 0  # (1, HOST)
    assert int(t._tier_bytes[2 * 1 + 1 + 1]) == 0  # (1, DEVICE)
    assert device_free_on(um, 1) == 0
    assert um.device_free() < free0
    assert um.prof.extra["node_losses"] == 1
    assert um.prof.extra["lost_pages"] > 0
    assert um.prof.extra["lost_bytes"] > 0
    assert um._recompute_residency() == (um.host_bytes(), um.device_bytes())
    # idempotent: a second report of the same loss is a no-op
    assert um.fail_node(1) == {}
    assert um.prof.extra["node_losses"] == 1
    um.free(a)


def test_lane_degradation_scales_charges():
    um = UnifiedMemory(hw=GH200_X2)
    pol = make_policy("cluster_system", page_size=4 * KB)
    a = um.alloc("x", NBYTES, pol)
    with um.on_node(1):
        um.kernel(writes=[(a, 0, NBYTES)], actor=Actor.GPU, name="init")
    t_clean = um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, node=0,
                        name="far_clean")
    um.set_lane_degradation((0.25, 0.25))
    t_deg = um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, node=0,
                      name="far_degraded")
    um.set_lane_degradation(None)
    t_back = um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, node=0,
                       name="far_recovered")
    topo = um.hw.topology
    # the degraded read pays exactly the extra NVLink wire time
    assert t_deg == pytest.approx(
        t_clean + NBYTES / (topo.nvlink_bw * 0.25) - NBYTES / topo.nvlink_bw,
        rel=1e-9)
    assert t_back == pytest.approx(t_clean, rel=1e-12)
    assert um.prof.extra["degraded_nvlink_bytes"] == NBYTES
    um.free(a)


# -------------------------------------------------- serve recovery (gate)
def _completed(report):
    return all(r.done for r in report.records)


@pytest.mark.parametrize("policy,dead", [("cluster_system", 1),
                                         ("cluster_striped", 0)])
def test_node_loss_mid_decode_tokens_bit_identical(micro_model, policy, dead):
    """The ISSUE acceptance gate: inject a single-node loss mid-decode on
    gh200_x2 under TP-2; the engine must complete every request with
    tokens bit-identical to a fault-free run, reporting nonzero replayed
    tokens and lost pages. The dead node is the one actually holding KV
    pages at the fault step (locality places on the serving node, striping
    fills node 0's stripe first at this pool size)."""
    from repro.serve import TrafficSim

    sc = _micro_scenario()
    base = TrafficSim(sc, policy="system", seed=3, models=micro_model).run()
    plan = FaultPlan.node_loss([(4, dead)])
    faulted = TrafficSim(sc, policy=policy, hw="gh200_x2", seed=3,
                         models=micro_model, tp=2, fault_plan=plan).run()
    assert faulted.tokens == base.tokens
    assert _completed(faulted)
    stats = faulted.per_engine["micro"]["stats"]
    assert stats["node_losses"] == 1
    assert stats["recovered_requests"] > 0
    assert stats["replayed_tokens"] > 0
    extra = faulted.per_engine["micro"]["um_report"]["traffic_extra"]
    assert extra["lost_pages"] > 0 and extra["lost_bytes"] > 0
    # recovery re-decodes the lost tokens: strictly more decode work than
    # the fault-free TP run (modeled *time* can go either way — the
    # survivor pays recompute but stops paying TP collectives)
    clean = TrafficSim(sc, policy=policy, hw="gh200_x2", seed=3,
                       models=micro_model, tp=2).run()
    assert faulted.tokens == clean.tokens
    assert faulted.per_engine["micro"]["stats"]["decode_tokens"] \
        > clean.per_engine["micro"]["stats"]["decode_tokens"]
    recs = {r.rid: r for r in faulted.records}
    assert sum(r.recoveries for r in recs.values()) \
        == stats["recovered_requests"]


def test_lane_degrade_window_slows_but_preserves_tokens(micro_model):
    from repro.serve import TrafficSim

    sc = _micro_scenario()
    clean = TrafficSim(sc, policy="cluster_system", hw="gh200_x2", seed=3,
                       models=micro_model, tp=2).run()
    plan = FaultPlan.lane_degrade(1, 8, nvlink_factor=0.1, fabric_factor=0.1)
    deg = TrafficSim(sc, policy="cluster_system", hw="gh200_x2", seed=3,
                     models=micro_model, tp=2, fault_plan=plan).run()
    assert deg.tokens == clean.tokens
    assert _completed(deg)
    stats = deg.per_engine["micro"]["stats"]
    assert stats["lane_degraded_steps"] > 0
    assert stats["recovered_requests"] == 0  # degradation loses nothing
    extra = deg.per_engine["micro"]["um_report"]["traffic_extra"]
    assert extra["degraded_nvlink_bytes"] > 0
    assert deg.per_engine["micro"]["clock"] \
        > clean.per_engine["micro"]["clock"]


def _tight_scenario():
    """Burst load against a pool that cannot hold the batch — the
    preemption-forcing shape test_traffic.py pins bit-identity for."""
    from repro.serve import ArrivalProcess, LengthDist, Scenario, TenantSpec

    return Scenario(
        name="tight",
        tenants=tuple(TenantSpec(
            name=f"t{i}", arch="micro", num_requests=8,
            arrival=ArrivalProcess("bursty", rate=4e5, burst_size=8),
            prompt=LengthDist("pareto", lo=8, hi=20, alpha=1.4),
            output=LengthDist("lognormal", lo=4, hi=8, mean=6.0))
            for i in range(2)),
        oversub=1.0, page_size=4, max_seqs=3, max_len=48,
        prefill_chunk=12, num_pages=8, admit_device_fraction=0.5)


def test_spill_failure_window_recovers_by_recompute(micro_model):
    """With the pool squeezed to force preemption and host-spill failing
    for the whole run, every preemption falls back to drop-and-recompute:
    tokens still match the unfaulted run of the same squeezed schedule."""
    from repro.serve import TrafficSim

    sc = _tight_scenario()
    clean = TrafficSim(sc, policy="system", seed=2, models=micro_model).run()
    assert clean.per_engine["micro"]["stats"]["preempted"] > 0, \
        "scenario must be tight enough to preempt"
    plan = FaultPlan.spill_failure(0, 10_000)
    spilled = TrafficSim(sc, policy="system", seed=2, models=micro_model,
                         fault_plan=plan).run()
    assert spilled.tokens == clean.tokens
    assert _completed(spilled)
    stats = spilled.per_engine["micro"]["stats"]
    assert stats["spill_failures"] > 0
    assert stats["recovered_requests"] >= stats["spill_failures"]
    assert stats["replayed_tokens"] > 0


def test_fault_free_run_with_empty_plan_is_bit_identical(micro_model):
    """An installed-but-empty plan must take the zero-cost path: clock,
    tokens and counters all bit-identical to no plan at all."""
    from repro.serve import TrafficSim

    sc = _micro_scenario()
    a = TrafficSim(sc, policy="system", seed=3, models=micro_model).run()
    b = TrafficSim(sc, policy="system", seed=3, models=micro_model,
                   fault_plan=FaultPlan()).run()
    assert a.tokens == b.tokens
    assert a.per_engine["micro"]["clock"] == b.per_engine["micro"]["clock"]
    assert a.per_engine["micro"]["stats"] == b.per_engine["micro"]["stats"]


# ------------------------------------------------------------- drain mode
def test_drain_mode_finishes_admitted_work_only(micro_model):
    from repro.serve.engine import SeqState, ServeEngine

    cfg, params = micro_model["micro"]
    um = UnifiedMemory()
    eng = ServeEngine(cfg, params, max_seqs=4, max_len=48, page_size=4,
                      um=um, prefill_chunk=12)
    rng = np.random.default_rng(0)
    first = [eng.add_request(rng.integers(1, 64, size=6), max_new_tokens=4)
             for _ in range(2)]
    eng.step()  # admits the first wave
    eng.start_drain()
    late = [eng.add_request(rng.integers(1, 64, size=6), max_new_tokens=4)
            for _ in range(2)]
    eng.run_to_completion()
    for rid in first:
        assert eng.requests[rid].done
        assert len(eng.requests[rid].generated) == 4
    for rid in late:
        r = eng.requests[rid]
        assert r.state is SeqState.PENDING and r.admit_time is None, \
            "drain mode must not admit fresh work"
