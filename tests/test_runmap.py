"""Unit tests for the run-compressed interval map (core/runs.py) — the
primary metadata structure of the page table after the run-compression
refactor. The dense reference for every operation is a plain numpy array."""
import numpy as np
import pytest

from repro.core import RunMap, union_runs


def test_initial_state_single_run():
    m = RunMap(1000, fill=7, dtype=np.int8)
    assert m.num_runs == 1
    assert m.value_at(0) == 7 and m.value_at(999) == 7
    s, e, v = m.runs()
    assert s.tolist() == [0] and e.tolist() == [1000] and v.tolist() == [7]
    m.check()


def test_set_range_splits_and_coalesces():
    m = RunMap(16, fill=0)
    m.set_range(4, 8, 5)
    assert m.to_dense().tolist() == [0] * 4 + [5] * 4 + [0] * 8
    m.check()
    # re-setting the same value is a no-op on the structure
    m.set_range(5, 7, 5)
    assert m.num_runs == 3
    # filling the gap coalesces back to one run
    m.set_range(4, 8, 0)
    assert m.num_runs == 1
    m.check()


def test_set_range_seams_coalesce_with_neighbors():
    m = RunMap(10, fill=0)
    m.set_range(0, 3, 1)
    m.set_range(3, 6, 1)  # extends the left neighbor
    assert m.num_runs == 2
    assert m.to_dense().tolist() == [1] * 6 + [0] * 4
    m.set_range(6, 10, 1)  # now uniform
    assert m.num_runs == 1
    m.check()


def test_set_range_edges():
    m = RunMap(8, fill=0)
    m.set_range(0, 8, 3)  # whole map
    assert m.num_runs == 1 and m.value_at(4) == 3
    m.set_range(7, 8, 9)  # last page only
    m.set_range(0, 1, 9)  # first page only
    assert m.to_dense().tolist() == [9, 3, 3, 3, 3, 3, 3, 9]
    m.set_range(2, 2, 1)  # empty range: no-op
    assert m.num_runs == 3
    m.check()


def test_runs_window_clipping():
    m = RunMap(20, fill=0)
    m.set_range(5, 10, 1)
    m.set_range(10, 15, 2)
    s, e, v = m.runs(7, 12)
    assert s.tolist() == [7, 10]
    assert e.tolist() == [10, 12]
    assert v.tolist() == [1, 2]


def test_add_range_matches_dense():
    rng = np.random.default_rng(0)
    m = RunMap(64, fill=0)
    dense = np.zeros(64, np.int64)
    for _ in range(200):
        p0, p1 = sorted(rng.integers(0, 65, 2).tolist())
        d = int(rng.integers(1, 5))
        m.add_range(p0, p1, d)
        dense[p0:p1] += d
        m.check()
    assert (m.to_dense() == dense).all()


def test_set_range_fuzz_matches_dense():
    rng = np.random.default_rng(1)
    m = RunMap(97, fill=-1, dtype=np.int8)
    dense = np.full(97, -1, np.int8)
    for _ in range(300):
        p0, p1 = sorted(rng.integers(0, 98, 2).tolist())
        val = int(rng.integers(-1, 3))
        m.set_range(p0, p1, val)
        dense[p0:p1] = val
        m.check()
    assert (m.to_dense() == dense).all()


def test_from_dense_round_trip():
    dense = np.array([0, 0, 1, 1, 1, 0, 2, 2, 0, 0], np.int64)
    m = RunMap.from_dense(dense)
    m.check()
    assert (m.to_dense() == dense).all()
    assert m.num_runs == 5


def test_nonzero_runs_and_counts():
    m = RunMap(12, fill=0, dtype=np.int8)
    m.set_range(1, 3, 1)
    m.set_range(7, 11, 1)
    s, e = m.nonzero_runs()
    assert list(zip(s.tolist(), e.tolist())) == [(1, 3), (7, 11)]
    assert m.count_nonzero() == 6
    assert m.count_nonzero(2, 8) == 2
    assert m.any()
    m.clear()
    assert not m.any() and m.num_runs == 1


def test_bytes_used_is_o_runs_not_o_pages():
    small = RunMap(16, fill=0)
    big = RunMap(1 << 24, fill=0)  # 16M pages
    assert big.bytes_used() == small.bytes_used()
    big.set_range(5, 100, 1)
    assert big.bytes_used() < 200  # 3 runs


def test_union_runs_merges_overlap_and_adjacency():
    s = np.array([0, 2, 10, 11], np.int64)
    e = np.array([3, 5, 11, 20], np.int64)
    us, ue = union_runs(s, e)
    assert us.tolist() == [0, 10]
    assert ue.tolist() == [5, 20]


def test_splice_preserves_dtype():
    m = RunMap(8, fill=0, dtype=np.int8)
    m.set_range(2, 4, 1)
    assert m.vals.dtype == np.int8
    m.add_range(0, 8, 1)
    assert m.vals.dtype == np.int8


@pytest.mark.parametrize("n", [1, 2, 7])
def test_tiny_maps(n):
    m = RunMap(n, fill=0)
    m.set_range(0, n, 4)
    assert m.to_dense().tolist() == [4] * n
    m.check()
