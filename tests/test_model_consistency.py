"""Autoregressive consistency: prefill+decode == full forward; chunked == ref."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import RunPolicy, decode_step, forward, init_params, prefill
from repro.models.cache import init_cache
from repro.models.rwkv import wkv6_chunked, wkv6_ref
from repro.models.attention import attn_apply, attn_decode
from repro.models.layout import HeadLayout


@pytest.mark.parametrize("arch", ["yi-6b", "qwen2.5-32b", "recurrentgemma-2b",
                                  "rwkv6-1.6b", "olmoe-1b-7b", "musicgen-medium"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    # high capacity factor: capacity-MoE token drops depend on batch size, so
    # exact prefill/decode == forward equality needs the no-drop regime
    pol = RunPolicy(moe_capacity_factor=1e9)
    B, S = 2, 24
    key = jax.random.PRNGKey(1)
    if cfg.input_kind == "embeddings":
        toks = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        tok_at = lambda i: toks[:, i:i + 1, :]
    else:
        toks = jax.random.randint(key, (B, S), 2, cfg.vocab_size)
        tok_at = lambda i: toks[:, i:i + 1]

    full_logits, _ = forward(cfg, params, toks, pol)

    # prefill the first S-4 tokens, then decode the rest one-by-one
    Spre = S - 4
    pre = toks[:, :Spre]
    logits_last, _ = prefill(cfg, params, pre, pol)
    np.testing.assert_allclose(np.asarray(logits_last[:, 0]),
                               np.asarray(full_logits[:, Spre - 1]),
                               atol=2e-3, rtol=1e-3)

    # decode from scratch: feed tokens sequentially through decode_step
    cache = init_cache(cfg, B, S + 2, tp=1, dtype=jnp.float32)
    step = jax.jit(lambda p, t, ps, c: decode_step(cfg, p, t, ps, c, pol))
    for i in range(S):
        lg, cache = step(params, tok_at(i), jnp.full((B,), i, jnp.int32), cache)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits[:, i]),
                                   atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("B,S,H,K,chunk", [(2, 64, 2, 8, 16), (1, 96, 4, 16, 32),
                                           (2, 33, 2, 8, 16)])
def test_wkv6_chunked_vs_ref(B, S, H, K, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, K)) * 0.5
    wlog = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) - 2.0)
    u = 0.3 * jax.random.normal(ks[4], (H, K))
    s0 = jnp.zeros((B, H, K, K))
    y1, sT1 = wkv6_ref(r, k, v, wlog, u, s0)
    y2, sT2 = wkv6_chunked(r, k, v, wlog, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(sT1), np.asarray(sT2), atol=2e-5)


def test_sliding_window_decode_ring_buffer():
    """Ring-buffer decode == full-cache decode with window mask."""
    cfg = dataclasses.replace(get_config("recurrentgemma-2b").reduced(),
                              local_window=8)
    lay = HeadLayout.make(cfg.num_heads, cfg.num_kv_heads, 1)
    key = jax.random.PRNGKey(0)
    from repro.models.attention import attn_init
    p = attn_init(cfg, lay, key, jnp.float32)
    pol = RunPolicy()
    B, S, W = 1, 24, 8
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    full, _ = attn_apply(cfg, p, x, lay, pol, window=W)
    ring = {"k": jnp.zeros((B, W, lay.n_kv_eff, cfg.head_dim)),
            "v": jnp.zeros((B, W, lay.n_kv_eff, cfg.head_dim))}
    for i in range(S):
        o, ring = attn_decode(cfg, p, x[:, i:i + 1], lay, pol,
                              jnp.asarray([i], jnp.int32), ring, window=W)
        np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(full[:, i]),
                                   atol=1e-4)


def test_int8_kv_cache_decode_close_to_fp():
    """int8 KV cache (decode memory-term lever): output distribution within
    quantization tolerance of the fp cache."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import RunPolicy, decode_step, forward, init_params
    from repro.models.cache import init_cache

    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    pol = RunPolicy()
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 2, cfg.vocab_size)
    full, _ = forward(cfg, params, toks, pol)
    cache = init_cache(cfg, B, S + 2, tp=1, dtype=jnp.float32, kv_quant=True)
    step = jax.jit(lambda p, t, ps, c: decode_step(cfg, p, t, ps, c, pol))
    for i in range(S):
        lg, cache = step(params, toks[:, i:i + 1], jnp.full((B,), i, jnp.int32), cache)
        np.testing.assert_allclose(
            np.asarray(jax.nn.softmax(lg[:, 0])),
            np.asarray(jax.nn.softmax(full[:, i])), atol=0.05)
