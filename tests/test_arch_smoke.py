"""Per-arch smoke: reduced config, one forward + one train step on CPU.

Asserts output shapes and no NaNs for every assigned architecture (the FULL
configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import RunPolicy, forward, init_params, loss_fn
from repro.train import TrainerConfig, make_train_state, make_train_step

B, S = 2, 32


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    pol = RunPolicy()
    key = jax.random.PRNGKey(1)
    if cfg.input_kind == "embeddings":
        toks = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, aux = jax.jit(lambda p, t: forward(cfg, p, t, pol))(params, toks)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    state = make_train_state(cfg, params)
    tc = TrainerConfig(grad_accum=1, total_steps=10, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, RunPolicy(), tc))
    key = jax.random.PRNGKey(2)
    if cfg.input_kind == "embeddings":
        toks = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks,
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(p0), np.asarray(p1))


@pytest.mark.parametrize("arch", ["yi-6b", "recurrentgemma-2b", "rwkv6-1.6b",
                                  "olmoe-1b-7b"])
def test_scan_equals_unroll(arch):
    """scan-over-layers lowering == unrolled lowering (homogeneous archs)."""
    cfg = get_config(arch).reduced()
    if cfg.layer_pattern:
        pytest.skip("hybrid archs always unroll")
    params = init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    o1, _ = forward(cfg, params, toks, RunPolicy(scan_layers=False))
    o2, _ = forward(cfg, params, toks, RunPolicy(scan_layers=True))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
