"""Deterministic (non-hypothesis) tests for eviction / oversubscription.

Covers the satellite checklist: LRU victim order by last_access_epoch,
dirty-page writeback traffic, the thrash-mode fallback in kernel(), the
_evict_lru `exclude` regression (no self-eviction of pages touched in the
same kernel step), and the extent runtime's cached-residency invariants.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    GRACE_HOPPER,
    Actor,
    Tier,
    UnifiedMemory,
    managed_policy,
)

KB = 1024
MB = 1024 * 1024


def _small_hw(capacity=64 * MB):
    return dataclasses.replace(GRACE_HOPPER, device_capacity=capacity)


def _pages(a, lo, hi):
    p0, p1 = a.table.page_range(lo, hi)
    return a.table.tier[p0:p1]


def test_lru_victim_order_by_epoch_and_dirty_writeback():
    """Two managed allocations fill the device; the one with the older
    last_access_epoch is evicted first, and dirty pages charge writeback."""
    um = UnifiedMemory(hw=_small_hw())
    a = um.alloc("A", 32 * MB, managed_policy(64 * KB))
    b = um.alloc("B", 32 * MB, managed_policy(64 * KB))
    um.kernel(writes=[(a, 0, 32 * MB)], actor=Actor.GPU)  # epoch 1
    um.kernel(writes=[(b, 0, 32 * MB)], actor=Actor.GPU)  # epoch 2
    assert um.device_free() == 0
    um.kernel(reads=[(a, 0, 32 * MB)], actor=Actor.GPU)   # epoch 3: A is MRU
    c = um.alloc("C", 16 * MB, managed_policy(64 * KB))
    um.kernel(writes=[(c, 0, 16 * MB)], actor=Actor.GPU)  # forces eviction
    # B (epoch 2) is LRU -> loses exactly the needed 16 MB; A untouched
    assert a.table.resident_bytes(Tier.DEVICE) == 32 * MB
    assert b.table.resident_bytes(Tier.DEVICE) == 16 * MB
    assert b.table.resident_bytes(Tier.HOST) == 16 * MB
    assert c.table.resident_bytes(Tier.DEVICE) == 16 * MB
    # victims are B's first pages (insertion order within equal epochs)
    assert (_pages(b, 0, 16 * MB) == int(Tier.HOST)).all()
    assert (_pages(b, 16 * MB, 32 * MB) == int(Tier.DEVICE)).all()
    # B was written (dirty) -> evicted bytes copy back over the link
    tr = um.report()["traffic_total"]
    assert tr["migrated_out"] == 16 * MB
    assert tr["link_d2h"] >= 16 * MB
    assert um.device_bytes() <= um.hw.device_capacity


def test_evict_exclude_regression_no_same_step_self_eviction():
    """_evict_lru must honor `exclude`: an eviction triggered while a kernel
    step is faulting must never evict pages that same step just touched.

    Construction: D (old, dirty) holds 4 MB; one GPU kernel first-touches A
    in two ranges. Range 2 needs 12 MB but only D's 4 MB + 4 MB free exist,
    so the buggy runtime would steal 4 MB from range 1's just-mapped pages;
    the fixed runtime spills range 2 to host instead."""
    um = UnifiedMemory(hw=_small_hw())
    d = um.alloc("D", 4 * MB, managed_policy(64 * KB))
    um.kernel(writes=[(d, 0, 4 * MB)], actor=Actor.GPU)  # epoch 1, dirty
    a = um.alloc("A", 68 * MB, managed_policy(64 * KB))
    um.kernel(writes=[(a, 0, 56 * MB), (a, 56 * MB, 68 * MB)], actor=Actor.GPU)
    # range 1's pages (same kernel step) must all still be device-resident
    assert (_pages(a, 0, 56 * MB) == int(Tier.DEVICE)).all()
    # D (older epoch) was fair game
    assert d.table.resident_bytes(Tier.DEVICE) == 0
    assert d.table.resident_bytes(Tier.HOST) == 4 * MB
    # range 2 could not fit -> spilled host-side, not served by self-eviction
    assert (_pages(a, 56 * MB, 68 * MB) == int(Tier.HOST)).all()
    tr = um.report()["traffic_total"]
    assert tr["migrated_out"] == 4 * MB  # D's dirty writeback only
    assert um.device_bytes() <= um.hw.device_capacity


def test_evict_exclude_single_range_head_not_self_evicted():
    """Same bug, single-range shape (what batched KV touches produce): one
    coalesced range whose unmapped tail forces an eviction must not evict
    the range's own already-resident head."""
    um = UnifiedMemory(hw=_small_hw())
    d = um.alloc("D", 4 * MB, managed_policy(64 * KB))
    um.kernel(writes=[(d, 0, 4 * MB)], actor=Actor.GPU)   # epoch 1, dirty
    a = um.alloc("A", 68 * MB, managed_policy(64 * KB))
    um.kernel(writes=[(a, 0, 56 * MB)], actor=Actor.GPU)  # head resident
    um.kernel(reads=[(a, 0, 68 * MB)], actor=Actor.GPU)   # ONE range, 12 MB tail
    # the head is part of this step's working set -> untouched
    assert (_pages(a, 0, 56 * MB) == int(Tier.DEVICE)).all()
    # D (older epoch) evicted; the tail spilled host-side
    assert d.table.resident_bytes(Tier.DEVICE) == 0
    assert (_pages(a, 56 * MB, 68 * MB) == int(Tier.HOST)).all()
    tr = um.report()["traffic_total"]
    assert tr["migrated_out"] == 4 * MB  # only D's dirty writeback
    assert um.device_bytes() <= um.hw.device_capacity


def test_thrash_mode_fallback():
    """When the touched working set cannot fit even after evicting every
    other managed page, kernel() stops migrating and serves remote reads at
    the degraded thrash bandwidth (paper §7)."""
    um = UnifiedMemory(hw=_small_hw())
    a = um.alloc("A", 96 * MB, managed_policy(64 * KB))
    um.kernel(writes=[(a, 0, 96 * MB)], actor=Actor.CPU)
    with um.phase("thrash"):
        dt = um.kernel(reads=[(a, 0, 96 * MB)], actor=Actor.GPU)
    # no migration happened: everything stayed host-resident
    assert a.table.resident_bytes(Tier.DEVICE) == 0
    tr = um.report()["traffic"]["thrash"]
    assert tr["migrated_in"] == 0
    assert tr["faults"] == 0
    assert tr["link_h2d"] == 96 * MB
    # time is bound by the degraded thrash bandwidth, not the healthy link
    t_expected = 96 * MB / (um.hw.link_h2d * um.hw.managed_thrash_efficiency)
    assert dt == pytest.approx(t_expected, rel=1e-6, abs=um.hw.kernel_launch * 2)


def test_streaming_oversubscription_respects_capacity():
    """A managed allocation 2x the device streams window-by-window: capacity
    is never exceeded and the cached residency totals never drift."""
    um = UnifiedMemory(hw=_small_hw())
    a = um.alloc("A", 128 * MB, managed_policy(64 * KB))
    um.kernel(writes=[(a, 0, 128 * MB)], actor=Actor.CPU)
    step = 16 * MB
    for i in range(128 // 16):
        um.kernel(reads=[(a, i * step, (i + 1) * step)], actor=Actor.GPU)
        assert um.device_bytes() <= um.hw.device_capacity
        assert (um.host_bytes(), um.device_bytes()) == um._recompute_residency()
    assert um.report()["traffic_total"]["migrated_in"] > 0


def test_cached_residency_tracks_free():
    um = UnifiedMemory(hw=_small_hw())
    a = um.alloc("A", 8 * MB, managed_policy(64 * KB))
    um.kernel(writes=[(a, 0, 8 * MB)], actor=Actor.GPU)
    assert um.device_bytes() == 8 * MB
    um.free(a)
    assert (um.host_bytes(), um.device_bytes()) == (0, 0)
    assert um._recompute_residency() == (0, 0)
