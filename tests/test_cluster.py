"""Distributed unified pool (src/repro/cluster/): the cluster subsystem.

What the multi-superchip layer guarantees:

* **(node, tier) encoding** — page locations are single small ints that
  round-trip through node/tier and collapse to the plain Tier values at
  N=1 (the bit-identity degeneracy the parity fixture pins).
* **node-aware placement** — first touch lands on the toucher's own
  superchip; cross-node access charges the inter-node NVLink/fabric
  lanes (side counters, never the parity-pinned TrafficCounters).
* **ring spill / promote** — demote pushes a node's device pages to the
  *next* node's host memory over the fabric; prefetch promotes toward
  the accessing node.
* **striped capacity** — the capacity-first backend round-robins GPU
  pages across every node's device memory.
* **batch == sequential** — the vectorized launch engine charges
  cluster runs bit-identically to the one-kernel-at-a-time loop.
* **TP serving acceptance** — a TP-2 serve run on gh200_x2 generates
  tokens bit-identical to the single-node run of the same schedule
  while reporting nonzero inter-node traffic.
* **trace replay** — a recorded single-node app re-charges under a
  cluster backend, and matches the native run of the same app.
"""
import numpy as np
import pytest

from repro.cluster import (
    GH200_X2,
    GH200_X4,
    ClusterTPPlan,
    device_free_on,
    device_used_on,
    gh200_cluster,
)
from repro.core import (
    GRACE_HOPPER,
    Actor,
    Tier,
    UnifiedMemory,
    available_hardware,
    get_hardware,
    make_policy,
)
from repro.core.pagetable import loc_node, loc_tier, node_tier_loc

KB = 1024
MB = 1024 * KB
NBYTES = 512 * KB

CLUSTER_POLICIES = ("cluster_system", "cluster_striped")


def _pol(name, **kw):
    return make_policy(name, page_size=4 * KB, **kw)


# ----------------------------------------------------------- (node, tier)
def test_node_tier_encoding_roundtrip():
    for node in range(8):
        for tier in (Tier.HOST, Tier.DEVICE):
            loc = node_tier_loc(node, tier)
            assert loc_node(loc) == node
            assert loc_tier(loc) is tier
    # N=1 degeneracy: node-0 encodings ARE the plain Tier ints, so every
    # single-node table, trace and parity snapshot is unchanged
    assert node_tier_loc(0, Tier.HOST) == int(Tier.HOST)
    assert node_tier_loc(0, Tier.DEVICE) == int(Tier.DEVICE)


def test_cluster_hardware_models():
    assert GH200_X2.nodes == 2 and GH200_X4.nodes == 4
    assert GH200_X2.name == "gh200_x2"
    assert GH200_X2.node_device_capacity == GRACE_HOPPER.device_capacity
    assert GH200_X2.device_capacity == 2 * GRACE_HOPPER.device_capacity
    # registered like any other hardware model
    assert {"gh200_x2", "gh200_x4"} <= set(available_hardware())
    assert get_hardware("gh200_x4").nodes == 4
    # capacity override keeps the per-node split consistent (oversub
    # harnesses shrink capacity through this)
    hw = GH200_X4.with_device_capacity(10 * MB)
    assert hw.device_capacity == hw.nodes * hw.node_device_capacity
    assert hw.device_capacity >= 10 * MB
    custom = gh200_cluster(3, node_device_capacity=64 * MB)
    assert custom.nodes == 3 and custom.device_capacity == 3 * 64 * MB


# ------------------------------------------------------ placement + lanes
def test_first_touch_lands_on_touching_node():
    um = UnifiedMemory(hw=GH200_X2)
    a = um.alloc("x", NBYTES, _pol("cluster_system"))
    with um.on_node(1):
        um.kernel(writes=[(a, 0, NBYTES)], actor=Actor.GPU, name="init")
    t = a.table
    assert int(t._tier_bytes[node_tier_loc(1, Tier.DEVICE) + 1]) == NBYTES
    assert device_used_on(um, 1) == NBYTES and device_used_on(um, 0) == 0
    assert device_free_on(um, 1) == GRACE_HOPPER.device_capacity - NBYTES


def test_cross_node_read_charges_nvlink_lane():
    um = UnifiedMemory(hw=GH200_X2)
    a = um.alloc("x", NBYTES, _pol("cluster_system"))
    with um.on_node(1):
        um.kernel(writes=[(a, 0, NBYTES)], actor=Actor.GPU, name="init")
    t_local = um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, node=1,
                        name="local")
    t_far = um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, node=0,
                      name="far")
    assert um.prof.extra["internode_nvlink_bytes"] == NBYTES
    assert um.prof.extra["internode_fabric_bytes"] == 0
    # the remote read swaps local HBM streaming for the inter-node link
    # (same fixed launch overhead, so the delta is exactly the lane cost)
    assert t_far > t_local
    topo = um.hw.topology
    assert t_far == pytest.approx(
        t_local - NBYTES / um.hw.device_bw
        + NBYTES / topo.nvlink_bw + topo.nvlink_latency, rel=1e-9)


def test_remote_host_read_charges_fabric_lane():
    um = UnifiedMemory(hw=GH200_X2)
    a = um.alloc("x", NBYTES, _pol("cluster_system"))
    with um.on_node(1):
        um.kernel(writes=[(a, 0, NBYTES)], actor=Actor.CPU, name="init")
    assert int(a.table._tier_bytes[node_tier_loc(1, Tier.HOST) + 1]) == NBYTES
    um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, node=0, name="far")
    assert um.prof.extra["internode_fabric_bytes"] == NBYTES
    assert um.prof.extra["internode_nvlink_bytes"] == 0


def test_demote_spills_to_next_nodes_host_over_fabric():
    um = UnifiedMemory(hw=GH200_X2)
    a = um.alloc("x", NBYTES, _pol("cluster_system"))
    with um.on_node(1):
        um.kernel(writes=[(a, 0, NBYTES)], actor=Actor.GPU, name="init")
    um.demote(a, 0, NBYTES)
    t = a.table
    # ring order: node 1's device pages land in node 0's host memory,
    # one NVLink-C2C push plus a fabric hop
    assert int(t._tier_bytes[node_tier_loc(0, Tier.HOST) + 1]) == NBYTES
    assert device_used_on(um, 1) == 0
    assert um.prof.extra["internode_fabric_bytes"] == NBYTES
    assert um.report()["traffic_total"]["migrated_out"] == NBYTES
    # promote back toward the accessing node: node 1 pulls it home
    with um.on_node(1):
        um.prefetch(a, 0, NBYTES)
    assert int(t._tier_bytes[node_tier_loc(1, Tier.DEVICE) + 1]) == NBYTES
    assert um.prof.extra["internode_fabric_bytes"] == 2 * NBYTES
    assert um.report()["traffic_total"]["migrated_in"] == NBYTES


def test_striped_backend_distributes_device_pages():
    um = UnifiedMemory(hw=GH200_X4)
    total = 16 * MB
    a = um.alloc("big", total, _pol("cluster_striped"))
    um.kernel(writes=[(a, 0, total)], actor=Actor.GPU, name="init")
    per_node = [device_used_on(um, k) for k in range(4)]
    assert per_node == [total // 4] * 4, per_node
    # the striping write itself already pushed 3/4 of the bytes to other
    # nodes' devices over NVLink...
    assert um.prof.extra["internode_nvlink_bytes"] == 3 * total // 4
    # ...and reading it all back from node 0 pulls the same 3/4 again
    um.kernel(reads=[(a, 0, total)], actor=Actor.GPU, node=0, name="r")
    assert um.prof.extra["internode_nvlink_bytes"] == 2 * (3 * total // 4)


def test_cluster_policies_have_no_access_counters():
    for name in CLUSTER_POLICIES:
        p = _pol(name)
        assert p.node_aware and p.migratable and not p.auto_migrate


# ------------------------------------------------------ batch == sequential
@pytest.mark.parametrize("policy", CLUSTER_POLICIES)
@pytest.mark.parametrize("hw", ["gh200_x2", "gh200_x4"])
def test_batch_matches_sequential(policy, hw):
    """The vectorized launch engine charges cluster runs bit-identically
    to the one-kernel-at-a-time loop — per-launch seconds, the clock, the
    traffic report and the inter-node side counters."""

    def ops(n_nodes):
        rng = np.random.default_rng(7)
        out = []
        for i in range(24):
            lo = int(rng.integers(0, NBYTES - 1)) & ~0xFFF
            hi = min(NBYTES, lo + int(rng.integers(1, NBYTES // 3)))
            actor = Actor.GPU if rng.integers(2) else Actor.CPU
            rd, wr = ([], [(lo, hi)]) if rng.integers(2) else ([(lo, hi)], [])
            out.append((f"k{i}", rd, wr, 0.0, actor,
                        int(rng.integers(n_nodes))))
        return out

    def build(h):
        um = UnifiedMemory(hw=get_hardware(h))
        a = um.alloc("x", NBYTES, _pol(policy))
        # established placement: every node touched its own slice first
        nn = um.hw.nodes
        for k in range(nn):
            um.kernel(writes=[(a, k * (NBYTES // nn),
                               (k + 1) * (NBYTES // nn))],
                      actor=Actor.GPU, node=k, name=f"init{k}")
        um.sync()
        return um, a

    um_s, a_s = build(hw)
    seq = [um_s.kernel(reads=[(a_s, lo, hi) for lo, hi in rd],
                       writes=[(a_s, lo, hi) for lo, hi in wr],
                       flops=fl, actor=ac, node=nd, name=nm)
           for nm, rd, wr, fl, ac, nd in ops(um_s.hw.nodes)]

    um_b, a_b = build(hw)
    bat = um_b.kernel_batch([
        (nm, [(a_b, lo, hi) for lo, hi in rd],
         [(a_b, lo, hi) for lo, hi in wr], fl, ac, nd)
        for nm, rd, wr, fl, ac, nd in ops(um_b.hw.nodes)])

    assert seq == list(bat)  # bit-identical, not approx
    assert um_s.clock == um_b.clock
    assert dict(um_s.prof.extra) == dict(um_b.prof.extra)
    assert um_s.report()["traffic_total"] == um_b.report()["traffic_total"]


# --------------------------------------------------------------- sharding
def test_tp_shard_nodes_mapping():
    from repro.launch.sharding import tp_shard_nodes

    assert tp_shard_nodes(4, 1) == (0, 0, 0, 0)
    assert tp_shard_nodes(2, 2) == (0, 1)
    assert tp_shard_nodes(4, 2) == (0, 0, 1, 1)  # consecutive ranks pack
    assert tp_shard_nodes(4, 4) == (0, 1, 2, 3)
    assert tp_shard_nodes(3, 2) == (0, 0, 1)  # ceil split, last node short
    for tp, nodes in ((8, 2), (8, 4), (5, 3)):
        m = tp_shard_nodes(tp, nodes)
        assert len(m) == tp and max(m) == nodes - 1  # every node serves


def test_tp_plan_allreduce_bytes():
    class Cfg:
        num_layers = 4
        d_model = 128

    assert ClusterTPPlan(1).allreduce_bytes_per_token(Cfg()) == 0
    b2 = ClusterTPPlan(2).allreduce_bytes_per_token(Cfg())
    # 2 all-reduces/layer * 4 layers * (2*(N-1)/N = 1) * 128 * 4B
    assert b2 == 2 * 4 * 128 * 4
    b4 = ClusterTPPlan(4).allreduce_bytes_per_token(Cfg())
    assert b4 == int(2 * 4 * 1.5 * 128 * 4)
    assert ClusterTPPlan(4).node_of_seq(6) == 2


# ------------------------------------------------- TP serving (acceptance)
@pytest.fixture(scope="module")
def micro_model():
    import jax

    from repro.configs.base import ArchConfig
    from repro.models import init_params

    cfg = ArchConfig(name="micro", family="dense", source="test",
                     num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                     head_dim=16, d_ff=64, vocab_size=64)
    return {"micro": (cfg, init_params(cfg, jax.random.PRNGKey(0)))}


def _micro_scenario(oversub=1.0):
    from repro.serve import ArrivalProcess, LengthDist, Scenario, TenantSpec

    return Scenario(
        name="micro",
        tenants=tuple(TenantSpec(
            name=f"t{i}", arch="micro", num_requests=5,
            arrival=ArrivalProcess("poisson", rate=2e5),
            prompt=LengthDist("lognormal", lo=4, hi=24, mean=10.0),
            output=LengthDist("lognormal", lo=1, hi=8, mean=4.0))
            for i in range(2)),
        oversub=oversub, page_size=4, max_seqs=4, max_len=48,
        prefill_chunk=12, num_pages=None, admit_device_fraction=0.5)


@pytest.mark.parametrize("policy", CLUSTER_POLICIES)
def test_tp_serve_tokens_match_single_node(micro_model, policy):
    """The ISSUE acceptance gate: a TP-2 serve run on the two-superchip
    model generates tokens bit-identical to the single-node run of the
    same schedule, while the report shows real inter-node traffic."""
    from repro.serve import TrafficSim

    sc = _micro_scenario()
    base = TrafficSim(sc, policy="system", seed=3, models=micro_model).run()
    tp2 = TrafficSim(sc, policy=policy, hw="gh200_x2", seed=3,
                     models=micro_model, tp=2).run()
    assert tp2.tokens == base.tokens
    extra = tp2.per_engine["micro"]["um_report"]["traffic_extra"]
    assert extra["tp_allreduce_bytes"] > 0
    assert extra["internode_nvlink_bytes"] > 0
    # the collectives and inter-node pulls cost modeled time
    assert tp2.per_engine["micro"]["clock"] > base.per_engine["micro"]["clock"]


def test_tp_serve_is_deterministic(micro_model):
    from repro.serve import TrafficSim

    runs = [TrafficSim(_micro_scenario(1.5), policy="cluster_system",
                       hw="gh200_x2", seed=5, models=micro_model,
                       tp=2).run() for _ in range(2)]
    assert runs[0].tokens == runs[1].tokens
    assert (runs[0].per_engine["micro"]["clock"]
            == runs[1].per_engine["micro"]["clock"])


# ----------------------------------------------------------- trace replay
def test_replay_single_node_trace_under_cluster_backend(tmp_path):
    """A recorded single-node app stream re-charges under the cluster
    backend: on single-node hardware it matches the native cluster_system
    run bit-for-bit (N=1 degeneracy through the whole trace pipeline),
    and on gh200_x2 it completes with consistent residency accounting."""
    from repro.apps import APPS, charge_snapshot
    from repro.core.trace import record_app, replay

    path = tmp_path / "srad.trace"
    kw = dict(APPS["srad"].sizes["small"])
    record_app("srad", "system", path, **kw)

    native = APPS["srad"].run("cluster_system", **kw)
    um1 = replay(path, policy="cluster_system")
    snap = charge_snapshot(native)
    rep = um1.report()
    assert snap["phase_times"] == {
        k: float(v).hex() for k, v in sorted(um1.prof.phase_times.items())}
    assert snap["traffic_total"] == {
        k: int(v) for k, v in sorted(rep["traffic_total"].items())}

    um2 = replay(path, policy="cluster_system", hw="gh200_x2")
    assert um2._recompute_residency() == (um2.host_bytes(),
                                          um2.device_bytes())
    live = [a for a in um2.allocs.values() if not a.freed]
    for a in live:
        if a.table is not None:
            _, nb = a.table.recount()
            assert np.array_equal(nb, a.table._tier_bytes)
