"""Serving under memory pressure: preemption, chunked prefill, oversubscribed
pools, and the umem demote/async-prefetch APIs the scheduler drives."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    TPU_V5E,
    Actor,
    Tier,
    UnifiedMemory,
    system_policy,
)
from repro.models import init_params
from repro.models.cache import kv_head_layout
from repro.serve import PagedKVCache, SeqState, ServeEngine

KB = 1024


@pytest.fixture(scope="module")
def model():
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n=5):
    rng = np.random.default_rng(0)
    return [rng.integers(2, cfg.vocab_size, int(rng.integers(10, 30)))
            for _ in range(n)]


def _run(cfg, params, prompts, max_new=10, **kw):
    eng = ServeEngine(cfg, params, max_seqs=len(prompts), max_len=96,
                      page_size=8, **kw)
    for p in prompts:
        eng.add_request(p, max_new)
    return eng.run_to_completion(), eng


def test_preemption_resume_matches_unconstrained(model):
    """A pool too small for every admitted sequence forces preemption; the
    preempt -> demote -> resume cycle must not change a single token."""
    cfg, params = model
    prompts = _prompts(cfg)
    base, eng0 = _run(cfg, params, prompts)
    assert eng0.stats.preempted == 0
    tight, eng1 = _run(cfg, params, prompts, num_pages=10)
    assert eng1.stats.preempted > 0 and eng1.stats.resumed > 0
    assert all(tight[r] == base[r] for r in base)


def test_no_page_leak_across_many_requests(model):
    """release() returns every page: after many requests (with preemptions)
    the free list is back to its initial size and no slot stays active."""
    cfg, params = model
    prompts = _prompts(cfg, n=8)
    eng = ServeEngine(cfg, params, max_seqs=3, max_len=96, page_size=8,
                      num_pages=10)
    free0 = eng.cache.free_pages()
    for p in prompts:
        eng.add_request(p, 8)
    eng.run_to_completion()
    assert eng.cache.free_pages() == free0
    assert not eng.cache.active.any()
    assert (eng.cache.page_table == 0).all()
    assert sorted(eng.cache._free) == list(range(1, eng.cache.num_pages))


def test_chunked_prefill_bit_identical(model):
    """Prefilling 4 tokens per step must generate exactly the tokens of the
    single-shot prefill (each chunk attends over the pool-resident KV)."""
    cfg, params = model
    prompts = _prompts(cfg, n=3)
    base, _ = _run(cfg, params, prompts)
    chunked, eng = _run(cfg, params, prompts, prefill_chunk=4)
    assert eng.stats.prefill_chunks > len(prompts)  # really ran chunked
    assert all(chunked[r] == base[r] for r in base)


def test_oversubscribed_pool_serves_remotely(model):
    """Pool 1.5x the device capacity: serving completes (no pool-exhausted /
    OOM), tokens match the in-memory run, and some KV reads go remote."""
    cfg, params = model
    prompts = _prompts(cfg)
    base, eng0 = _run(cfg, params, prompts)
    # pool sized to the workload's peak concurrent demand, then a device
    # capacity of 2/3 of that: ~1/3 of the KV must live host-side
    num_pages = sum(-(-(len(p) + 10) // 8) for p in prompts) + 1
    pool_bytes = num_pages * eng0.cache.page_bytes
    hw = dataclasses.replace(TPU_V5E, device_capacity=int(pool_bytes / 1.5))
    um = UnifiedMemory(hw=hw)
    over, eng1 = _run(cfg, params, prompts, num_pages=num_pages, um=um)
    assert all(over[r] == base[r] for r in base)
    rep = um.report()
    assert rep["traffic_total"]["remote_h2d"] > 0  # really read host KV pages
    assert 0 < rep["remote_access_share"] < 1
    tbl = eng1.cache.alloc.table
    assert tbl.resident_bytes(Tier.DEVICE) <= hw.device_capacity


def test_admission_defers_under_device_pressure(model):
    """With a high admit_device_fraction and a tiny device, not every request
    is admitted in the first step — admission waits for pressure to drop."""
    cfg, params = model
    prompts = _prompts(cfg, n=4)
    pb = PagedKVCache.page_bytes_for(cfg, kv_head_layout(cfg, 1), 8)
    hw = dataclasses.replace(TPU_V5E, device_capacity=6 * pb)
    um = UnifiedMemory(hw=hw)
    eng = ServeEngine(cfg, params, max_seqs=4, max_len=96, page_size=8,
                      um=um, admit_device_fraction=1.0)
    for p in prompts:
        eng.add_request(p, 8)
    eng.step()
    states = [r.state for r in eng.requests.values()]
    assert SeqState.PENDING in states  # pressure gate deferred someone
    out = eng.run_to_completion()
    assert all(len(out[r.rid]) == 8 for r in eng.requests.values())


def test_umem_demote_moves_device_pages_host():
    um = UnifiedMemory()
    a = um.alloc("x", 512 * KB, system_policy(page_size=64 * KB))
    um.kernel(reads=[(a, 0, 512 * KB)], actor=Actor.GPU)  # first-touch: device
    assert a.table.resident_bytes(Tier.DEVICE) == 512 * KB
    d2h0 = um.prof.report()["traffic_total"]["link_d2h"]
    um.demote(a, 0, 256 * KB)
    assert a.table.resident_bytes(Tier.DEVICE) == 256 * KB
    assert a.table.resident_bytes(Tier.HOST) == 256 * KB
    assert um.prof.report()["traffic_total"]["link_d2h"] == d2h0 + 256 * KB
    # demoting an untouched (unmapped) range is a no-op
    b = um.alloc("y", 128 * KB, system_policy(page_size=64 * KB))
    um.demote(b, 0, 128 * KB)
    assert b.table.resident_pages(Tier.UNMAPPED) == b.table.num_pages


def test_umem_demote_drops_pending_notifications():
    """demote() cold-marks the range: pending counter notifications must be
    dropped so the next sync() doesn't promote the pages straight back."""
    um = UnifiedMemory()
    a = um.alloc("x", 128 * KB, system_policy(page_size=64 * KB, threshold=1))
    um.kernel(reads=[(a, 0, 128 * KB)], actor=Actor.CPU)  # host-resident
    um.kernel(reads=[(a, 0, 128 * KB)], actor=Actor.GPU)  # remote: pending
    assert a.pending_count == a.table.num_pages
    um.demote(a, 0, 128 * KB)
    assert a.pending_count == 0 and not a.pending.any()
    um.sync()
    assert a.table.resident_bytes(Tier.DEVICE) == 0  # nothing migrated back


def test_umem_prefetch_async_overlaps_next_kernel():
    um = UnifiedMemory()
    a = um.alloc("x", 256 * KB, system_policy(page_size=64 * KB))
    um.kernel(reads=[(a, 0, 256 * KB)], actor=Actor.CPU)  # host-resident
    hidden = um.prefetch_async([(a, 0, 128 * KB), (a, 128 * KB, 256 * KB)])
    assert hidden > 0
    assert um._pending_overlap == pytest.approx(hidden)
    assert a.table.resident_bytes(Tier.DEVICE) == 256 * KB
    t0 = um.clock
    um.kernel(reads=[(a, 0, 64 * KB)], actor=Actor.GPU)
    # the kernel charge absorbed the prefetch: charged max(kernel, prefetch)
    assert um.clock - t0 >= hidden
    assert um._pending_overlap == 0.0
