"""Property-based tests (hypothesis) for the unified-memory runtime invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    Actor,
    GRACE_HOPPER,
    OutOfDeviceMemory,
    Tier,
    UnifiedMemory,
    explicit_policy,
    managed_policy,
    system_policy,
)

KB = 1024
MB = 1024 * 1024

ranges_st = st.lists(
    st.tuples(st.integers(0, 63), st.integers(1, 64)), min_size=1, max_size=8)


@settings(max_examples=60, deadline=None)
@given(
    page_kb=st.sampled_from([4, 64]),
    nbytes=st.integers(1, 8 * MB),
    accesses=st.lists(
        st.tuples(st.sampled_from(["cpu", "gpu"]), st.floats(0, 1), st.floats(0, 1)),
        min_size=1, max_size=12),
)
def test_first_touch_and_residency(page_kb, nbytes, accesses):
    """Invariants: a page is mapped by its first toucher's tier; mapped pages
    never return to UNMAPPED; device usage never exceeds capacity."""
    um = UnifiedMemory()
    a = um.alloc("x", nbytes, system_policy(page_kb * KB))
    t = a.table
    first_toucher = np.full(t.num_pages, -1)
    for actor_s, f0, f1 in accesses:
        lo, hi = sorted((int(f0 * nbytes), int(f1 * nbytes)))
        if lo == hi:
            continue
        actor = Actor.GPU if actor_s == "gpu" else Actor.CPU
        p0, p1 = t.page_range(lo, hi)
        newly = [p for p in range(p0, p1) if first_toucher[p] < 0]
        um.kernel(reads=[(a, lo, hi)], actor=actor)
        for p in newly:
            first_toucher[p] = int(actor)
            assert t.tier[p] == int(actor.home_tier)
        um.sync()
        assert um.device_bytes() <= um.hw.device_capacity
    # mapped pages stay mapped
    touched = first_toucher >= 0
    assert (t.tier[touched] != int(Tier.UNMAPPED)).all()
    assert (t.tier[~touched] == int(Tier.UNMAPPED)).all()


@settings(max_examples=40, deadline=None)
@given(nbytes=st.integers(64 * KB, 4 * MB), n_kernels=st.integers(1, 8))
def test_counter_threshold_migration(nbytes, n_kernels):
    """System memory: pages migrate to device only after the access counter
    crosses the threshold, and counters reset after migration."""
    um = UnifiedMemory()
    a = um.alloc("x", nbytes, system_policy(64 * KB, threshold=256))
    um.kernel(writes=[(a, 0, nbytes)], actor=Actor.CPU)  # CPU first touch
    t = a.table
    for _ in range(n_kernels):
        um.kernel(reads=[(a, 0, nbytes)], actor=Actor.GPU)
        um.sync()
    # a full-page read = page_bytes/grain transactions >= threshold
    txn_per_pass = (64 * KB) // um.hw.remote_access_grain
    if txn_per_pass >= 256:
        assert t.resident_bytes(Tier.DEVICE) > 0
        moved = t.pages_in(Tier.DEVICE)
        assert (t.gpu_counter[moved] == 0).all()  # reset on migration


@settings(max_examples=30, deadline=None)
@given(ratio=st.floats(1.2, 4.0))
def test_oversubscription_policies(ratio):
    """Managed evicts to fit; system stays host-resident; explicit OOMs."""
    cap = GRACE_HOPPER.device_capacity
    nbytes = int(cap * ratio)
    # explicit: must OOM
    um = UnifiedMemory()
    with pytest.raises(OutOfDeviceMemory):
        um.alloc("x", nbytes, explicit_policy())
    # managed: GPU touch migrates + evicts, device never over capacity
    um = UnifiedMemory()
    a = um.alloc("x", nbytes, managed_policy())
    step = nbytes // 8
    for i in range(8):
        um.kernel(reads=[(a, i * step, (i + 1) * step)], actor=Actor.GPU)
        assert um.device_bytes() <= cap
    # system: no eviction pressure; CPU-initialized data stays host-resident
    um = UnifiedMemory()
    a = um.alloc("x", nbytes, system_policy(auto_migrate=False))
    um.kernel(writes=[(a, 0, nbytes)], actor=Actor.CPU)
    um.kernel(reads=[(a, 0, nbytes)], actor=Actor.GPU)
    assert a.table.resident_bytes(Tier.DEVICE) == 0
    assert um.device_bytes() <= cap


op_st = st.tuples(
    st.sampled_from(["kernel_r_gpu", "kernel_w_gpu", "kernel_r_cpu",
                     "kernel_w_cpu", "prefetch", "demote", "sync", "free"]),
    st.integers(0, 2),  # which allocation
    st.floats(0, 1), st.floats(0, 1))


@settings(max_examples=50, deadline=None)
@given(
    page_kb=st.sampled_from([4, 64]),
    sizes=st.lists(st.integers(1, 2 * MB), min_size=3, max_size=3),
    kinds=st.lists(st.sampled_from(["system", "managed"]), min_size=3,
                   max_size=3),
    cap_mb=st.integers(1, 4),
    ops=st.lists(op_st, min_size=1, max_size=25),
)
def test_randomized_ops_residency_and_run_dense_roundtrip(
        page_kb, sizes, kinds, cap_mb, ops):
    """Drive randomized op sequences (kernel/prefetch/demote/evict-under-
    pressure/free/sync) and assert after every op that

      * the runtime's cached _host_bytes/_device_bytes equal the slow-path
        _recompute_residency() re-derived from the run structures,
      * each table's cached per-tier counters equal its recount(),
      * the run-compressed tier state round-trips to the dense per-page
        materialization page-for-page (from_dense(to_dense) == state).
    """
    import dataclasses

    from repro.core import GRACE_HOPPER, RunMap

    hw = dataclasses.replace(GRACE_HOPPER,
                             device_capacity=cap_mb * MB)  # eviction pressure
    um = UnifiedMemory(hw=hw)
    allocs = []
    for i, (nbytes, kind) in enumerate(zip(sizes, kinds)):
        pol = (system_policy(page_kb * KB) if kind == "system"
               else managed_policy(page_kb * KB))
        allocs.append(um.alloc(f"a{i}", nbytes, pol))

    def check():
        assert (um.host_bytes(), um.device_bytes()) == um._recompute_residency()
        assert um.device_bytes() <= um.hw.device_capacity
        for a in allocs:
            if a.freed:
                continue
            pages, nbytes = a.table.recount()
            assert (pages == a.table._tier_pages).all()
            assert (nbytes == a.table._tier_bytes).all()
            for m in (a.table._tier, a.table._epoch, a.table._dirty,
                      a.table._gpu_counter, a.pending):
                m.check()
                rt = RunMap.from_dense(m.to_dense())
                assert (rt.starts == m.starts).all()
                assert (rt.vals == m.vals).all()

    for op, ai, f0, f1 in ops:
        a = allocs[ai]
        if a.freed:
            continue
        lo, hi = sorted((int(f0 * a.nbytes), int(f1 * a.nbytes)))
        if op == "free":
            um.free(a)
        elif op == "sync":
            um.sync()
        elif op == "prefetch":
            um.prefetch(a, lo, hi)
        elif op == "demote":
            um.demote(a, lo, hi)
        elif lo < hi:
            actor = Actor.GPU if op.endswith("gpu") else Actor.CPU
            key = "writes" if "_w_" in op else "reads"
            um.kernel(**{key: [(a, lo, hi)]}, actor=actor)
        check()
    for a in allocs:
        if not a.freed:
            um.free(a)
    assert um._recompute_residency() == (um.host_bytes(), um.device_bytes())


def test_gpu_first_touch_cost_page_size():
    """§5.1.2/§5.2: GPU-first-touch PTE init is ~page-count bound — 64KB pages
    cut init time ~16x vs 4KB."""
    times = {}
    for ps in (4 * KB, 64 * KB):
        um = UnifiedMemory()
        a = um.alloc("x", 64 * MB, system_policy(ps))
        with um.phase("gpu_init"):
            um.kernel(writes=[(a, 0, 64 * MB)], actor=Actor.GPU)
        times[ps] = um.prof.phase_times["gpu_init"]
    assert times[4 * KB] > 8 * times[64 * KB]


def test_dealloc_cost_page_size():
    """Fig. 6: de-allocation dominated by per-page cost at 4KB."""
    times = {}
    for ps in (4 * KB, 64 * KB):
        um = UnifiedMemory()
        a = um.alloc("x", 64 * MB, system_policy(ps))
        um.kernel(writes=[(a, 0, 64 * MB)], actor=Actor.CPU)
        with um.phase("dealloc"):
            um.free(a)
        times[ps] = um.prof.phase_times["dealloc"]
    assert times[4 * KB] > 8 * times[64 * KB]


def test_prefetch_places_on_device():
    um = UnifiedMemory()
    a = um.alloc("x", 8 * MB, managed_policy())
    um.kernel(writes=[(a, 0, 8 * MB)], actor=Actor.CPU)
    um.prefetch(a, 0, 8 * MB)
    assert a.table.resident_bytes(Tier.DEVICE) == 8 * MB
    tr = um.report()["traffic_total"]
    assert tr["migrated_in"] == 8 * MB
