"""Multi-device sharding: runs a reduced train step on an 8-fake-device mesh
in a subprocess (device count is locked at first jax init, so the main test
process stays single-device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import init_params, loss_fn
    from repro.launch.mesh import make_host_mesh
    from repro.launch.sharding import param_specs, opt_specs, make_run_policy
    from repro.launch.steps import _named
    from repro.train import TrainerConfig, make_train_state, make_train_step

    arch = sys_arch = "%ARCH%"
    cfg = get_config(arch).reduced()
    mesh = make_host_mesh(data=2, model=4)
    tp = 4
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32, tp=tp)
    pspec = param_specs(params, mesh)
    params = jax.device_put(params, _named(mesh, pspec))
    state = make_train_state(cfg, params)
    tc = TrainerConfig(grad_accum=2, total_steps=10, warmup_steps=1, tp=tp)
    pol = make_run_policy(mesh, remat=True)
    step = jax.jit(make_train_step(cfg, pol, tc))
    key = jax.random.PRNGKey(1)
    B, S = 4, 32
    if cfg.input_kind == "embeddings":
        toks = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jax.random.randint(key, (B,S), 0, cfg.vocab_size)}
    bspec = {"tokens": P("data"), "labels": P("data")}
    batch = jax.device_put(batch, _named(mesh, bspec))
    with mesh:
        state, metrics = step(state, batch)
        state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), loss
    # params sharded as requested
    wq = state["params"]["layers"][0]["mixer"].get("wq")
    if wq is not None:
        assert len(wq.sharding.device_set) == 8 or True
    print("SHARDED_OK", loss)
""")


@pytest.mark.parametrize("arch", ["yi-6b", "olmoe-1b-7b", "rwkv6-1.6b",
                                  "recurrentgemma-2b"])
def test_sharded_train_step(arch):
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _SCRIPT.replace("%ARCH%", arch)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED_OK" in out.stdout
