"""UM-backed oversubscribed training: offload plan, trainer, pressure events.

The spine of these tests is the subsystem's one invariant: the *math* is
real numpy with a fixed op order and the *memory system* is modeled, so
losses are bit-identical across every policy, oversubscription ratio,
checkpoint cadence and elastic resize — only the modeled clock and the
traffic counters may differ.
"""
import itertools
import tempfile

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import UnifiedMemory, make_policy
from repro.train import (Trainer, UMTrainer, capacity_for,
                         device_demand_bytes, get_train_model, state_bytes)

KB = 1024
TINY = get_train_model("train_tiny")

BACKENDS = [("system", None), ("managed", None), ("explicit", None),
            ("mi300a_unified", "mi300a"), ("cluster_system", "gh200_x2"),
            ("cluster_striped", "gh200_x2")]


def _total(um, field):
    return um.prof.report()["traffic_total"][field]


def _run(policy, hw=None, ratio=1.0, steps=3, **kw):
    tr = UMTrainer(TINY, policy=policy, hw=hw, ratio=ratio,
                   page_size=4 * KB, **kw)
    out = tr.run(steps)
    return tr, out


# ------------------------------------------------------------ satellite: clock
def test_trainer_accepts_injected_clock():
    """Trainer.run times steps with the injected clock (the UM runtime's
    modeled clock), not wall time."""
    ticks = itertools.count()
    clock = lambda: 5.0 * next(ticks)  # noqa: E731
    loader = iter([(i, {"labels": np.zeros((1, 1))}) for i in range(3)])
    step_fn = lambda state, batch: (state, {"loss": 1.5})  # noqa: E731
    tr = Trainer(None, {}, step_fn, loader, clock=clock)
    out = tr.run(3)
    assert [h["dt"] for h in out["history"]] == [5.0, 5.0, 5.0]
    assert all(h["loss"] == 1.5 for h in out["history"])


def test_trainer_default_clock_is_wall():
    import time
    tr = Trainer(None, {}, None, None)
    assert tr.clock is time.perf_counter


def test_umtrainer_clock_is_modeled():
    tr, out = _run("system")
    assert tr.now() == tr.um.clock
    assert out["modeled_s"] == pytest.approx(
        sum(h["dt"] for h in out["history"]))
    # the modeled clock is monotone across steps and far from wall time
    assert 0.0 < out["modeled_s"] < 1.0
    tr.close()


# ------------------------------------------------- satellite: loss bit-identity
@pytest.fixture(scope="module")
def ref_losses():
    tr, out = _run("system", ratio=1.0)
    tr.close()
    return out["losses"]


@pytest.mark.parametrize("policy,hw", [("system", None), ("managed", None),
                                       ("mi300a_unified", "mi300a")])
@pytest.mark.parametrize("ratio", [1.25, 1.5])
def test_loss_bit_identity_under_oversubscription(policy, hw, ratio,
                                                  ref_losses):
    tr, out = _run(policy, hw=hw, ratio=ratio)
    tr.close()
    assert out["losses"] == ref_losses, \
        f"{policy} x{ratio}: oversubscription changed the math"


def test_all_backends_bit_identical_and_symmetric(ref_losses):
    for policy, hw in BACKENDS:
        tr, out = _run(policy, hw=hw, ratio=1.5)
        assert out["losses"] == ref_losses, f"{policy}: losses diverged"
        tr.close()
        assert (tr.um.host_bytes(), tr.um.device_bytes()) == (0, 0), \
            f"{policy}: training state leaked across close()"


def test_oversubscription_costs_time_not_loss():
    """Under the fault-driven backend a smaller device means migration +
    eviction traffic: the modeled step time must grow with the ratio while
    the losses stay bit-identical (the fig11-style tradeoff)."""
    tr1, out1 = _run("managed", ratio=1.0)
    tr2, out2 = _run("managed", ratio=1.5)
    assert out2["losses"] == out1["losses"]
    assert out2["modeled_s"] > out1["modeled_s"]
    assert _total(tr2.um, "migrated_out") > _total(tr1.um, "migrated_out")
    tr1.close()
    tr2.close()


# ----------------------------------------------------- capacity-axis semantics
def test_capacity_floors():
    demand = device_demand_bytes(TINY)
    sysp = make_policy("system", page_size=4 * KB)
    assert capacity_for(TINY, sysp, 1.0) >= demand
    assert capacity_for(TINY, sysp, 2.0) < demand  # migratable: shrinks
    # the non-migratable single pool cannot hold less than the whole tree
    mi = make_policy("mi300a_unified", page_size=4 * KB)
    assert capacity_for(TINY, mi, 4.0) == state_bytes(TINY)
    # the staged port keeps at least its slab set on device
    ex = make_policy("explicit", page_size=4 * KB)
    assert capacity_for(TINY, ex, 100.0) > 0


def test_eff_ratio_reports_modeled_capacity():
    tr, out = _run("system", ratio=1.5)
    tr.close()
    assert out["eff_ratio"] == pytest.approx(1.5, rel=0.05)
    # mi300a floors at the full state tree: eff_ratio honestly reports < 1
    tr, out = _run("mi300a_unified", hw="mi300a", ratio=1.5)
    tr.close()
    assert out["eff_ratio"] < 1.0
    assert out["capacity"] == state_bytes(TINY)


# ------------------------------------------------- satellite: checkpoint drain
def test_checkpoint_save_is_pure_pressure_event():
    """A mid-oversubscription save charges a d2h drain on the modeled
    clock but neither leaks pages nor perturbs any subsequent step's
    charges: the twin run with checkpointing shows bit-identical losses
    AND bit-identical per-step dts."""
    with tempfile.TemporaryDirectory() as tmp:
        tr_a, out_a = _run("managed", ratio=1.5, steps=4)
        cm = CheckpointManager(tmp, async_save=False)
        tr_b = UMTrainer(TINY, policy="managed", ratio=1.5, page_size=4 * KB)
        out_b = tr_b.run(4, ckpt=cm, ckpt_every=2)
        assert out_b["losses"] == out_a["losses"]
        dts_a = [h["dt"] for h in out_a["history"]]
        dts_b = [h["dt"] for h in out_b["history"]]
        # bit-identical before the first save; after it the drain has
        # offset the absolute clock, so dt = clock - t0 may differ in the
        # last ulp of the subtraction — but by nothing more
        assert dts_b[:2] == dts_a[:2]
        np.testing.assert_allclose(dts_b, dts_a, rtol=1e-12, atol=0.0,
                                   err_msg="drain perturbed later charges")
        # the drain itself is charged: the checkpointing run's clock is
        # strictly behind, and it moved real d2h bytes
        assert tr_b.um.clock > tr_a.um.clock
        assert _total(tr_b.um, "link_d2h") > _total(tr_a.um, "link_d2h")
        assert [e["kind"] for e in out_b["events"]] \
            == ["checkpoint", "checkpoint"]
        tr_a.close()
        tr_b.close()
        assert (tr_b.um.host_bytes(), tr_b.um.device_bytes()) == (0, 0)


def test_checkpoint_restore_roundtrip():
    """restore + continue reproduces the uninterrupted run bit-for-bit
    (deterministic batches are keyed on the restored step count)."""
    with tempfile.TemporaryDirectory() as tmp:
        tr_full, out_full = _run("system", ratio=1.5, steps=4)
        tr_full.close()

        cm = CheckpointManager(tmp, async_save=False)
        tr_head = UMTrainer(TINY, policy="system", ratio=1.5,
                            page_size=4 * KB)
        tr_head.run(2)
        tr_head.save_checkpoint(cm)
        tr_head.close()

        tr_tail = UMTrainer(TINY, policy="system", ratio=1.5,
                            page_size=4 * KB)
        got = tr_tail.restore_checkpoint(cm)
        assert got == 2
        out_tail = tr_tail.run(2)
        assert out_tail["losses"] == out_full["losses"][2:]
        tr_tail.close()


def test_drain_dirty_moves_no_pages():
    """umem.drain_dirty charges the d2h writeback of dirty device runs but
    mutates nothing: residency, page tiers and dirty bits are untouched."""
    from repro.core import Actor
    um = UnifiedMemory()
    a = um.alloc("d", 64 * KB, make_policy("system", page_size=4 * KB))
    um.kernel(writes=[(a, 0, 64 * KB)], actor=Actor.GPU, name="w")
    um.sync()
    res = (um.host_bytes(), um.device_bytes())
    tiers = a.table._tier.runs()
    dirty = a.table._dirty.runs()
    clock = um.clock
    moved = um.drain_dirty([(a, 0, 64 * KB)])
    assert moved > 0
    assert um.clock > clock
    assert (um.host_bytes(), um.device_bytes()) == res
    assert all(np.array_equal(x, y)
               for x, y in zip(a.table._tier.runs(), tiers))
    assert all(np.array_equal(x, y)
               for x, y in zip(a.table._dirty.runs(), dirty))
    # second drain charges the same bytes again: nothing was cleared
    assert um.drain_dirty([(a, 0, 64 * KB)]) == moved
    um.free(a)


# --------------------------------------------------- satellite: elastic resize
def test_elastic_resize_is_pressure_not_math():
    """Shrinking the device mid-run through runtime.elastic forces real
    eviction traffic under the fault-driven backend without touching the
    losses."""
    tr_a, out_a = _run("managed", ratio=1.0, steps=6)
    shrunk = capacity_for(TINY, make_policy("managed", page_size=4 * KB), 2.0)
    tr_b = UMTrainer(TINY, policy="managed", ratio=1.0, page_size=4 * KB)
    out_b = tr_b.run(6, resize_at={3: shrunk})
    assert out_b["losses"] == out_a["losses"], "elastic resize changed math"
    assert [e["kind"] for e in out_b["events"]] == ["resize"]
    assert tr_b.um.hw.device_capacity == shrunk
    assert _total(tr_b.um, "migrated_out") > _total(tr_a.um, "migrated_out"), \
        "shrink produced no eviction traffic"
    assert out_b["modeled_s"] > out_a["modeled_s"]
    tr_a.close()
    tr_b.close()
    assert (tr_b.um.host_bytes(), tr_b.um.device_bytes()) == (0, 0)


def test_elastic_resize_grow_restores_speed():
    """Grow back after a shrink: later steps stop paying eviction traffic
    (dt falls back toward the unshrunk profile) and losses never move."""
    tr_a, out_a = _run("system", ratio=1.0, steps=6)
    pol = make_policy("system", page_size=4 * KB)
    small = capacity_for(TINY, pol, 2.0)
    big = capacity_for(TINY, pol, 1.0)
    tr_b = UMTrainer(TINY, policy="system", ratio=1.0, page_size=4 * KB)
    out_b = tr_b.run(6, resize_at={2: small, 4: big})
    assert out_b["losses"] == out_a["losses"]
    assert [(e["kind"], e["capacity"]) for e in out_b["events"]] \
        == [("resize", small), ("resize", big)]
    tr_a.close()
    tr_b.close()


# ------------------------------------------------------------- node-aware path
def test_cluster_training_spreads_layers():
    """Node-aware backends round-robin the layers: both superchips see
    device-side residency during the run."""
    from repro.cluster import device_used_on
    tr = UMTrainer(TINY, policy="cluster_system", hw="gh200_x2", ratio=1.0,
                   page_size=4 * KB)
    tr.run(2)
    used = [device_used_on(tr.um, k) for k in range(tr.um.hw.nodes)]
    assert all(u > 0 for u in used), \
        f"layer round-robin left a node idle: {used}"
    tr.close()
