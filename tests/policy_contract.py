"""Policy-conformance contract: invariants every MemPolicy backend must keep.

The checks here are *reusable* — tests/test_policy_contract.py runs them
against every policy in the registry (including out-of-tree backends a
contributor registers before importing the suite), so "write a backend,
register it, run pytest" gives conformance coverage for free.

Contracts:

* **alloc/free symmetry** — after alloc -> touch (CPU and GPU) -> free, the
  runtime's host/device residency totals return to their pre-alloc values.
* **residency cache == recount** — after a randomized op sequence (kernels
  from both actors, prefetch/demote where paged, sync), the incrementally
  maintained totals equal a from-scratch recount of every table.
* **no charge on freed allocations** — kernel access to a freed allocation
  raises and leaves the modeled clock untouched.
* **serve-path pool symmetry** — the KV pool keeps alloc/free symmetric
  through a FULL serve-engine run (admission, chunked prefill,
  paged-attention decode, release, pool close), not just a bare touch;
  a table-less backend must be refused by the pool up front.
"""
from __future__ import annotations

import numpy as np

from repro.core import Actor, UnifiedMemory, make_policy

KB = 1024
NBYTES = 512 * KB


def _touch_both_sides(um, a, nbytes):
    um.kernel(writes=[(a, 0, nbytes)], actor=Actor.CPU, name="cpu_init")
    um.kernel(reads=[(a, 0, nbytes)], actor=Actor.GPU, name="gpu_read")
    um.sync()


def check_alloc_free_symmetry(policy) -> None:
    um = UnifiedMemory()
    base = (um.host_bytes(), um.device_bytes())
    a = um.alloc("sym", NBYTES, policy)
    _touch_both_sides(um, a, NBYTES)
    um.free(a)
    assert (um.host_bytes(), um.device_bytes()) == base, \
        f"{policy.kind}: residency leaked across free"


def check_residency_cache_matches_recount(policy, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    um = UnifiedMemory()
    allocs = [um.alloc(f"r{i}", NBYTES, policy) for i in range(3)]
    for _ in range(40):
        a = allocs[int(rng.integers(len(allocs)))]
        lo = int(rng.integers(0, NBYTES - 1)) & ~0xFFF
        hi = min(NBYTES, lo + int(rng.integers(1, NBYTES // 4)))
        op = int(rng.integers(5))
        if op == 0:
            um.kernel(writes=[(a, lo, hi)], actor=Actor.CPU, name="w")
        elif op == 1:
            um.kernel(reads=[(a, lo, hi)], actor=Actor.GPU, name="r")
        elif op == 2 and a.table is not None:
            um.prefetch(a, lo, hi)
        elif op == 3 and a.table is not None:
            um.demote(a, lo, hi)
        else:
            um.sync()
        assert um._recompute_residency() == (um.host_bytes(),
                                             um.device_bytes()), \
            f"{policy.kind}: cached residency drifted from recount"
    for a in allocs:
        um.free(a)
    assert um._recompute_residency() == (um.host_bytes(), um.device_bytes())


def check_no_charge_on_freed(policy) -> None:
    um = UnifiedMemory()
    a = um.alloc("gone", NBYTES, policy)
    _touch_both_sides(um, a, NBYTES)
    um.free(a)
    clock = um.clock
    try:
        um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, name="use_after_free")
    except AssertionError:
        pass
    else:
        raise AssertionError(f"{policy.kind}: kernel over a freed allocation "
                             "did not raise")
    assert um.clock == clock, \
        f"{policy.kind}: freed allocation was charged"


def check_serve_pool_symmetry(policy, seed: int = 0) -> None:
    """Serve-path clause: pool alloc/free symmetry through a full engine
    run. Several requests churn through a 2-slot engine (admission,
    chunked prefill, paged-attention decode, release); afterwards the page
    accounting must be back to empty and closing the pool must return the
    runtime's residency to its pre-pool baseline. Backends without a page
    table cannot back the pool at all — the contract there is that the
    pool refuses them up front, leaving no residency behind."""
    import dataclasses

    import jax

    from repro.configs.base import ArchConfig
    from repro.models import init_params
    from repro.models.cache import kv_head_layout
    from repro.serve import PagedKVCache, ServeEngine

    cfg = ArchConfig(name="contract-micro", family="dense", source="contract",
                     num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                     head_dim=16, d_ff=64, vocab_size=64)
    lay = kv_head_layout(cfg, 1)
    um = UnifiedMemory()
    base = (um.host_bytes(), um.device_bytes())
    if not policy.paged:
        try:
            PagedKVCache(cfg, lay, max_seqs=2, max_len=16, page_size=4,
                         um=um, mem_policy=policy)
        except AssertionError:
            assert (um.host_bytes(), um.device_bytes()) == base
            return
        raise AssertionError(
            f"{policy.kind}: table-less backend accepted for the KV pool")
    # rebuild at pool-page granularity the way PagedKVCache itself would:
    # through the registry factory when the kind is registered (knobs stay
    # coherent), dataclasses.replace otherwise (out-of-tree instances)
    page_bytes = PagedKVCache.page_bytes_for(cfg, lay, 4)
    try:
        pool_policy = make_policy(policy.kind, page_size=page_bytes)
    except KeyError:
        pool_policy = dataclasses.replace(policy, page_size=page_bytes)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    eng = ServeEngine(cfg, params, max_seqs=2, max_len=24, page_size=4,
                      num_pages=8, prefill_chunk=6, um=um,
                      mem_policy=pool_policy)
    rng = np.random.default_rng(seed)
    for _ in range(4):
        eng.add_request(rng.integers(2, cfg.vocab_size, int(rng.integers(4, 10))),
                        max_new_tokens=3)
    eng.run_to_completion()
    assert eng.cache.free_pages() == eng.cache.num_pages - 1, \
        f"{policy.kind}: KV pool pages leaked across the engine run"
    assert not eng.cache.active.any()
    assert (eng.cache.page_table == 0).all()
    eng.cache.close()
    assert (um.host_bytes(), um.device_bytes()) == base, \
        f"{policy.kind}: serve pool residency leaked across the engine " \
        "run + close()"


CONTRACTS = (
    check_alloc_free_symmetry,
    check_residency_cache_matches_recount,
    check_no_charge_on_freed,
    check_serve_pool_symmetry,
)
