"""Policy-conformance contract: invariants every MemPolicy backend must keep.

The checks here are *reusable* — tests/test_policy_contract.py runs them
against every policy in the registry (including out-of-tree backends a
contributor registers before importing the suite), so "write a backend,
register it, run pytest" gives conformance coverage for free.

Contracts:

* **alloc/free symmetry** — after alloc -> touch (CPU and GPU) -> free, the
  runtime's host/device residency totals return to their pre-alloc values.
* **residency cache == recount** — after a randomized op sequence (kernels
  from both actors, prefetch/demote where paged, sync), the incrementally
  maintained totals equal a from-scratch recount of every table.
* **no charge on freed allocations** — kernel access to a freed allocation
  raises and leaves the modeled clock untouched.
* **serve-path pool symmetry** — the KV pool keeps alloc/free symmetric
  through a FULL serve-engine run (admission, chunked prefill,
  paged-attention decode, release, pool close), not just a bare touch;
  a table-less backend must be refused by the pool up front.
* **train-path state symmetry** — the UM-backed training state tree keeps
  alloc/free symmetry through a full UMTrainer run (init, phased steps
  with placement hints, close); see check_train_state_symmetry.
"""
from __future__ import annotations

import numpy as np

from repro.core import Actor, UnifiedMemory, make_policy

KB = 1024
NBYTES = 512 * KB


def _touch_both_sides(um, a, nbytes):
    um.kernel(writes=[(a, 0, nbytes)], actor=Actor.CPU, name="cpu_init")
    um.kernel(reads=[(a, 0, nbytes)], actor=Actor.GPU, name="gpu_read")
    um.sync()


def check_alloc_free_symmetry(policy) -> None:
    um = UnifiedMemory()
    base = (um.host_bytes(), um.device_bytes())
    a = um.alloc("sym", NBYTES, policy)
    _touch_both_sides(um, a, NBYTES)
    um.free(a)
    assert (um.host_bytes(), um.device_bytes()) == base, \
        f"{policy.kind}: residency leaked across free"


def check_residency_cache_matches_recount(policy, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    um = UnifiedMemory()
    allocs = [um.alloc(f"r{i}", NBYTES, policy) for i in range(3)]
    for _ in range(40):
        a = allocs[int(rng.integers(len(allocs)))]
        lo = int(rng.integers(0, NBYTES - 1)) & ~0xFFF
        hi = min(NBYTES, lo + int(rng.integers(1, NBYTES // 4)))
        op = int(rng.integers(5))
        if op == 0:
            um.kernel(writes=[(a, lo, hi)], actor=Actor.CPU, name="w")
        elif op == 1:
            um.kernel(reads=[(a, lo, hi)], actor=Actor.GPU, name="r")
        elif op == 2 and a.table is not None:
            um.prefetch(a, lo, hi)
        elif op == 3 and a.table is not None:
            um.demote(a, lo, hi)
        else:
            um.sync()
        assert um._recompute_residency() == (um.host_bytes(),
                                             um.device_bytes()), \
            f"{policy.kind}: cached residency drifted from recount"
    for a in allocs:
        um.free(a)
    assert um._recompute_residency() == (um.host_bytes(), um.device_bytes())


def check_no_charge_on_freed(policy) -> None:
    um = UnifiedMemory()
    a = um.alloc("gone", NBYTES, policy)
    _touch_both_sides(um, a, NBYTES)
    um.free(a)
    clock = um.clock
    try:
        um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, name="use_after_free")
    except AssertionError:
        pass
    else:
        raise AssertionError(f"{policy.kind}: kernel over a freed allocation "
                             "did not raise")
    assert um.clock == clock, \
        f"{policy.kind}: freed allocation was charged"


def check_serve_pool_symmetry(policy, seed: int = 0) -> None:
    """Serve-path clause: pool alloc/free symmetry through a full engine
    run. Several requests churn through a 2-slot engine (admission,
    chunked prefill, paged-attention decode, release); afterwards the page
    accounting must be back to empty and closing the pool must return the
    runtime's residency to its pre-pool baseline. Backends without a page
    table cannot back the pool at all — the contract there is that the
    pool refuses them up front, leaving no residency behind."""
    import dataclasses

    import jax

    from repro.configs.base import ArchConfig
    from repro.models import init_params
    from repro.models.cache import kv_head_layout
    from repro.serve import PagedKVCache, ServeEngine

    cfg = ArchConfig(name="contract-micro", family="dense", source="contract",
                     num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                     head_dim=16, d_ff=64, vocab_size=64)
    lay = kv_head_layout(cfg, 1)
    um = UnifiedMemory()
    base = (um.host_bytes(), um.device_bytes())
    if not policy.paged:
        try:
            PagedKVCache(cfg, lay, max_seqs=2, max_len=16, page_size=4,
                         um=um, mem_policy=policy)
        except AssertionError:
            assert (um.host_bytes(), um.device_bytes()) == base
            return
        raise AssertionError(
            f"{policy.kind}: table-less backend accepted for the KV pool")
    # rebuild at pool-page granularity the way PagedKVCache itself would:
    # through the registry factory when the kind is registered (knobs stay
    # coherent), dataclasses.replace otherwise (out-of-tree instances)
    page_bytes = PagedKVCache.page_bytes_for(cfg, lay, 4)
    try:
        pool_policy = make_policy(policy.kind, page_size=page_bytes)
    except KeyError:
        pool_policy = dataclasses.replace(policy, page_size=page_bytes)
    params = init_params(cfg, jax.random.PRNGKey(seed))
    eng = ServeEngine(cfg, params, max_seqs=2, max_len=24, page_size=4,
                      num_pages=8, prefill_chunk=6, um=um,
                      mem_policy=pool_policy)
    rng = np.random.default_rng(seed)
    for _ in range(4):
        eng.add_request(rng.integers(2, cfg.vocab_size, int(rng.integers(4, 10))),
                        max_new_tokens=3)
    eng.run_to_completion()
    assert eng.cache.free_pages() == eng.cache.num_pages - 1, \
        f"{policy.kind}: KV pool pages leaked across the engine run"
    assert not eng.cache.active.any()
    assert (eng.cache.page_table == 0).all()
    eng.cache.close()
    assert (um.host_bytes(), um.device_bytes()) == base, \
        f"{policy.kind}: serve pool residency leaked across the engine " \
        "run + close()"


def check_train_state_symmetry(policy, seed: int = 0) -> None:
    """Training-path clause: the UM-backed training state tree (params,
    grads, AdamW moments, master weights, activation stash, io/scratch)
    keeps alloc/free symmetry through a FULL training run — init first
    touch, two optimizer steps' worth of phased launches with placement
    hints, close(). Every registered backend must come back to the
    pre-trainer residency baseline; the staged (table-less) port exercises
    its slab + host-blob split on the same path."""
    from repro.train import UMTrainer, get_train_model

    um = UnifiedMemory()
    base = (um.host_bytes(), um.device_bytes())
    tr = UMTrainer(get_train_model("train_tiny"), policy=policy,
                   um=um, seed=seed)
    out = tr.run(2)
    assert len(out["losses"]) == 2 and out["modeled_s"] > 0.0
    tr.close()
    assert (um.host_bytes(), um.device_bytes()) == base, \
        f"{policy.kind}: training state residency leaked across close()"
    assert um._recompute_residency() == base, \
        f"{policy.kind}: cached residency drifted across the training run"


CONTRACTS = (
    check_alloc_free_symmetry,
    check_residency_cache_matches_recount,
    check_no_charge_on_freed,
    check_serve_pool_symmetry,
    check_train_state_symmetry,
)


# --------------------------------------------------- cluster (node-aware) clause
# Extra invariants for node-aware backends, run on a multi-superchip
# hardware model. The single-node CONTRACTS above already cover these
# policies at N=1; this clause is the N>1 counterpart.

def _cluster_um():
    from repro.cluster import GH200_X2

    return UnifiedMemory(hw=GH200_X2)


def check_cluster_per_node_recount(policy, seed: int = 0) -> None:
    """Per-node residency cache == recount: after a randomized op sequence
    issued from rotating nodes (kernels from both actors, prefetch/demote,
    sync), every live table's cached per-(node, tier) byte counters equal
    a from-scratch recount of its run structure, and the global host/device
    caches agree with the runtime's own recount."""
    rng = np.random.default_rng(seed)
    um = _cluster_um()
    nn = um.hw.nodes
    allocs = [um.alloc(f"c{i}", NBYTES, policy) for i in range(3)]
    for _ in range(40):
        a = allocs[int(rng.integers(len(allocs)))]
        lo = int(rng.integers(0, NBYTES - 1)) & ~0xFFF
        hi = min(NBYTES, lo + int(rng.integers(1, NBYTES // 4)))
        op = int(rng.integers(5))
        with um.on_node(int(rng.integers(nn))):
            if op == 0:
                um.kernel(writes=[(a, lo, hi)], actor=Actor.CPU, name="w")
            elif op == 1:
                um.kernel(reads=[(a, lo, hi)], actor=Actor.GPU, name="r")
            elif op == 2:
                um.prefetch(a, lo, hi)
            elif op == 3:
                um.demote(a, lo, hi)
            else:
                um.sync()
        for t in (x.table for x in allocs):
            _, nbytes = t.recount()
            assert np.array_equal(nbytes, t._tier_bytes), \
                f"{policy.kind}: per-(node,tier) counters drifted from recount"
        assert um._recompute_residency() == (um.host_bytes(),
                                             um.device_bytes()), \
            f"{policy.kind}: global residency drifted under multi-node ops"
    for a in allocs:
        um.free(a)
    assert um._recompute_residency() == (um.host_bytes(), um.device_bytes())


def check_cluster_per_node_alloc_free_symmetry(policy) -> None:
    """alloc -> every node touches its own slice -> free: each node's
    device-resident byte count returns to its pre-alloc value."""
    from repro.cluster import device_used_on

    um = _cluster_um()
    nn = um.hw.nodes
    base = [device_used_on(um, k) for k in range(nn)]
    gbase = (um.host_bytes(), um.device_bytes())
    a = um.alloc("nsym", NBYTES, policy)
    step = NBYTES // nn
    for k in range(nn):
        with um.on_node(k):
            um.kernel(writes=[(a, k * step, (k + 1) * step)],
                      actor=Actor.GPU, name=f"touch_n{k}")
    um.sync()
    assert sum(device_used_on(um, k) for k in range(nn)) > sum(base), \
        f"{policy.kind}: GPU first touch placed nothing on any device"
    um.free(a)
    assert [device_used_on(um, k) for k in range(nn)] == base, \
        f"{policy.kind}: per-node device residency leaked across free"
    assert (um.host_bytes(), um.device_bytes()) == gbase


def check_cluster_no_internode_charge_after_free(policy) -> None:
    """Inter-node lanes stay quiet after free: a kernel over a freed
    allocation raises and leaves the clock AND the inter-node side
    counters untouched."""
    um = _cluster_um()
    a = um.alloc("gone", NBYTES, policy)
    with um.on_node(1):
        um.kernel(writes=[(a, 0, NBYTES)], actor=Actor.GPU, name="far_init")
    # node 0 reading node 1's placement crosses a link: the lanes charge
    um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, name="near_read")
    um.sync()
    assert um.prof.extra["internode_nvlink_bytes"] > 0, \
        f"{policy.kind}: cross-node read never hit the inter-node NVLink lane"
    um.free(a)
    clock = um.clock
    extra = dict(um.prof.extra)
    try:
        um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU,
                  name="use_after_free")
    except AssertionError:
        pass
    else:
        raise AssertionError(f"{policy.kind}: kernel over a freed allocation "
                             "did not raise on the cluster model")
    assert um.clock == clock and dict(um.prof.extra) == extra, \
        f"{policy.kind}: freed allocation charged time or inter-node bytes"


def check_cluster_node_loss_drain(policy, seed: int = 0) -> None:
    """Fault clause: after ``um.fail_node`` poisons a node and the
    survivors keep operating, (a) nothing is ever resident on — or charged
    against — the dead node again, (b) every table's cached per-(node,
    tier) counters still equal a from-scratch recount, and (c) the global
    residency caches stay exact through the drain and a final free."""
    from repro.cluster import device_free_on

    rng = np.random.default_rng(seed)
    um = _cluster_um()
    nn = um.hw.nodes
    dead = nn - 1
    allocs = [um.alloc(f"f{i}", NBYTES, policy) for i in range(3)]
    step = NBYTES // nn
    for a in allocs:
        for k in range(nn):
            with um.on_node(k):
                um.kernel(writes=[(a, k * step, (k + 1) * step)],
                          actor=Actor.GPU, name=f"seed_n{k}")
    um.sync()

    lost = um.fail_node(dead)
    assert lost, f"{policy.kind}: node loss drained no pages " \
        "(every node first-touched its own slice)"

    def dead_bytes():
        return sum(int(a.table._tier_bytes[2 * dead + L + 1])
                   for a in allocs for L in (0, 1))

    assert dead_bytes() == 0, \
        f"{policy.kind}: pages still resident on the dead node after drain"
    assert device_free_on(um, dead) == 0, \
        f"{policy.kind}: dead node still advertises placeable capacity"

    alive = [k for k in range(nn) if k != dead]
    for _ in range(30):
        a = allocs[int(rng.integers(len(allocs)))]
        lo = int(rng.integers(0, NBYTES - 1)) & ~0xFFF
        hi = min(NBYTES, lo + int(rng.integers(1, NBYTES // 4)))
        op = int(rng.integers(5))
        with um.on_node(alive[int(rng.integers(len(alive)))]):
            if op == 0:
                um.kernel(writes=[(a, lo, hi)], actor=Actor.CPU, name="w")
            elif op == 1:
                um.kernel(reads=[(a, lo, hi)], actor=Actor.GPU, name="r")
            elif op == 2:
                um.prefetch(a, lo, hi)
            elif op == 3:
                um.demote(a, lo, hi)
            else:
                um.sync()
        assert dead_bytes() == 0, \
            f"{policy.kind}: survivor traffic landed on the dead node"
        for t in (x.table for x in allocs):
            _, nbytes = t.recount()
            assert np.array_equal(nbytes, t._tier_bytes), \
                f"{policy.kind}: counters drifted from recount post-loss"
        assert um._recompute_residency() == (um.host_bytes(),
                                             um.device_bytes()), \
            f"{policy.kind}: global residency drifted after node loss"
    for a in allocs:
        um.free(a)
    assert um._recompute_residency() == (um.host_bytes(), um.device_bytes())


CLUSTER_CONTRACTS = (
    check_cluster_per_node_recount,
    check_cluster_per_node_alloc_free_symmetry,
    check_cluster_no_internode_charge_after_free,
    check_cluster_node_loss_drain,
)
