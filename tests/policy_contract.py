"""Policy-conformance contract: invariants every MemPolicy backend must keep.

The checks here are *reusable* — tests/test_policy_contract.py runs them
against every policy in the registry (including out-of-tree backends a
contributor registers before importing the suite), so "write a backend,
register it, run pytest" gives conformance coverage for free.

Contracts:

* **alloc/free symmetry** — after alloc -> touch (CPU and GPU) -> free, the
  runtime's host/device residency totals return to their pre-alloc values.
* **residency cache == recount** — after a randomized op sequence (kernels
  from both actors, prefetch/demote where paged, sync), the incrementally
  maintained totals equal a from-scratch recount of every table.
* **no charge on freed allocations** — kernel access to a freed allocation
  raises and leaves the modeled clock untouched.
"""
from __future__ import annotations

import numpy as np

from repro.core import Actor, UnifiedMemory

KB = 1024
NBYTES = 512 * KB


def _touch_both_sides(um, a, nbytes):
    um.kernel(writes=[(a, 0, nbytes)], actor=Actor.CPU, name="cpu_init")
    um.kernel(reads=[(a, 0, nbytes)], actor=Actor.GPU, name="gpu_read")
    um.sync()


def check_alloc_free_symmetry(policy) -> None:
    um = UnifiedMemory()
    base = (um.host_bytes(), um.device_bytes())
    a = um.alloc("sym", NBYTES, policy)
    _touch_both_sides(um, a, NBYTES)
    um.free(a)
    assert (um.host_bytes(), um.device_bytes()) == base, \
        f"{policy.kind}: residency leaked across free"


def check_residency_cache_matches_recount(policy, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    um = UnifiedMemory()
    allocs = [um.alloc(f"r{i}", NBYTES, policy) for i in range(3)]
    for _ in range(40):
        a = allocs[int(rng.integers(len(allocs)))]
        lo = int(rng.integers(0, NBYTES - 1)) & ~0xFFF
        hi = min(NBYTES, lo + int(rng.integers(1, NBYTES // 4)))
        op = int(rng.integers(5))
        if op == 0:
            um.kernel(writes=[(a, lo, hi)], actor=Actor.CPU, name="w")
        elif op == 1:
            um.kernel(reads=[(a, lo, hi)], actor=Actor.GPU, name="r")
        elif op == 2 and a.table is not None:
            um.prefetch(a, lo, hi)
        elif op == 3 and a.table is not None:
            um.demote(a, lo, hi)
        else:
            um.sync()
        assert um._recompute_residency() == (um.host_bytes(),
                                             um.device_bytes()), \
            f"{policy.kind}: cached residency drifted from recount"
    for a in allocs:
        um.free(a)
    assert um._recompute_residency() == (um.host_bytes(), um.device_bytes())


def check_no_charge_on_freed(policy) -> None:
    um = UnifiedMemory()
    a = um.alloc("gone", NBYTES, policy)
    _touch_both_sides(um, a, NBYTES)
    um.free(a)
    clock = um.clock
    try:
        um.kernel(reads=[(a, 0, NBYTES)], actor=Actor.GPU, name="use_after_free")
    except AssertionError:
        pass
    else:
        raise AssertionError(f"{policy.kind}: kernel over a freed allocation "
                             "did not raise")
    assert um.clock == clock, \
        f"{policy.kind}: freed allocation was charged"


CONTRACTS = (
    check_alloc_free_symmetry,
    check_residency_cache_matches_recount,
    check_no_charge_on_freed,
)
