"""Tier-1 guard on the golden charge-parity fixture.

scripts/check_parity.py verifies all 66 fig3/fig11 configurations (CI runs
it as its own job); this pins a cheap representative subset — every app
class (CPU-init regular/irregular, GPU-init, graph), every policy, and
oversubscribed cases — so modeled-charge drift fails fast in tier-1."""
import json
from pathlib import Path

import pytest

from repro.apps import APPS, charge_snapshot

FIXTURE = Path(__file__).parent / "fixtures" / "parity.json"
KB = 1024

SUBSET = [
    "fig3/hotspot/explicit",
    "fig3/hotspot/system",
    "fig3/srad/managed",
    "fig3/bfs/system",
    "fig3/pathfinder/explicit",
    "fig11/hotspot/oversub1.5/managed",
    "fig11/needle/oversub2.0/system",
    "fig11/srad/oversub3.0/managed",
]


def _config(key: str):
    parts = key.split("/")
    if parts[0] == "fig3":
        _, app, pol = parts
        return app, pol, dict(APPS[app].sizes["fig3"])
    _, app, ratio, pol = parts
    return app, pol, dict(APPS[app].sizes["fig11"],
                          oversub_ratio=float(ratio[len("oversub"):]),
                          page_size=4 * KB)


@pytest.fixture(scope="module")
def fixture():
    assert FIXTURE.exists(), "run scripts/check_parity.py --write first"
    return json.loads(FIXTURE.read_text())


def test_fixture_covers_all_66_configs(fixture):
    assert len(fixture) == 66
    assert sum(1 for k in fixture if k.startswith("fig3/")) == 18
    assert sum(1 for k in fixture if k.startswith("fig11/")) == 48


@pytest.mark.parametrize("key", SUBSET)
def test_charges_bit_identical_to_fixture(key, fixture):
    app, pol, kw = _config(key)
    got = charge_snapshot(APPS[app].run(pol, **kw))
    want = fixture[key]
    # compare section-by-section so a drift names the exact counter
    for section in want:
        assert got[section] == want[section], f"{key}: {section} drifted"
    assert got == want


def _load_check_parity():
    import importlib.util

    path = Path(__file__).parent.parent / "scripts" / "check_parity.py"
    spec = importlib.util.spec_from_file_location("check_parity", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_select_configs_policies_subset_filter():
    """check_parity --policies: a contributor re-verifying one backend gets
    exactly that backend's configs (and filters compose with --only)."""
    cp = _load_check_parity()
    assert len(cp.select_configs()) == 66
    sys_only = cp.select_configs(policies=("system",))
    assert len(sys_only) == 6 + 6 * 4  # fig3 + fig11 ratios, six apps each
    assert all(p == "system" for _, _, p, _ in sys_only)
    both = cp.select_configs(policies=("system", "explicit"))
    assert {p for _, _, p, _ in both} == {"system", "explicit"}
    assert len(cp.select_configs(only="fig3/", policies=("managed",))) == 6
    assert cp.select_configs(policies=("nope",)) == []
