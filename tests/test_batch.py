"""Batched launch engine: bit-identity with the sequential path.

um.launch_batch / kernel_batch is certified as a *pure dispatch
optimization*: for every registered policy backend, charging a batch must
leave the runtime in exactly the state the per-launch loop produces —
modeled clock (compared as float hex), profiler counters and timelines,
page-table RunMaps, counter/pending notification state — on both the
vectorized fast path and the conformance fallback. A fast-path engagement
test pins that the batched sweep actually runs for the policies that
declare ``batched_charge`` (otherwise the identity tests would only ever
exercise the fallback looping kernel())."""
import dataclasses

import numpy as np
import pytest

from repro.core import Actor, KernelBatch, KernelLaunch, UnifiedMemory
from repro.core.registry import available_policies, make_policy

try:  # the property test is a bonus layer: the seeded suite below runs
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False

KB = 1024
PS = 4 * KB
NB_A = 96 * PS            # page-aligned allocation
NB_B = 37 * PS + 777      # partial tail page (span/range tail quirks)

POLICIES = available_policies()


def _mk(kind: str):
    um = UnifiedMemory()
    a = um.alloc("A", NB_A, make_policy(kind, page_size=PS, threshold=8))
    b = um.alloc("B", NB_B, make_policy(kind, page_size=PS, threshold=8))
    return um, a, b


def _warm(um, a, b) -> None:
    """CPU first-touch both allocations, then a GPU pass + sync: leaves each
    policy in its own steady placement (host under system with counters
    part-bumped, device under managed, ...) before the measured batch."""
    um.kernel(writes=[(a, 0, NB_A), (b, 0, NB_B)], actor=Actor.CPU,
              name="init")
    um.kernel(reads=[(a, 0, NB_A), (b, 0, NB_B)], actor=Actor.GPU,
              name="warm")
    um.sync()


def _rm(rm):
    return rm.starts.tolist(), np.asarray(rm.vals).tolist()


def _state(um) -> dict:
    prof = um.prof
    allocs = {}
    for name, al in um.allocs.items():
        t = al.table
        allocs[name] = None if t is None else {
            "tier": _rm(t._tier), "epoch": _rm(t._epoch),
            "dirty": _rm(t._dirty), "counter": _rm(t._gpu_counter),
            "pending": _rm(al.pending), "pending_count": al.pending_count,
        }
    return {
        "clock": um.clock.hex(),
        "epoch": um.epoch,
        "phase_times": {k: v.hex() for k, v in prof.phase_times.items()},
        "traffic": dataclasses.asdict(prof.traffic()),
        "kernel_times": {k: v.hex() for k, v in prof.kernel_times.items()},
        "kernel_counts": dict(prof.kernel_counts),
        "timeline": [(ts.hex(), h, d) for ts, h, d in prof.timeline],
        "peaks": (prof._peak_host, prof._peak_device),
        "allocs": allocs,
    }


def _extent(rng, nbytes: int):
    lo = int(rng.integers(0, nbytes))
    hi = int(rng.integers(lo, nbytes + 1))
    return lo, hi


def _items(rng, a, b, n: int):
    """n random launches: mixed actors, 1-2 reads, 0-1 writes, extents on
    either allocation (unaligned, overlapping, occasionally empty)."""
    items = []
    for i in range(n):
        actor = Actor.GPU if rng.random() < 0.8 else Actor.CPU
        tgt = lambda: (a, *_extent(rng, NB_A)) if rng.random() < 0.5 \
            else (b, *_extent(rng, NB_B))
        reads = [tgt() for _ in range(int(rng.integers(1, 3)))]
        writes = [tgt()] if rng.random() < 0.4 else []
        items.append((f"k{i}", reads, writes, float(rng.integers(0, 5)) * 1e6,
                      actor))
    return items


def _apply_and_compare(kind: str, seed: int, n_items: int,
                       warm: bool) -> None:
    rng = np.random.default_rng(seed)
    items = None
    states = []
    dts = []
    for batched in (False, True):
        um, a, b = _mk(kind)
        if warm:
            _warm(um, a, b)
        if items is None:
            items = _items(rng, a, b, n_items)
            # rebind extents onto this um's allocations by name on replay
            raw = [(nm, [(r[0].name, r[1], r[2]) for r in rd],
                    [(w[0].name, w[1], w[2]) for w in wr], fl, ac)
                   for nm, rd, wr, fl, ac in items]
        resolved = [(nm, [(um.allocs[an], lo, hi) for an, lo, hi in rd],
                     [(um.allocs[an], lo, hi) for an, lo, hi in wr], fl, ac)
                    for nm, rd, wr, fl, ac in raw]
        if batched:
            got = um.launch_batch([
                KernelLaunch(nm, reads=rd, writes=wr, flops=fl, actor=ac)
                for nm, rd, wr, fl, ac in resolved])
        else:
            got = [um.kernel(reads=rd, writes=wr, flops=fl, actor=ac, name=nm)
                   for nm, rd, wr, fl, ac in resolved]
        dts.append([d.hex() for d in got])
        pre = _state(um)
        um.sync()
        states.append((pre, _state(um)))
    assert dts[0] == dts[1], "per-launch modeled times diverged"
    for section in states[0][0]:
        assert states[0][0][section] == states[1][0][section], \
            f"pre-sync {section} diverged"
    for section in states[0][1]:
        assert states[0][1][section] == states[1][1][section], \
            f"post-sync {section} diverged"


@pytest.mark.parametrize("kind", POLICIES)
@pytest.mark.parametrize("seed", [1, 42, 2026, 99991])
def test_batch_matches_sequential_warm(kind, seed):
    """Warm tables: the certified fast path (for batched_charge policies)
    must be indistinguishable from looping kernel()."""
    _apply_and_compare(kind, seed, 9, warm=True)


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("kind", POLICIES)
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_items=st.integers(1, 10))
    def test_batch_matches_sequential_property(kind, seed, n_items):
        """Property form of the identity: random batch shapes and extents."""
        _apply_and_compare(kind, seed, n_items, warm=True)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_batch_matches_sequential_property():
        pass


@pytest.mark.parametrize("kind", POLICIES)
@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_batch_matches_sequential_cold_fallback(kind, seed):
    """Cold tables (unmapped pages in every hull): certification fails and
    the conformance fallback must still be bit-identical — including the
    placement side effects of first touch mid-batch."""
    _apply_and_compare(kind, seed, 8, warm=False)


@pytest.mark.parametrize("kind", POLICIES)
def test_fast_path_engages_for_batched_policies(kind, monkeypatch):
    """On warm tables a batch must NOT fall back for policies declaring
    batched_charge (else the identity suite would never cover the sweep).
    Policies without batched_charge must always fall back."""
    um, a, b = _mk(kind)
    _warm(um, a, b)
    calls = []
    orig = UnifiedMemory.kernel

    def counting(self, **kw):
        calls.append(kw.get("name"))
        return orig(self, **kw)

    monkeypatch.setattr(UnifiedMemory, "kernel", counting)
    batch = KernelBatch()
    batch.launch("r0", reads=[(a, 0, NB_A)])
    batch.launch("r1", reads=[(b, 0, NB_B)], writes=[(b, 0, PS)])
    um.launch_batch(batch)
    if a.policy.batched_charge or a.table is None:
        # unpaged (explicit) ranges never enter certification: the engine
        # charges them device-local directly, so they ride the fast path
        assert calls == [], f"{kind}: certified batch fell back"
    else:
        assert calls == ["r0", "r1"], f"{kind}: expected sequential fallback"


def test_empty_and_single_item_batches():
    um, a, b = _mk("system")
    _warm(um, a, b)
    assert um.launch_batch(KernelBatch()) == []
    um2, a2, b2 = _mk("system")
    _warm(um2, a2, b2)
    d1 = um2.launch_batch([KernelLaunch("one", reads=[(a2, 0, NB_A)])])
    d2 = um.kernel(reads=[(a, 0, NB_A)], name="one")
    assert len(d1) == 1 and d1[0].hex() == d2.hex()
    assert _state(um) == _state(um2)
