"""Gradient compression: quantization bounds + error-feedback convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim import ef_int8_roundtrip, int8_dequant, int8_quant
from repro.optim.compression import BLOCK


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 2048), scale=st.floats(1e-6, 1e3), seed=st.integers(0, 99))
def test_quant_roundtrip_bound(n, scale, seed):
    """|x - D(Q(x))| <= max|block| / 127 per block (half-ulp of the grid)."""
    x = scale * jax.random.normal(jax.random.PRNGKey(seed), (n,))
    q, s = int8_quant(x)
    y = int8_dequant(q, s, x.shape)
    pad = (-n) % BLOCK
    blocks = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    bound = jnp.max(jnp.abs(blocks), axis=1) / 127.0 * 0.5 + 1e-9
    err = jnp.abs(jnp.pad(x - y, (0, pad)).reshape(-1, BLOCK))
    assert bool(jnp.all(err <= bound[:, None] + 1e-12))


def test_error_feedback_converges_quadratic():
    """EF-compressed GD on a quadratic reaches the optimum; naive compressed
    GD stalls at the quantization floor."""
    dim = 512
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (dim,))

    def run(ef: bool, steps=300, lr=0.2):
        x = jnp.zeros((dim,))
        err = jnp.zeros((dim,))
        for _ in range(steps):
            g = x - target
            if ef:
                g, err = ef_int8_roundtrip(g, err)
            else:
                q, s = int8_quant(g)
                g = int8_dequant(q, s, g.shape)
            x = x - lr * g
        return float(jnp.linalg.norm(x - target))

    assert run(ef=True) < 1e-2
    # and compression actually compresses: int8 + f32/BLOCK scales
    g = jax.random.normal(key, (4096,))
    q, s = int8_quant(g)
    assert q.size * 1 + s.size * 4 < 0.3 * g.size * 4


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50))
def test_ef_residual_bounded(seed):
    """Error feedback residual stays bounded over repeated compression."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (1024,))
    err = jnp.zeros_like(g)
    for _ in range(20):
        _, err = ef_int8_roundtrip(g, err)
    assert float(jnp.max(jnp.abs(err))) < float(jnp.max(jnp.abs(g)))
