"""Exactness of the padded TP head layout — forward AND multi-step training."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.models import RunPolicy, forward, init_params, set_policy_tp
from repro.models.layout import HeadLayout
from repro.train import TrainerConfig, make_train_state, make_train_step


@settings(max_examples=50, deadline=None)
@given(n_kv=st.integers(1, 12), g=st.integers(1, 8), tp=st.sampled_from([2, 4, 8, 16]))
def test_layout_invariants(n_kv, g, tp):
    l = HeadLayout.make(n_kv * g, n_kv, tp)
    assert l.n_q_eff % tp == 0 and l.n_kv_eff % tp == 0
    src = l.q_src()
    real = src[src >= 0]
    assert sorted(real.tolist()) == list(range(n_kv * g))  # every head, once
    kv_src = l.kv_src()
    for e, s in enumerate(src):
        if s >= 0:  # grouping preserved: real q maps to a replica of its kv
            assert kv_src[e // l.p] == s // l.g


def _reduced(arch, mha=False):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(
        cfg, num_heads=6, num_kv_heads=6 if mha else 2, head_dim=16)


@pytest.mark.parametrize("arch,mha", [("yi-6b", False), ("qwen2.5-32b", False),
                                      ("musicgen-medium", True)])
def test_forward_equivalence(arch, mha):
    cfg = _reduced(arch, mha)
    key = jax.random.PRNGKey(0)
    p1 = init_params(cfg, key, dtype=jnp.float32, tp=1)
    p4 = init_params(cfg, key, dtype=jnp.float32, tp=4)
    if cfg.input_kind == "embeddings":
        toks = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    else:
        toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    o1, _ = forward(cfg, p1, toks, set_policy_tp(RunPolicy(), 1))
    o4, _ = forward(cfg, p4, toks, set_policy_tp(RunPolicy(), 4))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o4), atol=1e-5)


@pytest.mark.parametrize("arch,mha", [("yi-6b", False), ("musicgen-medium", True)])
def test_training_equivalence(arch, mha):
    """3 AdamW steps at tp=4 layout == tp=1 layout (grad mask + replica sync)."""
    cfg = _reduced(arch, mha)
    key = jax.random.PRNGKey(0)
    losses = {}
    for tp in (1, 4):
        params = init_params(cfg, key, dtype=jnp.float32, tp=tp)
        state = make_train_state(cfg, params)
        tc = TrainerConfig(grad_accum=1, total_steps=10, warmup_steps=1, tp=tp)
        step = jax.jit(make_train_step(cfg, set_policy_tp(RunPolicy(), tp), tc))
        ls = []
        bkey = jax.random.PRNGKey(7)
        for i in range(3):
            k1, k2, bkey = jax.random.split(bkey, 3)
            if cfg.input_kind == "embeddings":
                toks = jax.random.normal(k1, (2, 16, cfg.d_model), jnp.float32)
            else:
                toks = jax.random.randint(k1, (2, 16), 0, cfg.vocab_size)
            batch = {"tokens": toks,
                     "labels": jax.random.randint(k2, (2, 16), 0, cfg.vocab_size)}
            state, m = step(state, batch)
            ls.append(float(m["loss"]))
        losses[tp] = ls
    np.testing.assert_allclose(losses[1], losses[4], rtol=2e-5)


def test_kv_replicas_stay_identical_after_updates():
    cfg = _reduced("yi-6b")
    key = jax.random.PRNGKey(0)
    tp = 4
    params = init_params(cfg, key, dtype=jnp.float32, tp=tp)
    state = make_train_state(cfg, params)
    tc = TrainerConfig(grad_accum=1, total_steps=10, warmup_steps=1, tp=tp)
    step = jax.jit(make_train_step(cfg, set_policy_tp(RunPolicy(), tp), tc))
    for i in range(2):
        toks = jax.random.randint(jax.random.PRNGKey(i), (2, 16), 0, cfg.vocab_size)
        state, _ = step(state, {"tokens": toks, "labels": toks})
    lay = HeadLayout.make(cfg.num_heads, cfg.num_kv_heads, tp)
    wk = np.asarray(state["params"]["layers"][0]["mixer"]["wk"])
    wk = wk.reshape(wk.shape[0], lay.n_kv, lay.rep, -1)
    for c in range(1, lay.rep):
        np.testing.assert_array_equal(wk[:, :, 0], wk[:, :, c])
    # padded W_o columns stay exactly zero
    wo = np.asarray(state["params"]["layers"][0]["mixer"]["wo"])
    pads = lay.q_pad_mask()
    if pads.any():
        assert np.all(wo[pads] == 0.0)
