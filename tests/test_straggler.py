"""StragglerMonitor: detection, deadline and rebalance hints.

Simulated per-worker step times drive the monitor the way the coordinator
would at scale: uniform workers stay clean, a slow worker trips the
median + k*MAD detector, the deadline tracks the healthy median, and the
rebalance hint shrinks exactly the slow worker's share.
"""
import numpy as np
import pytest

from repro.runtime import StragglerMonitor


def _feed(mon, worker, times):
    for t in times:
        mon.record(worker, t)


def test_uniform_workers_no_stragglers():
    mon = StragglerMonitor()
    rng = np.random.default_rng(0)
    for w in ("w0", "w1", "w2", "w3"):
        _feed(mon, w, 1.0 + 0.01 * rng.standard_normal(16))
    assert mon.stragglers() == []


def test_slow_worker_flagged():
    mon = StragglerMonitor()
    rng = np.random.default_rng(1)
    for w in ("w0", "w1", "w2"):
        _feed(mon, w, 1.0 + 0.01 * rng.standard_normal(16))
    _feed(mon, "slow", 1.0 + 0.01 * rng.standard_normal(12))
    _feed(mon, "slow", [3.0, 3.1, 2.9, 3.0])  # recent window goes bad
    assert mon.stragglers() == ["slow"]


def test_recovered_worker_unflagged():
    mon = StragglerMonitor(window=8)
    for w in ("w0", "w1", "w2"):
        _feed(mon, w, [1.0] * 8)
    _feed(mon, "flaky", [3.0] * 4)
    assert "flaky" in mon.stragglers()
    # the rolling window forgets the bad stretch once healthy times return
    _feed(mon, "flaky", [1.0] * 8)
    assert mon.stragglers() == []


def test_deadline_tracks_median_times_slack():
    mon = StragglerMonitor(deadline_slack=2.0)
    for w in ("w0", "w1"):
        _feed(mon, w, [1.0] * 8)
    assert mon.deadline() == pytest.approx(2.0)
    mon2 = StragglerMonitor(deadline_slack=3.0)
    _feed(mon2, "w0", [0.5] * 8)
    assert mon2.deadline() == pytest.approx(1.5)


def test_empty_monitor_is_safe():
    mon = StragglerMonitor()
    assert mon.stragglers() == []
    assert mon.deadline() == 0.0
    assert mon.rebalance_hint() == {}


def test_rebalance_hint_shrinks_only_the_slow_worker():
    mon = StragglerMonitor()
    for w in ("w0", "w1", "w2"):
        _feed(mon, w, [1.0] * 8)
    _feed(mon, "slow", [2.0] * 8)
    hints = mon.rebalance_hint()
    assert hints["w0"] == pytest.approx(1.0)
    assert hints["w1"] == pytest.approx(1.0)
    assert hints["slow"] == pytest.approx(0.5)
    # the suggested share is floored: a pathological worker never drops
    # below a quarter of its microbatches
    mon2 = StragglerMonitor()
    for w in ("w0", "w1", "w2"):
        _feed(mon2, w, [1.0] * 8)
    _feed(mon2, "dying", [100.0] * 8)
    assert mon2.rebalance_hint()["dying"] == pytest.approx(0.25)
