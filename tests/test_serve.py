"""Serving engine: paged decode == dense decode; umem-governed KV pool."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import UnifiedMemory, Tier
from repro.models import RunPolicy, decode_step, init_params, prefill
from repro.models.cache import init_cache
from repro.serve import ServeEngine


def _dense_generate(cfg, params, prompt, n_new, max_len):
    policy = RunPolicy()
    cache = init_cache(cfg, 1, max_len, tp=1, dtype=jnp.float32)
    step = jax.jit(lambda p, t, ps, c: decode_step(cfg, p, t, ps, c, policy))
    lg = None
    for i, t in enumerate(list(prompt)):
        lg, cache = step(params, jnp.asarray([[t]], jnp.int32),
                         jnp.asarray([i], jnp.int32), cache)
    gen = [int(jnp.argmax(lg[0, 0]))]
    for k in range(n_new - 1):
        i = len(prompt) + k
        lg, cache = step(params, jnp.asarray([[gen[-1]]], jnp.int32),
                         jnp.asarray([i], jnp.int32), cache)
        gen.append(int(jnp.argmax(lg[0, 0])))
    return gen


def test_paged_serving_matches_dense_decode():
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seqs=4, max_len=96, page_size=16)
    prompts = [np.arange(5, 15), np.arange(20, 52), np.arange(7, 19)]
    rids = [eng.add_request(p, max_new_tokens=6) for p in prompts]
    out = eng.run_to_completion()
    for rid, p in zip(rids, prompts):
        assert out[rid] == _dense_generate(cfg, params, p, 6, 96)


def test_page_reuse_after_release():
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seqs=2, max_len=64, page_size=16)
    free0 = len(eng.cache._free)
    eng.add_request(np.arange(2, 20), max_new_tokens=4)
    eng.run_to_completion()
    assert len(eng.cache._free) == free0  # all pages returned


def test_umem_governed_kv_pool():
    """KV pool pages are tracked by the unified-memory runtime: hot pages
    migrate device-side under the system policy."""
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    um = UnifiedMemory()
    eng = ServeEngine(cfg, params, max_seqs=2, max_len=64, page_size=16, um=um)
    eng.add_request(np.arange(2, 34), max_new_tokens=8)
    eng.run_to_completion()
    um.sync()
    tbl = eng.cache.alloc.table
    assert tbl.resident_bytes(Tier.DEVICE) + tbl.resident_bytes(Tier.HOST) > 0
    rep = um.report()
    assert rep["traffic_total"]["pte_inits_gpu"] > 0  # GPU first-touch pages
