"""Traffic-harness invariants (serve/traffic.py + serve/metrics.py).

The load-bearing guarantees of the production traffic simulator:

* **same-seed bit-determinism** — a repeated run reproduces the token
  streams AND the SLO metric report bit-for-bit, under every paged
  policy backend;
* **leak-free soak** — after a 1k-request run with preemption churn the
  KV pool's page accounting is exactly back to empty;
* **preemption/resume bit-identity** — burst load against a tight pool
  preempts and swaps, but generates the same tokens as a roomy pool;
* **oversubscribed token identity** — a 1.5x-oversubscribed pool serves
  the same tokens as the in-memory run of the same schedule;
* **TTFT anchors at arrival** — queueing delay before the admission gate
  is part of TTFT (the serve/engine.py timing contract).
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core import Tier
from repro.models import init_params
from repro.serve import (
    SCENARIOS,
    ArrivalProcess,
    LengthDist,
    RequestRecord,
    Scenario,
    ServeEngine,
    TenantSpec,
    TrafficSim,
    collect,
    get_scenario,
    policy_supports,
    summarize,
)

POLICIES = ("system", "managed", "mi300a_unified")

MICRO = ArchConfig(name="micro", family="dense", source="test",
                   num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                   head_dim=16, d_ff=64, vocab_size=64)


@pytest.fixture(scope="module")
def micro_model():
    return {"micro": (MICRO, init_params(MICRO, jax.random.PRNGKey(0)))}


def _micro_scenario(name="micro", *, n=5, tenants=2, num_pages=None,
                    oversub=1.0, adf=0.5, max_seqs=4, max_len=48,
                    prefill_chunk=12,
                    arrival=ArrivalProcess("poisson", rate=2e5),
                    prompt=LengthDist("lognormal", lo=4, hi=24, mean=10.0),
                    output=LengthDist("lognormal", lo=1, hi=8, mean=4.0)):
    return Scenario(
        name=name,
        tenants=tuple(TenantSpec(name=f"t{i}", arch="micro", num_requests=n,
                                 arrival=arrival, prompt=prompt,
                                 output=output)
                      for i in range(tenants)),
        oversub=oversub, page_size=4, max_seqs=max_seqs, max_len=max_len,
        prefill_chunk=prefill_chunk, num_pages=num_pages,
        admit_device_fraction=adf)


# ------------------------------------------------------- schedule building
def test_arrival_processes_are_seeded_and_ordered():
    t = ArrivalProcess("poisson", rate=100.0).times(
        np.random.default_rng(0), 50)
    t2 = ArrivalProcess("poisson", rate=100.0).times(
        np.random.default_rng(0), 50)
    assert np.array_equal(t, t2)  # seeded: same rng state, same times
    assert len(t) == 50 and (np.diff(t) > 0).all()
    t3 = ArrivalProcess("poisson", rate=100.0).times(
        np.random.default_rng(1), 50)
    assert not np.array_equal(t, t3)  # the seed really drives the schedule

    u = ArrivalProcess("uniform", rate=10.0).times(np.random.default_rng(0), 5)
    assert np.allclose(np.diff(u), 0.1)

    b = ArrivalProcess("bursty", rate=100.0, burst_size=8).times(
        np.random.default_rng(0), 24)
    assert len(b) == 24 and (np.diff(b) >= 0).all()
    # arrivals cluster: most gaps are jitter-scale, burst boundaries are
    # inter-arrival-scale — that bimodality is what forces queueing
    gaps = np.diff(b)
    assert np.median(gaps) < 1e-4 < gaps.max()

    with pytest.raises(ValueError, match="unknown arrival kind"):
        ArrivalProcess("fractal").times(np.random.default_rng(0), 4)


def test_length_dists_clip_to_bounds():
    rng = np.random.default_rng(0)
    for kind in ("lognormal", "pareto"):
        s = LengthDist(kind, lo=4, hi=24, mean=10.0).sample(rng, 500)
        assert s.dtype == np.int64
        assert s.min() >= 4 and s.max() <= 24
        assert len(np.unique(s)) > 1  # a distribution, not a constant
    f = LengthDist("fixed", lo=1, hi=64, mean=7.0).sample(rng, 8)
    assert (f == 7).all()
    with pytest.raises(ValueError, match="unknown length kind"):
        LengthDist("weird").sample(rng, 4)


# ------------------------------------------------------------ determinism
@pytest.mark.parametrize("policy", POLICIES)
def test_same_seed_reproduces_tokens_and_metrics(micro_model, policy):
    """The tentpole guarantee: a same-seed run is bit-deterministic in
    both the generated tokens and the SLO metric report."""
    sc = _micro_scenario(n=5)
    a = TrafficSim(sc, policy=policy, seed=3, models=micro_model).run()
    b = TrafficSim(sc, policy=policy, seed=3, models=micro_model).run()
    assert a.tokens == b.tokens
    assert json.dumps(a.metrics, sort_keys=True) == \
        json.dumps(b.metrics, sort_keys=True)
    assert a.per_engine["micro"]["clock"] == b.per_engine["micro"]["clock"]
    assert a.records == b.records


def test_different_seed_changes_the_workload(micro_model):
    sc = _micro_scenario(n=5)
    a = TrafficSim(sc, policy="system", seed=0, models=micro_model)
    b = TrafficSim(sc, policy="system", seed=1, models=micro_model)
    ta = [arr.t for arr in a._arrivals["micro"]]
    tb = [arr.t for arr in b._arrivals["micro"]]
    assert ta != tb


def test_tokens_match_across_policy_backends(micro_model):
    """Memory policies change placement and timing, never the math: every
    paged backend generates the identical token streams."""
    sc = _micro_scenario(n=4)
    runs = {p: TrafficSim(sc, policy=p, seed=0, models=micro_model).run()
            for p in POLICIES}
    tokens = [r.tokens for r in runs.values()]
    assert all(t == tokens[0] for t in tokens[1:])


# ------------------------------------------------------------------- soak
@pytest.mark.parametrize("policy", POLICIES)
def test_soak_1k_requests_no_kv_page_leak(micro_model, policy):
    """1000 requests of bursty traffic through a pool-limited engine:
    every page returns to the free list, no slot stays active, and the
    metrics account for every request."""
    sc = _micro_scenario(
        name="soak", n=500, tenants=2, num_pages=12, max_seqs=3,
        arrival=ArrivalProcess("bursty", rate=4e5, burst_size=8),
        prompt=LengthDist("pareto", lo=6, hi=20, alpha=1.4),
        output=LengthDist("lognormal", lo=2, hi=8, mean=4.0))
    sim = TrafficSim(sc, policy=policy, seed=1, models=micro_model)
    res = sim.run(max_steps=500_000)
    assert res.metrics["n"] == res.metrics["completed"] == 1000
    assert all(r.done for r in res.records)
    cache = sim.engines["micro"].cache
    assert cache.free_pages() == cache.num_pages - 1
    assert not cache.active.any()
    assert (cache.page_table == 0).all()
    assert sorted(cache._free) == list(range(1, cache.num_pages))
    # the churn was real: the tight pool forced preemption along the way
    assert res.per_engine["micro"]["stats"]["preempted"] > 0


# ------------------------------------------------ preemption / oversubscribe
@pytest.mark.parametrize("policy", POLICIES)
def test_burst_preemption_resume_bit_identity(micro_model, policy):
    """Burst load against a pool that cannot hold the batch: sequences
    preempt (KV demoted host-side) and resume, yet every token matches the
    roomy-pool run of the same schedule."""
    tight = _micro_scenario(
        name="tight", n=8, tenants=2, num_pages=8, max_seqs=3,
        arrival=ArrivalProcess("bursty", rate=4e5, burst_size=8),
        prompt=LengthDist("pareto", lo=8, hi=20, alpha=1.4),
        output=LengthDist("lognormal", lo=4, hi=8, mean=6.0))
    roomy = dataclasses.replace(tight, num_pages=None)
    a = TrafficSim(tight, policy=policy, seed=2, models=micro_model).run()
    b = TrafficSim(roomy, policy=policy, seed=2, models=micro_model).run()
    assert a.per_engine["micro"]["stats"]["preempted"] > 0
    assert b.per_engine["micro"]["stats"]["preempted"] == 0
    assert a.tokens == b.tokens
    assert a.metrics["preemptions"] > 0


@pytest.mark.parametrize("policy", ("system", "managed"))
def test_oversubscribed_tokens_match_in_memory_run(micro_model, policy):
    """KV pool 1.5x the modeled device capacity, pressure gate off: the
    run completes with host-resident KV in play and the tokens are
    bit-identical to the in-memory (1.0x) run of the same schedule."""
    over = _micro_scenario(
        name="over", n=8, tenants=2, num_pages=24, oversub=1.5, adf=0.0,
        max_seqs=4,
        arrival=ArrivalProcess("poisson", rate=4e5),
        prompt=LengthDist("lognormal", lo=8, hi=32, mean=16.0, sigma=0.5),
        output=LengthDist("lognormal", lo=2, hi=8, mean=5.0))
    sim = TrafficSim(over, policy=policy, seed=0, models=micro_model)
    a = sim.run()
    b = TrafficSim(dataclasses.replace(over, oversub=1.0), policy=policy,
                   seed=0, models=micro_model).run()
    assert a.tokens == b.tokens
    # capacity was genuinely shrunk below the pool footprint and respected
    cap = int(sim.pool_bytes["micro"] / over.oversub)
    tbl = sim.engines["micro"].cache.alloc.table
    assert tbl.resident_bytes(Tier.DEVICE) <= cap
    rep = a.per_engine["micro"]["um_report"]
    if policy == "system":
        assert rep["traffic_total"]["remote_h2d"] > 0  # read host KV remotely
        assert rep["remote_access_share"] > 0


def test_mi300a_cannot_run_oversubscribed(micro_model):
    assert not policy_supports("mi300a_unified",
                               _micro_scenario(oversub=1.5))
    assert not policy_supports("explicit", _micro_scenario())
    assert all(policy_supports(p, _micro_scenario()) for p in POLICIES)


# ------------------------------------------------------------------ timing
def test_ttft_anchors_at_arrival_not_admission(micro_model):
    """The regression the SLO metrics exist to catch: a queued request's
    TTFT must include the time it waited for admission. With one slot, the
    second request queues behind the first — its TTFT strictly exceeds its
    post-admission latency."""
    cfg, params = micro_model["micro"]
    eng = ServeEngine(cfg, params, max_seqs=1, max_len=32, page_size=4)
    rng = np.random.default_rng(0)
    r0 = eng.add_request(rng.integers(2, cfg.vocab_size, 6), 4)
    r1 = eng.add_request(rng.integers(2, cfg.vocab_size, 6), 4)
    eng.run_to_completion()
    recs = {r.rid: r for r in collect(eng)}
    for r in (recs[r0], recs[r1]):
        assert (r.arrival_time <= r.admit_time <= r.first_token_time
                <= r.finish_time)
        assert r.ttft == r.first_token_time - r.arrival_time
    assert recs[r0].queue_delay == 0.0  # the slot was free at arrival
    assert recs[r1].admit_time > recs[r1].arrival_time
    assert recs[r1].queue_delay > 0.0
    assert recs[r1].ttft > recs[r0].ttft
    assert recs[r1].ttft >= recs[r1].queue_delay


def test_explicit_arrival_time_and_clock(micro_model):
    cfg, params = micro_model["micro"]
    eng = ServeEngine(cfg, params, max_seqs=2, max_len=32, page_size=4)
    rid = eng.add_request(np.arange(2, 8), 2, arrival_time=5.0, tenant="acme")
    assert eng.requests[rid].arrival_time == 5.0
    assert eng.requests[rid].tenant == "acme"
    assert eng.advance_to(10.0) == 10.0
    assert eng.advance_to(3.0) == 10.0  # never moves backwards
    t0 = eng.now()
    eng.step()
    assert eng.now() > t0  # stepping advances the modeled clock


# ----------------------------------------------------------------- metrics
def _rec(rid, tenant, arrival, first, finish, ntok=4, preempts=0):
    return RequestRecord(rid=rid, tenant=tenant, prompt_len=6,
                         new_tokens=ntok, arrival_time=arrival,
                         admit_time=arrival + 0.5 * (first - arrival),
                         first_token_time=first, finish_time=finish,
                         preemptions=preempts)


def test_summarize_slo_report():
    recs = [_rec(0, "a", 0.0, 1.0, 4.0),
            _rec(1, "a", 1.0, 3.0, 7.0, preempts=1),
            _rec(2, "b", 0.0, 5.0, 9.0)]
    m = summarize(recs, slo_ttft=2.5)
    assert m["n"] == m["completed"] == 3
    assert m["tokens"] == 12
    assert m["preemptions"] == 1
    assert m["ttft"]["p50"] == 2.0 and m["ttft"]["max"] == 5.0
    assert m["ttft"]["p50"] <= m["ttft"]["p99"] <= m["ttft"]["max"]
    # per-request TPOT: (4-1)/3, (7-3)/3, (9-5)/3 -> [1.0, 4/3, 4/3]
    assert m["tpot"]["p50"] == pytest.approx(4 / 3)
    # goodput: 12 tokens over makespan 9.0
    assert m["goodput_tok_s"] == pytest.approx(12 / 9.0)
    # TTFTs are (1.0, 2.0, 5.0) against a 2.5 deadline
    assert m["slo_attainment"] == pytest.approx(2 / 3)
    assert set(m["tenants"]) == {"a", "b"}
    assert m["tenants"]["a"]["completed"] == 2
    assert m["tenants"]["b"]["ttft"]["p50"] == 5.0
    # an unfinished request counts in n but nowhere else
    recs.append(RequestRecord(rid=3, tenant="b", prompt_len=6, new_tokens=0,
                              arrival_time=8.0, admit_time=None,
                              first_token_time=None, finish_time=None,
                              preemptions=0))
    m2 = summarize(recs)
    assert m2["n"] == 4 and m2["completed"] == 3 and m2["tokens"] == 12


def test_summarize_empty():
    m = summarize([], slo_ttft=1.0)
    assert m["n"] == 0 and m["goodput_tok_s"] == 0.0
    assert m["slo_attainment"] == 0.0 and m["tenants"] == {}


# ----------------------------------------------------------------- presets
def test_scenario_presets_shape():
    assert set(SCENARIOS) == {"steady", "burst", "oversubscribed"}
    for name in SCENARIOS:
        sc = get_scenario(name)
        assert sc.name == name
        assert len({t.arch for t in sc.tenants}) >= 3  # multi-config mix
    ov = get_scenario("oversubscribed")
    assert ov.oversub > 1.0
    assert ov.admit_device_fraction == 0.0  # gate off: really oversubscribe
    full = get_scenario("steady").tenants[0].num_requests
    assert get_scenario("steady", 0.5).tenants[0].num_requests < full
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("nope")


def test_steady_preset_end_to_end_real_configs():
    """The real thing, shrunk: the steady preset across three reduced
    model configs (dense GQA / dense / MoE) through the paged-attention
    decode path, per-tenant SLO report included."""
    sc = get_scenario("steady", scale=0.25)
    sim = TrafficSim(sc, policy="system", seed=0)
    res = sim.run()
    assert set(sim.engines) == {"yi-6b", "qwen2.5-32b", "olmoe-1b-7b"}
    expect = sum(t.num_requests for t in sc.tenants)
    assert res.metrics["n"] == res.metrics["completed"] == expect
    assert set(res.metrics["tenants"]) == {t.name for t in sc.tenants}
    assert all(len(v) > 0 for v in res.tokens.values())
    assert res.metrics["goodput_tok_s"] > 0
    assert res.metrics["ttft"]["p50"] > 0
