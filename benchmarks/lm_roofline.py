"""Ours: per-(arch x shape x mesh) roofline terms from the dry-run records."""
import os

from repro.launch.roofline import load

from benchmarks.common import emit

DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")
TAG = os.environ.get("DRYRUN_TAG", "baseline")


def run():
    if not os.path.isdir(os.path.join(DIR, TAG)):
        emit("lm_roofline/missing", 0.0, f"run launch.dryrun first ({DIR}/{TAG})")
        return
    for r in load(DIR, TAG):
        if "skipped" in r:
            emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                 "skipped=" + r["skipped"].replace(",", ";"))
            continue
        bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", bound * 1e6,
             f"dominant={r['dominant']};roofline_frac={r['roofline_fraction']:.2f};"
             f"mfu_bound={r['mfu_bound']:.3f};fits={r['fits']}")
