"""Fig. 6/7: system page size (4KB vs 64KB): alloc/dealloc and compute time."""
from repro.apps import APP_RUNNERS

from benchmarks.common import emit

KB = 1024
SIZES = {
    "needle": dict(n=1024),
    "pathfinder": dict(rows=2048, cols=512),
    "bfs": dict(n_nodes=1 << 14),
    "hotspot": dict(rows=1024, cols=1024, iters=8),
    "srad": dict(rows=512, cols=512, iters=12),
}


def run():
    for app, kw in SIZES.items():
        res = {}
        for ps in (4 * KB, 64 * KB):
            r = APP_RUNNERS[app]("system", page_size=ps, **kw)
            res[ps] = r
            ad = r.phase_times.get("alloc", 0) + r.phase_times.get("dealloc", 0)
            emit(f"fig6/{app}/page{ps//KB}K", ad * 1e6,
                 f"compute_us={r.phase_times.get('compute',0)*1e6:.1f}")
        ad4 = res[4 * KB].phase_times["alloc"] + res[4 * KB].phase_times["dealloc"]
        ad64 = res[64 * KB].phase_times["alloc"] + res[64 * KB].phase_times["dealloc"]
        c4 = res[4 * KB].phase_times["compute"]
        c64 = res[64 * KB].phase_times["compute"]
        emit(f"fig67/{app}/ratios", 0.0,
             f"allocdealloc_4k_over_64k={ad4/ad64:.1f};compute_4k_over_64k={c4/c64:.2f}")
