"""Fig. 6/7: system page size (4KB vs 64KB): alloc/dealloc and compute time.

Sizes come from the AppSpec "fig3" presets (qsim has its own page-size
study in fig89_qiskit.py and is skipped here, as in the paper)."""
from repro.apps import APPS

from benchmarks.common import emit

KB = 1024


def run():
    for app, spec in APPS.items():
        if app == "qiskit":
            continue
        kw = spec.sizes["fig3"]
        res = {}
        for ps in (4 * KB, 64 * KB):
            r = spec.run("system", page_size=ps, **kw)
            res[ps] = r
            ad = r.phase_times.get("alloc", 0) + r.phase_times.get("dealloc", 0)
            emit(f"fig6/{app}/page{ps//KB}K", ad * 1e6,
                 f"compute_us={r.phase_times.get('compute',0)*1e6:.1f}")
        ad4 = res[4 * KB].phase_times["alloc"] + res[4 * KB].phase_times["dealloc"]
        ad64 = res[64 * KB].phase_times["alloc"] + res[64 * KB].phase_times["dealloc"]
        c4 = res[4 * KB].phase_times["compute"]
        c64 = res[64 * KB].phase_times["compute"]
        emit(f"fig67/{app}/ratios", 0.0,
             f"allocdealloc_4k_over_64k={ad4/ad64:.1f};compute_4k_over_64k={c4/c64:.2f}")
