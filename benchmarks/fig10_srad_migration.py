"""Fig. 10: SRAD per-iteration time + memory traffic (access-counter
migration warm-up vs managed's first-iteration migration)."""
from repro.apps import run_srad

from benchmarks.common import emit


def run():
    rs = run_srad("system", rows=512, cols=512, iters=12)
    rm = run_srad("managed", rows=512, cols=512, iters=12)
    for r, pol in ((rs, "system"), (rm, "managed")):
        for d in r.extra["per_iter"]:
            emit(f"fig10/srad/{pol}/iter{d['iter']}", d["seconds"] * 1e6,
                 f"h2d_MB={d['link_h2d']/2**20:.1f};hbm_MB={d['device_local']/2**20:.1f}")
    s = [d["seconds"] for d in rs.extra["per_iter"]]
    m = [d["seconds"] for d in rm.extra["per_iter"]]
    cross = next((i for i in range(len(s)) if s[i] <= m[i]), -1)
    emit("fig10/srad/crossover_iter", 0.0, f"iter={cross}")
