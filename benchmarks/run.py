"""Benchmark harness: one module per paper table/figure. CSV to stdout.

Exits non-zero if ANY module fails, so CI smoke runs can gate on it.
``--json [DIR]`` directs modules that support it (sim_throughput) to write
their BENCH_<module>.json snapshots into DIR (default: the repo root, so
a plain ``--json`` refreshes the committed baselines in place).

``--jobs N`` fans the modules out over N worker processes (spawn): each
worker runs one module with stdout/stderr captured, and the parent prints
the captured output in submission order, so the CSV stays deterministic.
A crashed worker fails the run non-zero just like an in-process exception.

``--policy NAME`` / ``--hw NAME`` run the figure suites under a registered
memory-policy backend / hardware model (see repro.core.registry), e.g.

    python benchmarks/run.py --policy mi300a_unified --hw mi300a

Only modules whose ``run()`` accepts the overrides participate (currently
the AppSpec-driven fig3 suite); the others are skipped with a note, since
silently running them on the default backend would mislabel the results.
Skip detection happens in the parent, so with ``--jobs`` each skip is
still reported exactly once.
"""
import contextlib
import importlib
import inspect
import io
import multiprocessing
import os
import sys
import traceback
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from pathlib import Path

from benchmarks.common import header

REPO_ROOT = Path(__file__).resolve().parent.parent

MODULES = [
    "benchmarks.fig3_overview",
    "benchmarks.fig45_timeline",
    "benchmarks.fig67_pagesize",
    "benchmarks.fig89_qiskit",
    "benchmarks.fig10_srad_migration",
    "benchmarks.fig11_oversub",
    "benchmarks.fig1213_prefetch",
    "benchmarks.kernels_micro",
    "benchmarks.lm_serve_paged",
    "benchmarks.lm_roofline",
    "benchmarks.sim_throughput",
    "benchmarks.train_oversub",
]


def _pop_value_flag(argv: list, flag: str):
    """Remove ``flag VALUE`` from argv and return VALUE (or None)."""
    if flag not in argv:
        return None
    i = argv.index(flag)
    argv.pop(i)
    if i >= len(argv) or argv[i].startswith("-"):
        print(f"benchmarks/run.py: {flag} needs a value", file=sys.stderr)
        raise SystemExit(2)
    return argv.pop(i)


def _takes_overrides(m: str, overrides: dict) -> bool:
    """Whether module m's run() accepts every override kwarg."""
    params = inspect.signature(importlib.import_module(m).run).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return True
    return all(k in params for k in overrides)


def _run_module(m: str, overrides: dict):
    """Worker: import + run one module with stdout/stderr captured (the
    fan-out would interleave them otherwise). Returns (stdout, stderr,
    traceback-or-None); the parent replays the streams in order."""
    out, err = io.StringIO(), io.StringIO()
    error = None
    try:
        with contextlib.redirect_stdout(out), contextlib.redirect_stderr(err):
            importlib.import_module(m).run(**overrides)
    except Exception:
        error = traceback.format_exc()
    return out.getvalue(), err.getvalue(), error


def main(argv=None) -> int:
    """Run all (or the named) benchmark modules; return a shell exit code."""
    argv = list(argv) if argv else []
    # value-taking flags first, so --json's optional-DIR sniffing below can
    # never swallow them as its directory argument
    policy = _pop_value_flag(argv, "--policy")
    hw = _pop_value_flag(argv, "--hw")
    jobs_s = _pop_value_flag(argv, "--jobs")
    try:
        jobs = max(1, int(jobs_s)) if jobs_s is not None else 1
    except ValueError:
        print(f"benchmarks/run.py: --jobs needs an integer, got {jobs_s!r}",
              file=sys.stderr)
        raise SystemExit(2)
    if "--json" in argv:
        i = argv.index("--json")
        argv.pop(i)
        if (i < len(argv) and not argv[i].startswith("benchmarks.")
                and not argv[i].startswith("-")):
            os.environ["BENCH_JSON_DIR"] = argv.pop(i)
        else:
            os.environ.setdefault("BENCH_JSON_DIR", str(REPO_ROOT))
    overrides = {}
    if policy is not None:
        overrides["policy"] = policy
    if hw is not None:
        overrides["hw"] = hw
    names = argv if argv else MODULES
    header()
    failed = []
    todo = []
    for m in names:
        # skip detection stays in the parent: one note per module, never
        # repeated per worker
        if overrides and not _takes_overrides(m, overrides):
            print(f"# {m}: skipped (run() takes no "
                  f"{'/'.join(overrides)} overrides)", file=sys.stderr)
            continue
        todo.append(m)
    if jobs > 1:
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=jobs, mp_context=ctx) as ex:
            futs = [(m, ex.submit(_run_module, m, overrides)) for m in todo]
            for m, f in futs:
                try:
                    out, err, error = f.result()
                except BrokenProcessPool:
                    failed.append(m)
                    print(f"# {m}: worker process crashed", file=sys.stderr)
                    continue
                sys.stdout.write(out)
                sys.stderr.write(err)
                if error is not None:
                    failed.append(m)
                    sys.stderr.write(error)
    else:
        for m in todo:
            try:
                importlib.import_module(m).run(**overrides)
            except Exception:
                failed.append(m)
                traceback.print_exc()
    if failed:
        print(f"benchmark failures: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
