"""Benchmark harness: one module per paper table/figure. CSV to stdout.

Exits non-zero if ANY module fails, so CI smoke runs can gate on it.
"""
import importlib
import sys
import traceback

from benchmarks.common import header

MODULES = [
    "benchmarks.fig3_overview",
    "benchmarks.fig45_timeline",
    "benchmarks.fig67_pagesize",
    "benchmarks.fig89_qiskit",
    "benchmarks.fig10_srad_migration",
    "benchmarks.fig11_oversub",
    "benchmarks.fig1213_prefetch",
    "benchmarks.kernels_micro",
    "benchmarks.lm_serve_paged",
    "benchmarks.lm_roofline",
    "benchmarks.sim_throughput",
]


def main(argv=None) -> int:
    """Run all (or the named) benchmark modules; return a shell exit code."""
    names = argv if argv else MODULES
    header()
    failed = []
    for m in names:
        try:
            importlib.import_module(m).run()
        except Exception:
            failed.append(m)
            traceback.print_exc()
    if failed:
        print(f"benchmark failures: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
