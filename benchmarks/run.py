"""Benchmark harness: one module per paper table/figure. CSV to stdout.

Exits non-zero if ANY module fails, so CI smoke runs can gate on it.
``--json [DIR]`` directs modules that support it (sim_throughput) to write
their BENCH_<module>.json snapshots into DIR (default: cwd).
"""
import importlib
import os
import sys
import traceback

from benchmarks.common import header

MODULES = [
    "benchmarks.fig3_overview",
    "benchmarks.fig45_timeline",
    "benchmarks.fig67_pagesize",
    "benchmarks.fig89_qiskit",
    "benchmarks.fig10_srad_migration",
    "benchmarks.fig11_oversub",
    "benchmarks.fig1213_prefetch",
    "benchmarks.kernels_micro",
    "benchmarks.lm_serve_paged",
    "benchmarks.lm_roofline",
    "benchmarks.sim_throughput",
]


def main(argv=None) -> int:
    """Run all (or the named) benchmark modules; return a shell exit code."""
    argv = list(argv) if argv else []
    if "--json" in argv:
        i = argv.index("--json")
        argv.pop(i)
        if i < len(argv) and not argv[i].startswith("benchmarks."):
            os.environ["BENCH_JSON_DIR"] = argv.pop(i)
        else:
            os.environ.setdefault("BENCH_JSON_DIR", ".")
    names = argv if argv else MODULES
    header()
    failed = []
    for m in names:
        try:
            importlib.import_module(m).run()
        except Exception:
            failed.append(m)
            traceback.print_exc()
    if failed:
        print(f"benchmark failures: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
