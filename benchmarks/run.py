"""Benchmark harness: one module per paper table/figure. CSV to stdout.

Exits non-zero if ANY module fails, so CI smoke runs can gate on it.
``--json [DIR]`` directs modules that support it (sim_throughput) to write
their BENCH_<module>.json snapshots into DIR (default: cwd).

``--policy NAME`` / ``--hw NAME`` run the figure suites under a registered
memory-policy backend / hardware model (see repro.core.registry), e.g.

    python benchmarks/run.py --policy mi300a_unified --hw mi300a

Only modules whose ``run()`` accepts the overrides participate (currently
the AppSpec-driven fig3 suite); the others are skipped with a note, since
silently running them on the default backend would mislabel the results.
"""
import importlib
import inspect
import os
import sys
import traceback

from benchmarks.common import header

MODULES = [
    "benchmarks.fig3_overview",
    "benchmarks.fig45_timeline",
    "benchmarks.fig67_pagesize",
    "benchmarks.fig89_qiskit",
    "benchmarks.fig10_srad_migration",
    "benchmarks.fig11_oversub",
    "benchmarks.fig1213_prefetch",
    "benchmarks.kernels_micro",
    "benchmarks.lm_serve_paged",
    "benchmarks.lm_roofline",
    "benchmarks.sim_throughput",
]


def _pop_value_flag(argv: list, flag: str):
    """Remove ``flag VALUE`` from argv and return VALUE (or None)."""
    if flag not in argv:
        return None
    i = argv.index(flag)
    argv.pop(i)
    if i >= len(argv) or argv[i].startswith("-"):
        print(f"benchmarks/run.py: {flag} needs a value", file=sys.stderr)
        raise SystemExit(2)
    return argv.pop(i)


def main(argv=None) -> int:
    """Run all (or the named) benchmark modules; return a shell exit code."""
    argv = list(argv) if argv else []
    # value-taking flags first, so --json's optional-DIR sniffing below can
    # never swallow them as its directory argument
    policy = _pop_value_flag(argv, "--policy")
    hw = _pop_value_flag(argv, "--hw")
    if "--json" in argv:
        i = argv.index("--json")
        argv.pop(i)
        if (i < len(argv) and not argv[i].startswith("benchmarks.")
                and not argv[i].startswith("-")):
            os.environ["BENCH_JSON_DIR"] = argv.pop(i)
        else:
            os.environ.setdefault("BENCH_JSON_DIR", ".")
    overrides = {}
    if policy is not None:
        overrides["policy"] = policy
    if hw is not None:
        overrides["hw"] = hw
    names = argv if argv else MODULES
    header()
    failed = []
    for m in names:
        try:
            run = importlib.import_module(m).run
            if overrides:
                params = inspect.signature(run).parameters
                var_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                             for p in params.values())
                if not var_kw and not all(k in params for k in overrides):
                    print(f"# {m}: skipped (run() takes no "
                          f"{'/'.join(overrides)} overrides)", file=sys.stderr)
                    continue
                run(**overrides)
            else:
                run()
        except Exception:
            failed.append(m)
            traceback.print_exc()
    if failed:
        print(f"benchmark failures: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
