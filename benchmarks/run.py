"""Benchmark harness: one module per paper table/figure. CSV to stdout."""
import importlib
import traceback

from benchmarks.common import header

MODULES = [
    "benchmarks.fig3_overview",
    "benchmarks.fig45_timeline",
    "benchmarks.fig67_pagesize",
    "benchmarks.fig89_qiskit",
    "benchmarks.fig10_srad_migration",
    "benchmarks.fig11_oversub",
    "benchmarks.fig1213_prefetch",
    "benchmarks.kernels_micro",
    "benchmarks.lm_serve_paged",
    "benchmarks.lm_roofline",
]


def main() -> None:
    header()
    failed = []
    for m in MODULES:
        try:
            importlib.import_module(m).run()
        except Exception:
            failed.append(m)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark failures: {failed}")


if __name__ == "__main__":
    main()
