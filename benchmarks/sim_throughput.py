"""Simulator throughput: kernel-ops/sec of the UnifiedMemory hot path.

Not a paper figure — this tracks the *runtime's own* speed (the paper's
§6 page-size sweep needs GB-scale allocations at 4 KB pages, which is only
tractable if the page-table runtime is extent-based rather than per-page).
Two workloads per page size (4 KB / 64 KB / 2 MB), both on a 1 GiB buffer:

  stream  -- system policy, GPU reads a 64 MiB sliding window with periodic
             syncs (counter-based delayed migration path)
  evict   -- managed policy with an explicit ballast squeezing free device
             memory to 256 MiB, so every window fault migrates + evicts
             (the LRU eviction path)

Emits wall-clock us/kernel-op plus kernel-ops/sec and modeled-pages/sec.
SIM_TP_OPS scales the op count (default 48 stream / 12 evict).
"""
from __future__ import annotations

import os
import time

from repro.core import Actor, UnifiedMemory, explicit_policy, managed_policy, system_policy

from benchmarks.common import emit

KB = 1024
MB = 1024 * 1024
GB = 1024 * 1024 * 1024

NBYTES = 1 * GB
WINDOW = 64 * MB
PAGE_SIZES = {"4KB": 4 * KB, "64KB": 64 * KB, "2MB": 2 * MB}


def _stream(page_size: int, ops: int) -> tuple:
    um = UnifiedMemory()
    a = um.alloc("buf", NBYTES, system_policy(page_size))
    um.kernel(writes=[(a, 0, NBYTES)], actor=Actor.CPU, name="init")
    t0 = time.perf_counter()
    pages = 0
    for i in range(ops):
        lo = (i * WINDOW) % NBYTES
        hi = min(lo + WINDOW, NBYTES)
        um.kernel(reads=[(a, lo, hi)], actor=Actor.GPU)
        pages += -(-(hi - lo) // page_size)
        if i % 8 == 7:
            um.sync()
    return time.perf_counter() - t0, pages


def _evict(page_size: int, ops: int) -> tuple:
    um = UnifiedMemory()
    ballast = um.hw.device_capacity - 256 * MB
    um.alloc("__ballast__", ballast, explicit_policy())
    a = um.alloc("buf", NBYTES, managed_policy(page_size))
    um.kernel(writes=[(a, 0, NBYTES)], actor=Actor.CPU, name="init")
    t0 = time.perf_counter()
    pages = 0
    for i in range(ops):
        lo = (i * WINDOW) % NBYTES
        hi = min(lo + WINDOW, NBYTES)
        um.kernel(reads=[(a, lo, hi)], actor=Actor.GPU)
        pages += -(-(hi - lo) // page_size)
    return time.perf_counter() - t0, pages


def run() -> None:
    ops = int(os.environ.get("SIM_TP_OPS", "48"))
    for label, ps in PAGE_SIZES.items():
        dt, pages = _stream(ps, ops)
        emit(f"sim_throughput/stream/{label}", dt / ops * 1e6,
             f"kernel_ops_per_s={ops / dt:.1f};modeled_pages_per_s={pages / dt:.0f}")
    eops = max(1, ops // 4)
    for label, ps in PAGE_SIZES.items():
        dt, pages = _evict(ps, eops)
        emit(f"sim_throughput/evict/{label}", dt / eops * 1e6,
             f"kernel_ops_per_s={eops / dt:.1f};modeled_pages_per_s={pages / dt:.0f}")


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
