"""Simulator throughput: kernel-ops/sec of the UnifiedMemory hot path.

Not a paper figure — this tracks the *runtime's own* speed (the paper's
§6 page-size sweep needs GB-scale allocations at 4 KB pages, which is only
tractable because the page-table runtime is run-compressed: cost scales
with fragmentation, not allocation size). Workloads:

  stream  -- system policy, GPU reads a sliding window (NBYTES/16) with
             periodic syncs (counter-based delayed migration path), one
             launch at a time; 1 GiB at 4 KB / 64 KB / 2 MB pages
  batch   -- the stream workload submitted through the batched engine
             (um.launch_batch, 1024 launches per engine step + sync):
             per-launch Python dispatch amortized into one vectorized
             charge pass; 1 GiB at the same page sizes
  evict   -- managed policy with an explicit ballast squeezing free device
             memory to 256 MiB, so every window fault migrates + evicts
             (the LRU eviction path); 1 GiB at the same page sizes
  huge    -- the *batched* stream workload at 16 GiB / 4 KB pages (4M+
             PTEs): the dense per-page runtime collapsed here (~295
             kernel-ops/s, ~80 MB metadata); the run-compressed core
             brought it to ~13k ops/s per-launch, and the batched engine
             is the current headline path (>=100k kernel-ops/s)
  huge-seq -- the same 16 GiB workload through per-launch kernel() calls,
             tracking the sequential path's trajectory alongside

Emits wall-clock us/kernel-op plus kernel-ops/sec and modeled-pages/sec to
stdout (CSV) and writes BENCH_simthroughput.json (workload -> metrics) for
the cross-PR perf trajectory. SIM_TP_OPS scales the per-launch op count
(default 48 stream / 12 evict); batched workloads run SIM_TP_OPS*256 ops
(256 per engine step). SIM_TP_FLOOR="stream/4KB=2000,huge/4KB=30000" makes
the run fail if any named workload drops below its kernel-ops/s floor —
the CI perf-smoke gate.
"""
from __future__ import annotations

import os
import sys
import time

from repro.core import (GRACE_HOPPER, Actor, KernelLaunch, UnifiedMemory,
                        explicit_policy, managed_policy, system_policy)

from benchmarks.common import emit, write_json

KB = 1024
MB = 1024 * 1024
GB = 1024 * 1024 * 1024

NBYTES = 1 * GB
HUGE_NBYTES = 16 * GB
PAGE_SIZES = {"4KB": 4 * KB, "64KB": 64 * KB, "2MB": 2 * MB}


def _stream(page_size: int, ops: int, nbytes: int = NBYTES) -> tuple:
    um = UnifiedMemory()
    a = um.alloc("buf", nbytes, system_policy(page_size))
    um.kernel(writes=[(a, 0, nbytes)], actor=Actor.CPU, name="init")
    window = nbytes // 16
    t0 = time.perf_counter()
    pages = 0
    for i in range(ops):
        lo = (i * window) % nbytes
        hi = min(lo + window, nbytes)
        um.kernel(reads=[(a, lo, hi)], actor=Actor.GPU)
        pages += -(-(hi - lo) // page_size)
        if i % 8 == 7:
            um.sync()
    dt = time.perf_counter() - t0
    meta = a.table.metadata_nbytes() + a.pending.bytes_used()
    return dt, pages, meta


def _stream_batched(page_size: int, ops: int, nbytes: int = NBYTES,
                    batch: int = 1024) -> tuple:
    """The stream workload through the batched engine: 1024 launches per
    um.launch_batch call, one sync per batch (vs every 8 ops sequentially —
    syncs are per-engine-step either way)."""
    um = UnifiedMemory()
    a = um.alloc("buf", nbytes, system_policy(page_size))
    um.kernel(writes=[(a, 0, nbytes)], actor=Actor.CPU, name="init")
    window = nbytes // 16
    t0 = time.perf_counter()
    pages = 0
    i = 0
    while i < ops:
        n = min(batch, ops - i)
        items = []
        for j in range(i, i + n):
            lo = (j * window) % nbytes
            hi = min(lo + window, nbytes)
            items.append(KernelLaunch("op", reads=[(a, lo, hi)]))
            pages += -(-(hi - lo) // page_size)
        um.launch_batch(items)
        um.sync()
        i += n
    dt = time.perf_counter() - t0
    meta = a.table.metadata_nbytes() + a.pending.bytes_used()
    return dt, pages, meta


def _evict(page_size: int, ops: int) -> tuple:
    um = UnifiedMemory()
    ballast = um.hw.device_capacity - 256 * MB
    um.alloc("__ballast__", ballast, explicit_policy())
    a = um.alloc("buf", NBYTES, managed_policy(page_size))
    um.kernel(writes=[(a, 0, NBYTES)], actor=Actor.CPU, name="init")
    window = NBYTES // 16
    t0 = time.perf_counter()
    pages = 0
    for i in range(ops):
        lo = (i * window) % NBYTES
        hi = min(lo + window, NBYTES)
        um.kernel(reads=[(a, lo, hi)], actor=Actor.GPU)
        pages += -(-(hi - lo) // page_size)
    dt = time.perf_counter() - t0
    meta = a.table.metadata_nbytes() + a.pending.bytes_used()
    return dt, pages, meta


def _record(results: dict, key: str, dt: float, ops: int, pages: int,
            meta: int) -> None:
    results[key] = {
        "us_per_op": dt / ops * 1e6,
        "kernel_ops_per_s": ops / dt,
        "modeled_pages_per_s": pages / dt,
        "metadata_bytes": meta,
    }
    emit(f"sim_throughput/{key}", dt / ops * 1e6,
         f"kernel_ops_per_s={ops / dt:.1f};modeled_pages_per_s={pages / dt:.0f}"
         f";metadata_bytes={meta}")


def _check_floors(results: dict) -> None:
    """SIM_TP_FLOOR='stream/4KB=2000,...': fail if ops/s drops below."""
    spec = os.environ.get("SIM_TP_FLOOR", "")
    if not spec:
        return
    failures = []
    for item in spec.split(","):
        key, floor = item.split("=")
        key, floor = key.strip(), float(floor)
        got = results[key]["kernel_ops_per_s"]
        if got < floor:
            failures.append(f"{key}: {got:.1f} kernel-ops/s < floor {floor:.1f}")
    if failures:
        print("sim_throughput: PERF FLOOR VIOLATED\n  "
              + "\n  ".join(failures), file=sys.stderr)
        # RuntimeError (not SystemExit) so benchmarks/run.py records this as
        # a module failure instead of aborting the whole harness
        raise RuntimeError("sim_throughput perf floor violated")


def run() -> None:
    ops = int(os.environ.get("SIM_TP_OPS", "48"))
    bops = int(os.environ.get("SIM_TP_BATCH_OPS", str(ops * 256)))
    results = {}
    for label, ps in PAGE_SIZES.items():
        dt, pages, meta = _stream(ps, ops)
        _record(results, f"stream/{label}", dt, ops, pages, meta)
    for label, ps in PAGE_SIZES.items():
        dt, pages, meta = _stream_batched(ps, bops)
        _record(results, f"batch/{label}", dt, bops, pages, meta)
    eops = max(1, ops // 4)
    for label, ps in PAGE_SIZES.items():
        dt, pages, meta = _evict(ps, eops)
        _record(results, f"evict/{label}", dt, eops, pages, meta)
    dt, pages, meta = _stream(4 * KB, ops, nbytes=HUGE_NBYTES)
    _record(results, "huge-seq/4KB", dt, ops, pages, meta)
    dt, pages, meta = _stream_batched(4 * KB, bops, nbytes=HUGE_NBYTES)
    _record(results, "huge/4KB", dt, bops, pages, meta)
    write_json("simthroughput", results, hardware=GRACE_HOPPER.name,
               policies=("system", "managed", "explicit"))
    _check_floors(results)


if __name__ == "__main__":
    from benchmarks.common import header

    header()
    run()
