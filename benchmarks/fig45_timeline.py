"""Fig. 4/5: memory-usage-over-time shapes (hotspot CPU-init, qsim GPU-init)."""
import numpy as np

from repro.apps import run_hotspot, run_qsim

from benchmarks.common import emit


def _shape_stats(res):
    t = np.array([x[0] for x in res.report["allocations"] and []])  # unused
    tl = res.report
    return tl


def run():
    # hotspot: system keeps data host-resident (flat GPU curve); managed
    # migrates at compute start (step up in GPU usage)
    for pol in ("system", "managed"):
        r = run_hotspot(pol, rows=1024, cols=1024, iters=8)
        peak_dev = r.report["peak_device_bytes"]
        peak_host = r.report["peak_host_bytes"]
        emit(f"fig4/hotspot/{pol}", r.total * 1e6,
             f"peak_dev_MB={peak_dev/2**20:.0f};peak_host_MB={peak_host/2**20:.0f}")
    for pol in ("system", "managed"):
        r = run_qsim(pol, n_qubits=16, depth=2)
        emit(f"fig5/qsim/{pol}", r.total * 1e6,
             f"init_s={r.phase_times.get('gpu_init',0):.4f};"
             f"compute_s={r.phase_times.get('compute',0):.4f}")
