"""Fig. 11: system-vs-managed speedup at increasing memory oversubscription.

Sizes come from each app's AppSpec "fig11" preset — the same configurations
scripts/check_parity.py pins bit-identical across refactors."""
from repro.apps import APPS

from benchmarks.common import emit

KB = 1024


def run():
    for app, spec in APPS.items():
        kw = spec.sizes["fig11"]
        for ratio in (1.2, 1.5, 2.0, 3.0):
            ts = spec.run("system", oversub_ratio=ratio,
                          page_size=4 * KB, **kw).time_excluding_cpu_init()
            tm = spec.run("managed", oversub_ratio=ratio,
                          page_size=4 * KB, **kw).time_excluding_cpu_init()
            emit(f"fig11/{app}/oversub{ratio}", ts * 1e6,
                 f"system_over_managed_speedup={tm/ts:.2f}")
