"""Fig. 11: system-vs-managed speedup at increasing memory oversubscription."""
from repro.apps import APP_RUNNERS

from benchmarks.common import emit

SIZES = {
    "qiskit": dict(n_qubits=16, depth=2),
    "needle": dict(n=1024),
    "pathfinder": dict(rows=2048, cols=512),
    "bfs": dict(n_nodes=1 << 14),
    "hotspot": dict(rows=1024, cols=1024, iters=6),
    "srad": dict(rows=512, cols=512, iters=8),
}
KB = 1024


def run():
    for app, kw in SIZES.items():
        for ratio in (1.2, 1.5, 2.0, 3.0):
            ts = APP_RUNNERS[app]("system", oversub_ratio=ratio,
                                  page_size=4 * KB, **kw).time_excluding_cpu_init()
            tm = APP_RUNNERS[app]("managed", oversub_ratio=ratio,
                                  page_size=4 * KB, **kw).time_excluding_cpu_init()
            emit(f"fig11/{app}/oversub{ratio}", ts * 1e6,
                 f"system_over_managed_speedup={tm/ts:.2f}")
