"""Fig. 12/13: explicit prefetch rescues managed memory under
oversubscription (the paper's 34-qubit natural-oversubscription case)."""
from repro.apps import run_qsim

from benchmarks.common import emit

KB = 1024


def run():
    for ps in (4 * KB, 64 * KB):
        base = run_qsim("managed", n_qubits=16, depth=2, oversub_ratio=1.3,
                        page_size=ps)
        pf = run_qsim("managed", n_qubits=16, depth=2, oversub_ratio=1.3,
                      page_size=ps, use_prefetch=True)
        emit(f"fig12/qv16/managed/page{ps//KB}K", base.phase_times["compute"] * 1e6,
             f"prefetch_speedup={base.phase_times['compute']/pf.phase_times['compute']:.2f}")
    # fig13: init/compute breakdown at small vs big page under oversub
    for n, ratio in ((14, 1.0), (16, 1.3)):
        for ps in (4 * KB, 64 * KB):
            r = run_qsim("managed", n_qubits=n, depth=2,
                         oversub_ratio=ratio, page_size=ps)
            emit(f"fig13/qv{n}/managed/page{ps//KB}K", r.total * 1e6,
                 f"init_us={r.phase_times.get('gpu_init',0)*1e6:.1f};"
                 f"compute_us={r.phase_times['compute']*1e6:.1f}")
