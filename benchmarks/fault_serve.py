"""Ours: fault-tolerant serving under injected node/lane/spill failures.

Grid over the PR-7 traffic scenarios x fault kinds x cluster policies,
every cell driven by a seeded :class:`repro.runtime.FaultPlan` against the
TP-2 gh200_x2 serve stack (plus a single-node spill-failure cell on the
preemption-heavy burst scenario):

* **node_loss**    — one superchip dies mid-run: its KV pages are
                     poisoned, the TP group shrinks to the survivor, and
                     affected sequences replay from their prompts.
* **lane_degrade** — the inter-node NVLink/fabric lanes run at 25% of
                     nominal bandwidth for a window of steps.
* **spill_fail**   — host-spill (preemption demote) fails for the whole
                     run; preemptions fall back to drop-and-recompute.

Every faulted cell asserts its generated tokens are BIT-IDENTICAL to the
fault-free run of the same configuration for dense archs (the recovery
guarantee tests/test_fault_serve.py pins). MoE archs are held to
completion + identical stream lengths instead: capacity-bounded routing
drops tokens as a function of the batch/chunk shape (GShard semantics),
and a replay changes batch composition, so bit-identity across a fault
is not a property the arch family has. The rows then report the recovery
SLOs: recovered requests, replayed tokens, p99 TTFT inflation over the
clean run, and the goodput floor.

    PYTHONPATH=src:. python benchmarks/fault_serve.py

Env:
  FAULT_SMOKE=1  shrink the workload for CI smoke runs
  FAULT_FLOOR    'scenario/fault/policy=TOKS_PER_S,...' — fail the run if
                 a faulted cell's modeled goodput drops below its floor,
                 e.g. FAULT_FLOOR='steady/node_loss/cluster_system=20000'

Writes BENCH_fault.json (benchmarks/common.py); CI's fault-smoke job
uploads it and enforces FAULT_FLOOR.
"""
import os
import sys
import time

from repro.configs import get_config
from repro.runtime import FaultPlan
from repro.serve import TrafficSim, get_scenario

from benchmarks.common import emit, header, write_json

SEED = 0
POLICIES = ("cluster_system", "cluster_striped")
SCENARIOS = ("steady", "burst")
# the dead node is the one holding KV pages at the fault step: locality
# places on the serving superchip (kill rank 1), striping fills node 0's
# first stripe at these pool sizes (kill rank 0)
DEAD_NODE = {"cluster_system": 1, "cluster_striped": 0}
NODE_LOSS_STEP = 6
LANE_WINDOW = (2, 24, 0.25)  # (start step, duration, bandwidth factor)


def _floors() -> dict:
    spec = os.environ.get("FAULT_FLOOR", "")
    out = {}
    for item in spec.split(","):
        if item.strip():
            key, floor = item.split("=")
            out[key.strip()] = float(floor)
    return out


def _plan(fault: str, policy: str) -> FaultPlan:
    if fault == "node_loss":
        return FaultPlan.node_loss([(NODE_LOSS_STEP, DEAD_NODE[policy])])
    if fault == "lane_degrade":
        s, d, f = LANE_WINDOW
        return FaultPlan.lane_degrade(s, d, nvlink_factor=f, fabric_factor=f)
    assert fault == "spill_fail"
    return FaultPlan.spill_failure(0, 10_000)


def _stats(res) -> dict:
    agg = {}
    for pe in res.per_engine.values():
        for k, v in pe["stats"].items():
            agg[k] = agg.get(k, 0) + v
    return agg


def _extra(res, key: str) -> int:
    out = 0
    for pe in res.per_engine.values():
        rep = pe["um_report"]
        if rep is not None:
            out += int(rep["traffic_extra"].get(key, 0))
    return out


def _cell(scenario: str, sc, fault: str, policy: str, clean, *,
          hw, tp) -> dict:
    """One faulted run, pinned bit-identical to its clean twin."""
    t0 = time.perf_counter()
    res = TrafficSim(sc, policy=policy, hw=hw, seed=SEED, tp=tp,
                     fault_plan=_plan(fault, policy)).run()
    wall = time.perf_counter() - t0
    key = f"{scenario}/{fault}/{policy}"
    bit_identical = 0
    for rk, stream in clean.tokens.items():
        if get_config(rk.split("/")[0]).is_moe:
            # capacity routing is batch-shape-dependent: a replay changes
            # batch composition, so MoE pins length + completion only
            assert len(res.tokens[rk]) == len(stream), \
                f"{key}: {rk} stream length changed across the fault"
        else:
            assert res.tokens[rk] == stream, \
                f"{key}: {rk} tokens diverged from the fault-free run"
            bit_identical += 1
    assert all(r.done for r in res.records), \
        f"{key}: a request never completed after the fault"
    st = _stats(res)
    m, mc = res.metrics, clean.metrics
    ttft_inflation = (m["ttft"]["p99"] / mc["ttft"]["p99"]
                      if mc["ttft"]["p99"] > 0 else 1.0)
    return {"kind": "fault_serve", "scenario": scenario, "fault": fault,
            "policy": policy, "tp": tp,
            "goodput_tok_s": m["goodput_tok_s"],
            "goodput_clean_tok_s": mc["goodput_tok_s"],
            "ttft_p99": m["ttft"]["p99"],
            "ttft_p99_inflation": ttft_inflation,
            "completed": m["completed"],
            "recovered_requests": st.get("recovered_requests", 0),
            "replayed_tokens": st.get("replayed_tokens", 0),
            "spill_failures": st.get("spill_failures", 0),
            "node_losses": st.get("node_losses", 0),
            "lane_degraded_steps": st.get("lane_degraded_steps", 0),
            "admission_retries": st.get("admission_retries", 0),
            "lost_pages": _extra(res, "lost_pages"),
            "lost_bytes": _extra(res, "lost_bytes"),
            "degraded_nvlink_bytes": _extra(res, "degraded_nvlink_bytes"),
            "degraded_fabric_bytes": _extra(res, "degraded_fabric_bytes"),
            "bit_identical_requests": bit_identical,
            "total_requests": len(clean.tokens), "wall_s": wall}


def main() -> int:
    smoke = os.environ.get("FAULT_SMOKE") == "1"
    scale = 0.25 if smoke else 1.0
    floors = _floors()
    header()
    rows, failures = [], []

    for scenario in SCENARIOS:
        sc = get_scenario(scenario, scale)
        for policy in POLICIES:
            clean = TrafficSim(sc, policy=policy, hw="gh200_x2", seed=SEED,
                               tp=2).run()
            for fault in ("node_loss", "lane_degrade"):
                row = _cell(scenario, sc, fault, policy, clean,
                            hw="gh200_x2", tp=2)
                rows.append(row)
                key = f"{scenario}/{fault}/{policy}"
                emit(f"fault/{key}", row["ttft_p99"] * 1e6,
                     f"goodput_tok_s={row['goodput_tok_s']:.0f},"
                     f"recovered={row['recovered_requests']},"
                     f"replayed={row['replayed_tokens']}")
                floor = floors.get(key)
                if floor is not None and row["goodput_tok_s"] < floor:
                    failures.append(
                        f"{key}: goodput {row['goodput_tok_s']:.0f} tok/s "
                        f"< floor {floor:.0f}")

    # single-node spill-failure cell on the preemption-heavy burst shape
    sc = get_scenario("burst", scale)
    clean = TrafficSim(sc, policy="system", seed=SEED).run()
    row = _cell("burst", sc, "spill_fail", "system", clean, hw=None, tp=1)
    rows.append(row)
    emit("fault/burst/spill_fail/system", row["ttft_p99"] * 1e6,
         f"goodput_tok_s={row['goodput_tok_s']:.0f},"
         f"spill_failures={row['spill_failures']},"
         f"replayed={row['replayed_tokens']}")
    floor = floors.get("burst/spill_fail/system")
    if floor is not None and row["goodput_tok_s"] < floor:
        failures.append(f"burst/spill_fail/system: goodput "
                        f"{row['goodput_tok_s']:.0f} tok/s < floor {floor:.0f}")

    # the artifact must prove the faults really hit (ISSUE acceptance)
    assert sum(r["replayed_tokens"] for r in rows) > 0, \
        "no cell replayed any tokens — the node losses never hit live KV"
    assert sum(r["degraded_nvlink_bytes"] + r["degraded_fabric_bytes"]
               for r in rows) > 0, \
        "no cell counted degraded-lane bytes — the windows never overlapped traffic"

    if failures:
        raise SystemExit("FAULT_FLOOR violated:\n  " + "\n  ".join(failures))

    write_json("fault", {"rows": rows}, hardware="gh200_x2",
               policies=POLICIES + ("system",),
               extra_meta={"scenarios": list(SCENARIOS),
                           "node_loss_step": NODE_LOSS_STEP,
                           "lane_window": list(LANE_WINDOW),
                           "seed": SEED, "smoke": smoke})
    return 0


if __name__ == "__main__":
    sys.exit(main())
