"""Kernel micro-benches (interpret mode on CPU: structural timing only —
real perf comes from the §Roofline analysis, not CPU wall time)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.qv_gate import apply_two_qubit_gate
from repro.kernels.stencil5 import stencil5

from benchmarks.common import emit


def _bench(fn, *args, n=3, **kw):
    fn(*args, **kw)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args, **kw)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(key, (1, 256, 2, 64), jnp.float32)
    emit("kernel/flash_attention_256", _bench(
        flash_attention, q, k, v, block_q=64, block_k=64, interpret=True),
        "B1_S256_H8_D64")
    qd = jax.random.normal(key, (2, 8, 64), jnp.float32)
    kp = jax.random.normal(key, (16, 16, 2, 64), jnp.float32)
    pt = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    ln = jnp.asarray([60, 33], jnp.int32)
    emit("kernel/paged_attention", _bench(
        paged_attention, qd, kp, kp, pt, ln, interpret=True), "B2_NP4_PS16")
    st = jnp.zeros((2 ** 14,), jnp.complex64).at[0].set(1.0)
    g = jnp.eye(4, dtype=jnp.complex64)
    emit("kernel/qv_gate_14q", _bench(
        apply_two_qubit_gate, st, g, 3, 9, 14, interpret=True), "n14")
    grid = jax.random.normal(key, (512, 256), jnp.float32)
    emit("kernel/stencil5_512x256", _bench(
        stencil5, grid, 0.1, tile_h=128, interpret=True), "")
