"""Fig. 8/9: Quantum Volume x system page size, system vs managed, with the
init/compute breakdown for the largest in-memory case."""
from repro.apps import run_qsim

from benchmarks.common import emit

KB = 1024


def run():
    for n in (14, 16, 18):
        for pol in ("system", "managed"):
            t = {}
            for ps in (4 * KB, 64 * KB):
                r = run_qsim(pol, n_qubits=n, depth=2, page_size=ps)
                t[ps] = r.total
            emit(f"fig8/qv{n}/{pol}", t[64 * KB] * 1e6,
                 f"speedup_64k_over_4k={t[4*KB]/t[64*KB]:.2f}")
    # fig9 breakdown (largest case)
    for pol in ("system", "managed"):
        for ps in (4 * KB, 64 * KB):
            r = run_qsim(pol, n_qubits=18, depth=2, page_size=ps)
            emit(f"fig9/qv18/{pol}/page{ps//KB}K", r.total * 1e6,
                 f"init_us={r.phase_times.get('gpu_init',0)*1e6:.1f};"
                 f"compute_us={r.phase_times.get('compute',0)*1e6:.1f}")
