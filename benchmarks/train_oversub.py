"""Ours: fig11-style oversubscribed TRAINING — step time vs ratio per policy.

The paper's fig11 sweeps an HPC kernel's working set past device capacity
and plots the slowdown per memory backend. This module is the training
counterpart over the PR-10 offload subsystem: a train_100m-class residual
MLP (params + grads + AdamW m/v/master + activation stash in UMBuffers,
see src/repro/train/offload.py) is driven through every registered backend
with the device sized to 1.0x / 1.25x / 1.5x / 2.0x oversubscription of
the GPU-touched working set (``device_demand_bytes`` — the optimizer tree
is CPU-resident and pressures the pool, not the device).

Every cell asserts its losses are BIT-IDENTICAL to the in-memory (1.0x)
run — the math is real numpy with a fixed op order; only the modeled
step time and the traffic counters may move with the ratio. Backends that
cannot reach the requested ratio report the capacity they actually ran
with as ``eff_ratio`` (mi300a_unified floors at the full state tree: its
single pool cannot map less than it holds; the staged explicit port's
slab traffic is ratio-insensitive by construction).

    PYTHONPATH=src:. python benchmarks/train_oversub.py

Env:
  TRAIN_SMOKE=1  shrink to train_25m x fewer ratios for CI smoke runs
  TRAIN_MODEL    spec name override (train_tiny / train_25m / train_100m)
  TRAIN_FLOOR    'policy/ratio=STEPS_PER_S,...' — fail the run if a cell's
                 modeled throughput drops below its floor, e.g.
                 TRAIN_FLOOR='system/1.5=100,managed/1.5=40'

Writes BENCH_train.json (benchmarks/common.py); CI's train-smoke job
uploads it and enforces TRAIN_FLOOR.
"""
import os
import sys
import time

from repro.train import UMTrainer, get_train_model

from benchmarks.common import emit, header, write_json

SEED = 0
RATIOS = (1.0, 1.25, 1.5, 2.0)
SMOKE_RATIOS = (1.0, 1.5)
POLICIES = ("system", "managed", "explicit", "mi300a_unified",
            "cluster_system", "cluster_striped")
HW_FOR = {"mi300a_unified": "mi300a", "cluster_system": "gh200_x2",
          "cluster_striped": "gh200_x2"}


def _floors() -> dict:
    spec = os.environ.get("TRAIN_FLOOR", "")
    out = {}
    for item in spec.split(","):
        if item.strip():
            key, floor = item.split("=")
            out[key.strip()] = float(floor)
    return out


def _cell(spec, policy: str, ratio: float, steps: int, ref_losses) -> dict:
    t0 = time.perf_counter()
    tr = UMTrainer(spec, policy=policy, hw=HW_FOR.get(policy), ratio=ratio,
                   seed=SEED)
    out = tr.run(steps)
    wall = time.perf_counter() - t0
    if ref_losses is not None:
        assert out["losses"] == ref_losses, \
            f"{policy} x{ratio}: losses diverged from the 1.0x reference " \
            "— the memory system leaked into the math"
    rep = tr.um.prof.report()
    tt = rep["traffic_total"]
    tr.close()
    return {"kind": "train_oversub", "model": spec.name, "policy": policy,
            "ratio": ratio, "eff_ratio": round(out["eff_ratio"], 4),
            "capacity_bytes": out["capacity"],
            "demand_bytes": out["demand_bytes"],
            "state_bytes": out["peak_bytes"],
            "steps": steps, "modeled_s": out["modeled_s"],
            "steps_per_s": out["steps_per_s"],
            "step_time_s": out["modeled_s"] / steps,
            "migrated_out_bytes": tt["migrated_out"],
            "remote_access_share": rep["remote_access_share"],
            "losses": out["losses"], "wall_s": wall}


def run() -> int:
    """Benchmark-harness entry point (benchmarks/run.py). Takes no
    --policy/--hw overrides: the module grids over every registered
    backend itself, so the harness skips it (with a note) rather than
    mislabeling an override run."""
    smoke = os.environ.get("TRAIN_SMOKE") == "1"
    model = os.environ.get("TRAIN_MODEL",
                           "train_25m" if smoke else "train_100m")
    spec = get_train_model(model)
    ratios = SMOKE_RATIOS if smoke else RATIOS
    steps = 2 if smoke else 3
    floors = _floors()
    header()
    rows, failures = [], []

    ref_losses = None
    for policy in POLICIES:
        for ratio in ratios:
            row = _cell(spec, policy, ratio, steps, ref_losses)
            if ref_losses is None:
                ref_losses = row["losses"]  # system x1.0 anchors the grid
            rows.append(row)
            key = f"{policy}/{ratio}"
            emit(f"train/{model}/{key}", row["step_time_s"] * 1e6,
                 f"steps_per_s={row['steps_per_s']:.1f},"
                 f"eff_ratio={row['eff_ratio']},"
                 f"migrated_out={row['migrated_out_bytes']}")
            floor = floors.get(key)
            if floor is not None and row["steps_per_s"] < floor:
                failures.append(
                    f"{key}: {row['steps_per_s']:.1f} steps/s "
                    f"< floor {floor:.1f}")

    # the curve must be a curve: oversubscription has to cost modeled time
    # somewhere (the fault-driven backend cannot be flat across the axis)
    managed = {r["ratio"]: r for r in rows if r["policy"] == "managed"}
    assert managed[max(ratios)]["modeled_s"] > managed[1.0]["modeled_s"], \
        "managed showed no slowdown under oversubscription — the ratio " \
        "axis is not applying device pressure"
    assert managed[max(ratios)]["migrated_out_bytes"] > 0, \
        "managed evicted nothing at the deepest ratio"

    if failures:
        raise SystemExit("TRAIN_FLOOR violated:\n  " + "\n  ".join(failures))

    write_json("train", {"rows": rows}, hardware="grace-hopper",
               policies=POLICIES,
               extra_meta={"model": model, "ratios": list(ratios),
                           "steps": steps, "seed": SEED, "smoke": smoke,
                           "hw_overrides": HW_FOR})
    return 0


def main() -> int:
    return run()


if __name__ == "__main__":
    sys.exit(main())
