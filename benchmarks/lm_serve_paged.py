"""Ours: paged-KV serving with the umem-governed pool (tokens/s + traffic)."""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import TPU_V5E, UnifiedMemory
from repro.models import init_params
from repro.serve import ServeEngine

from benchmarks.common import emit


def run():
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    um = UnifiedMemory(hw=TPU_V5E)
    eng = ServeEngine(cfg, params, max_seqs=4, max_len=128, page_size=16, um=um)
    rng = np.random.default_rng(0)
    for _ in range(4):
        eng.add_request(rng.integers(2, cfg.vocab_size, 24), 12)
    t0 = time.perf_counter()
    out = eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    tr = um.report()["traffic_total"]
    emit("lm_serve/paged_umem", dt / max(1, toks) * 1e6,
         f"tokens={toks};kv_h2d_MB={tr['link_h2d']/2**20:.2f};"
         f"pte_gpu={tr['pte_inits_gpu']}")
