"""Ours: paged-KV serving with the umem-governed pool, plus an
oversubscription sweep.

The sweep applies the fig11 methodology (benchmarks/fig11_oversub.py) to
serving: the KV page pool is sized to the workload's peak concurrent
demand and the modeled device capacity is shrunk to ``pool_bytes /
ratio`` for ratios 1x-1.75x. Under the system policy the overflow pages
map host-side and decode reads them remotely, so the engine keeps
serving instead of dying on ``page pool exhausted`` / OOM. Each ratio
reports wall-clock tokens/s, modeled tokens/s and the remote-access
share of GPU KV reads, and asserts the generated tokens are
bit-identical to the in-memory (1.0x) run.

    PYTHONPATH=src:. python benchmarks/lm_serve_paged.py --oversub 1.5

Env: LM_SERVE_SMOKE=1 shrinks the workload for CI smoke runs.
"""
import argparse
import dataclasses
import os
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import TPU_V5E, UnifiedMemory
from repro.models import init_params
from repro.models.cache import kv_head_layout
from repro.serve import PagedKVCache, ServeEngine

from benchmarks.common import emit

PAGE_SIZE = 16
RATIOS = (1.0, 1.25, 1.5, 1.75)


def _workload(cfg, smoke: bool):
    rng = np.random.default_rng(0)
    n_req = 3 if smoke else 4
    max_new = 8 if smoke else 12
    prompts = [rng.integers(2, cfg.vocab_size, int(rng.integers(18, 30)))
               for _ in range(n_req)]
    return prompts, max_new


def _pool_pages(prompts, max_new) -> int:
    """Pages for the peak concurrent KV demand (all requests in flight)."""
    return sum(-(-(len(p) + max_new) // PAGE_SIZE) for p in prompts) + 1


def _serve(cfg, params, prompts, max_new, *, num_pages, device_capacity):
    hw = dataclasses.replace(TPU_V5E, device_capacity=device_capacity)
    um = UnifiedMemory(hw=hw)
    eng = ServeEngine(cfg, params, max_seqs=len(prompts), max_len=128,
                      page_size=PAGE_SIZE, num_pages=num_pages, um=um)
    for p in prompts:
        eng.add_request(p, max_new)
    t0 = time.perf_counter()
    out = eng.run_to_completion()
    wall = time.perf_counter() - t0
    return out, eng, um, wall


def run(ratios=RATIOS):
    smoke = bool(os.environ.get("LM_SERVE_SMOKE"))
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts, max_new = _workload(cfg, smoke)
    num_pages = _pool_pages(prompts, max_new)
    pool_bytes = num_pages * PagedKVCache.page_bytes_for(
        cfg, kv_head_layout(cfg, 1), PAGE_SIZE)

    baseline = None
    for ratio in ratios:
        cap = int(pool_bytes / ratio) if ratio > 1.0 else pool_bytes
        out, eng, um, wall = _serve(cfg, params, prompts, max_new,
                                    num_pages=num_pages, device_capacity=cap)
        toks = sum(len(v) for v in out.values())
        if ratio == 1.0:
            baseline = out
        elif baseline is not None:
            assert all(out[r] == baseline[r] for r in baseline), \
                f"oversub {ratio}x diverged from the in-memory run"
        rep = um.report()
        tr = rep["traffic_total"]
        emit(f"lm_serve/oversub{ratio}", wall / max(1, toks) * 1e6,
             f"tokens={toks};tok_s={toks / wall:.1f};"
             f"model_tok_s={toks / max(um.clock, 1e-12):.0f};"
             f"remote_share={rep['remote_access_share']:.3f};"
             f"preempted={eng.stats.preempted};"
             f"kv_h2d_MB={tr['link_h2d'] / 2**20:.2f};"
             f"pte_gpu={tr['pte_inits_gpu']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--oversub", type=float, default=None,
                    help="run the in-memory baseline plus this pool/HBM ratio "
                         "(default: sweep 1.0-1.75)")
    args = ap.parse_args(argv)
    if args.oversub is not None:
        if args.oversub < 1.0:
            ap.error("--oversub must be >= 1.0 (pool/HBM ratio)")
        ratios = (1.0,) if args.oversub == 1.0 else (1.0, args.oversub)
    else:
        ratios = RATIOS
    run(ratios)
    return 0


if __name__ == "__main__":
    sys.exit(main())
