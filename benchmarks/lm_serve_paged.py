"""Ours: production traffic through the UM-backed serve engine.

Replaces the fixed oversubscription sweep with the scenario presets from
``repro.serve.traffic`` — seeded Poisson/bursty arrivals, heavy-tail
prompt/output lengths, multi-tenant mixes over three model configs
(yi-6b / qwen2.5-32b / olmoe-1b-7b, reduced) — run under every
registered memory-policy backend that can back the KV pool (PR 5
registry). Per (scenario, policy, tenant) it reports the SLO metrics
from ``repro.serve.metrics``: p50/p99 TTFT, per-token latency (TPOT),
goodput under preemption, plus the remote-access share of KV reads.

The ``oversubscribed`` scenario additionally asserts the generated
tokens are bit-identical to an in-memory (1.0x) run of the same
schedule — the paper's graceful-oversubscription claim, applied to
serving.

    PYTHONPATH=src:. python benchmarks/lm_serve_paged.py --scenario steady
    PYTHONPATH=src:. python benchmarks/lm_serve_paged.py --policies system,managed

Env:
  LM_SERVE_SMOKE=1   shrink the workload for CI smoke runs
  LM_SERVE_FLOOR     'scenario/policy=TOKS_PER_S,...' — fail the run if a
                     cell's modeled goodput drops below its floor, e.g.
                     LM_SERVE_FLOOR='steady/system=50000'

Writes BENCH_lmserve.json (benchmarks/common.py) with `_meta`
hardware/policy stamping for the cross-PR perf trajectory.
"""
import argparse
import dataclasses
import os
import sys
import time

from repro.core import available_policies, get_hardware
from repro.serve import SCENARIOS, TrafficSim, get_scenario, policy_supports

from benchmarks.common import emit, write_json

SEED = 0


def _floors() -> dict:
    spec = os.environ.get("LM_SERVE_FLOOR", "")
    out = {}
    for item in spec.split(","):
        if item.strip():
            key, floor = item.split("=")
            out[key.strip()] = float(floor)
    return out


def _run_cell(scenario_name: str, policy: str, scale: float, hw) -> dict:
    """One (scenario, policy) traffic run -> JSON-able result row."""
    sc = get_scenario(scenario_name, scale)
    sim = TrafficSim(sc, policy=policy, hw=hw, seed=SEED)
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0

    if sc.oversub > 1.0:
        # token bit-identity vs the in-memory run of the SAME schedule
        flat = dataclasses.replace(sc, oversub=1.0)
        base = TrafficSim(flat, policy=policy, hw=hw, seed=SEED).run()
        assert res.tokens == base.tokens, \
            f"{scenario_name}/{policy}: oversubscribed tokens diverged " \
            "from the in-memory run"

    m = res.metrics
    remote = 0.0
    preempted = 0
    for pe in res.per_engine.values():
        preempted += pe["stats"]["preempted"]
        if pe["um_report"] is not None:
            remote = max(remote, pe["um_report"]["remote_access_share"])
    row = {
        "tokens": m["tokens"],
        "completed": m["completed"],
        "goodput_tok_s": m["goodput_tok_s"],
        "ttft_p50": m["ttft"]["p50"],
        "ttft_p99": m["ttft"]["p99"],
        "tpot_p50": m["tpot"]["p50"],
        "tpot_p99": m["tpot"]["p99"],
        "preempted": preempted,
        "remote_share_max": remote,
        "wall_s": wall,
        "tenants": {t: {"ttft_p50": tm["ttft"]["p50"],
                        "ttft_p99": tm["ttft"]["p99"],
                        "tpot_p50": tm["tpot"]["p50"],
                        "goodput_tok_s": tm["goodput_tok_s"],
                        "tokens": tm["tokens"]}
                    for t, tm in m["tenants"].items()},
    }
    emit(f"lm_serve/{scenario_name}/{policy}",
         m["ttft"]["p99"] * 1e6,
         f"tokens={m['tokens']};goodput_tok_s={m['goodput_tok_s']:.0f};"
         f"ttft_p50_us={m['ttft']['p50'] * 1e6:.2f};"
         f"tpot_p99_us={m['tpot']['p99'] * 1e6:.2f};"
         f"preempted={preempted};remote_share={remote:.3f};"
         f"wall_s={wall:.2f}")
    for t, tm in m["tenants"].items():
        emit(f"lm_serve/{scenario_name}/{policy}/{t}",
             tm["ttft"]["p99"] * 1e6,
             f"tokens={tm['tokens']};goodput_tok_s={tm['goodput_tok_s']:.0f};"
             f"ttft_p50_us={tm['ttft']['p50'] * 1e6:.2f}")
    return row


def run(scenarios=None, policies=None, *, policy=None, hw=None):
    """Run the scenario x policy grid. ``policy``/``hw`` are the
    benchmarks/run.py single-backend overrides (--policy/--hw)."""
    smoke = bool(os.environ.get("LM_SERVE_SMOKE"))
    scale = 0.5 if smoke else 1.0
    scenarios = list(scenarios or sorted(SCENARIOS))
    if policy is not None:
        policies = [policy]
    if policies is None:
        policies = [p for p in available_policies()
                    if policy_supports(p, get_scenario("steady"))]

    results, failures = {}, []
    floors = _floors()
    for name in scenarios:
        sc = get_scenario(name)
        for pol in policies:
            if not policy_supports(pol, sc):
                print(f"# lm_serve: skipping {name}/{pol} "
                      f"(backend cannot run this scenario)")
                continue
            key = f"{name}/{pol}"
            results[key] = _run_cell(name, pol, scale, hw)
            floor = floors.get(key)
            if floor is not None and results[key]["goodput_tok_s"] < floor:
                failures.append(
                    f"{key}: {results[key]['goodput_tok_s']:.0f} modeled "
                    f"tok/s < floor {floor:.0f}")
    write_json("lmserve", results,
               hardware=get_hardware(hw).name, policies=policies)
    if failures:
        for f in failures:
            print(f"FLOOR VIOLATION: {f}", file=sys.stderr)
        raise RuntimeError("lm_serve goodput floor violated")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", action="append", default=None,
                    metavar="NAME", choices=sorted(SCENARIOS),
                    help="scenario preset(s) to run (default: all); "
                         "repeatable")
    ap.add_argument("--policies", default=None,
                    help="comma-separated registry backends (default: every "
                         "backend that can back the KV pool)")
    args = ap.parse_args(argv)
    policies = args.policies.split(",") if args.policies else None
    run(args.scenario, policies)
    return 0


if __name__ == "__main__":
    sys.exit(main())
