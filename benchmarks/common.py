"""Benchmark harness utilities: CSV emission per paper table/figure."""
from __future__ import annotations

import sys
import time
from typing import Iterable


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def header() -> None:
    print("name,us_per_call,derived")
