"""Benchmark harness utilities: CSV emission per paper table/figure, plus
machine-readable JSON snapshots (``BENCH_<module>.json``) so the perf
trajectory is tracked across PRs (CI uploads them as artifacts)."""
from __future__ import annotations

import json
import os
from pathlib import Path


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def header() -> None:
    print("name,us_per_call,derived")


def json_dir() -> Path:
    """Where BENCH_*.json files land (override with BENCH_JSON_DIR)."""
    return Path(os.environ.get("BENCH_JSON_DIR", "."))


def write_json(module: str, results: dict, *, hardware: str = "",
               policies=(), extra_meta: dict = None) -> Path:
    """Write a benchmark module's results as BENCH_<module>.json.

    ``hardware`` (HardwareModel name) and ``policies`` (the policy kinds the
    module exercised) land under a ``_meta`` key, so the cross-PR perf
    trajectory stays attributable when runs switch memory backends.
    ``extra_meta`` merges additional keys into ``_meta`` (e.g. the cluster
    benchmark's link topology)."""
    path = json_dir() / f"BENCH_{module}.json"
    out = dict(results)
    out["_meta"] = {"hardware": hardware,
                    "policies": sorted(set(policies)),
                    **(extra_meta or {})}
    path.write_text(json.dumps(out, indent=1, sort_keys=True) + "\n")
    return path
