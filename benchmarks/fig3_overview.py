"""Fig. 3: relative performance of system/managed vs explicit, six apps.

Sizes come from each app's AppSpec "fig3" preset — the same configurations
scripts/check_parity.py pins bit-identical across refactors."""
from repro.apps import APPS

from benchmarks.common import emit


def run():
    for app, spec in APPS.items():
        kw = spec.sizes["fig3"]
        base = spec.run("explicit", **kw).time_excluding_cpu_init()
        for pol in ("managed", "system"):
            t = spec.run(pol, **kw).time_excluding_cpu_init()
            emit(f"fig3/{app}/{pol}", t * 1e6, f"speedup_vs_explicit={base / t:.3f}")
