"""Fig. 3: relative performance of system/managed vs explicit, six apps."""
from repro.apps import APP_RUNNERS

from benchmarks.common import emit

SIZES = {
    "qiskit": dict(n_qubits=16, depth=3),
    "needle": dict(n=1024),
    "pathfinder": dict(rows=2048, cols=512),
    "bfs": dict(n_nodes=1 << 14),
    "hotspot": dict(rows=1024, cols=1024, iters=8),
    "srad": dict(rows=512, cols=512, iters=12),
}


def run():
    for app, kw in SIZES.items():
        base = APP_RUNNERS[app]("explicit", **kw).time_excluding_cpu_init()
        for pol in ("managed", "system"):
            t = APP_RUNNERS[app](pol, **kw).time_excluding_cpu_init()
            emit(f"fig3/{app}/{pol}", t * 1e6, f"speedup_vs_explicit={base / t:.3f}")
