"""Fig. 3: relative performance of system/managed vs explicit, six apps.

Sizes come from each app's AppSpec "fig3" preset — the same configurations
scripts/check_parity.py pins bit-identical across refactors.

``run(policy=..., hw=...)`` swaps the whole suite onto one registered
memory-policy backend / hardware model (benchmarks/run.py --policy/--hw):
every app runs end-to-end under that backend and raw times are emitted
(no explicit-baseline speedup — the baseline belongs to the paper's
three-way Grace Hopper comparison, not to an arbitrary backend).
"""
from repro.apps import APPS
from repro.core import get_hardware

from benchmarks.common import emit


def run(policy=None, hw=None):
    hw_name = get_hardware(hw).name
    pols = ("managed", "system") if policy is None else (policy,)
    for app, spec in APPS.items():
        kw = spec.sizes["fig3"]
        base = (spec.run("explicit", hw=hw, **kw).time_excluding_cpu_init()
                if policy is None else None)
        for pol in pols:
            t = spec.run(pol, hw=hw, **kw).time_excluding_cpu_init()
            derived = (f"speedup_vs_explicit={base / t:.3f}"
                       if base is not None else "")
            if hw is not None:  # overridden hardware must label its rows
                derived += (";" if derived else "") + f"hw={hw_name}"
            emit(f"fig3/{app}/{pol}", t * 1e6, derived)
