"""Ours: multi-superchip scaling of the distributed unified pool.

Two experiment families over the cluster subsystem (src/repro/cluster/):

* **Oversubscription sweep** (fig. 11 style, scaled out): each app's
  device working set is squeezed to ``peak / ratio`` across N = 1/2/4
  superchips (``gh200_cluster(n).with_device_capacity``, keeping the
  per-node split consistent), under both node-aware backends. Reported
  per cell: modeled time, remote-access share and the inter-node
  NVLink/fabric byte totals — how much of the pressure each placement
  strategy pushes across the cluster links.
* **TP serving** (PR 7 traffic harness): the ``steady`` scenario served
  with tensor parallelism over 2 and 4 superchips. Each TP run asserts
  its generated tokens are bit-identical to the single-node run of the
  same schedule (the cluster plan only adds modeled time), then reports
  goodput, TTFT and the all-reduce/inter-node byte counters.

    PYTHONPATH=src:. python benchmarks/cluster_scaling.py
    PYTHONPATH=src:. python benchmarks/cluster_scaling.py --apps srad,bfs

Env:
  CLUSTER_SMOKE=1  shrink the workload for CI smoke runs
  CLUSTER_FLOOR    'scenario/tpN/policy=TOKS_PER_S,...' — fail the run if
                   a TP-serving cell's modeled goodput drops below its
                   floor, e.g. CLUSTER_FLOOR='steady/tp2/cluster_system=10000'

Writes BENCH_cluster.json (benchmarks/common.py) with the link topology
under ``_meta`` for the cross-PR perf trajectory.
"""
import argparse
import os
import sys
import time

from repro.apps import run_app

KB = 1024
from repro.cluster import ClusterTopology, gh200_cluster
from repro.serve import TrafficSim, get_scenario

from benchmarks.common import emit, header, write_json

SEED = 0
POLICIES = ("cluster_system", "cluster_striped")
NODE_COUNTS = (1, 2, 4)
RATIOS = (1.0, 1.5, 2.0)


def _floors() -> dict:
    spec = os.environ.get("CLUSTER_FLOOR", "")
    out = {}
    for item in spec.split(","):
        if item.strip():
            key, floor = item.split("=")
            out[key.strip()] = float(floor)
    return out


def _lanes(report: dict) -> dict:
    extra = report.get("traffic_extra", {})
    return {"internode_nvlink_bytes": int(extra.get("internode_nvlink_bytes", 0)),
            "internode_fabric_bytes": int(extra.get("internode_fabric_bytes", 0))}


# ------------------------------------------------------ oversubscription sweep
def sweep(apps, preset: str, ratios) -> list:
    rows = []
    for app in apps:
        # roomy measuring run: the app's allocation footprint (every
        # non-harness buffer it ever created) sets the squeeze
        roomy = run_app(app, "cluster_system", preset=preset,
                        page_size=4 * KB, hw=gh200_cluster(1))
        peak = sum(a["nbytes"]
                   for name, a in roomy.report["allocations"].items()
                   if not name.startswith("__"))
        for nodes in NODE_COUNTS:
            for ratio in ratios:
                hw = gh200_cluster(nodes).with_device_capacity(
                    int(peak / ratio))
                for policy in POLICIES:
                    r = run_app(app, policy, preset=preset,
                                page_size=4 * KB, hw=hw)
                    t = r.time_excluding_cpu_init()
                    row = {"kind": "sweep", "app": app, "nodes": nodes,
                           "ratio": ratio, "policy": policy, "time_s": t,
                           "remote_share": r.report["remote_access_share"],
                           **_lanes(r.report)}
                    rows.append(row)
                    emit(f"cluster/{app}/x{nodes}/oversub{ratio}/{policy}",
                         t * 1e6,
                         f"remote_share={row['remote_share']:.3f},"
                         f"nvlink_mb={row['internode_nvlink_bytes'] / 1e6:.1f}")
    return rows


# ------------------------------------------------------------------ TP serving
def tp_serve(scale: float, tps, floors: dict) -> list:
    rows, failures = [], []
    sc = get_scenario("steady", scale)
    base = TrafficSim(sc, policy="system", seed=SEED).run()
    for tp in tps:
        hw = f"gh200_x{tp}"
        for policy in POLICIES:
            t0 = time.perf_counter()
            res = TrafficSim(sc, policy=policy, hw=hw, seed=SEED,
                             tp=tp).run()
            wall = time.perf_counter() - t0
            assert res.tokens == base.tokens, \
                f"steady/tp{tp}/{policy}: TP tokens diverged from the " \
                "single-node run of the same schedule"
            m = res.metrics
            lanes = {}
            allreduce = 0
            for pe in res.per_engine.values():
                rep = pe["um_report"]
                if rep is not None:
                    for k, v in _lanes(rep).items():
                        lanes[k] = lanes.get(k, 0) + v
                    allreduce += int(rep["traffic_extra"].get(
                        "tp_allreduce_bytes", 0))
            row = {"kind": "tp_serve", "scenario": "steady", "tp": tp,
                   "policy": policy, "goodput_tok_s": m["goodput_tok_s"],
                   "ttft_p50": m["ttft"]["p50"],
                   "tokens_match_single_node": True,
                   "tp_allreduce_bytes": allreduce, "wall_s": wall, **lanes}
            rows.append(row)
            key = f"steady/tp{tp}/{policy}"
            emit(f"cluster/{key}", m["ttft"]["p50"] * 1e6,
                 f"goodput_tok_s={m['goodput_tok_s']:.0f},"
                 f"allreduce_mb={allreduce / 1e6:.1f}")
            floor = floors.get(key)
            if floor is not None and m["goodput_tok_s"] < floor:
                failures.append(f"{key}: goodput {m['goodput_tok_s']:.0f} "
                                f"tok/s < floor {floor:.0f}")
    if failures:
        raise SystemExit("CLUSTER_FLOOR violated:\n  " + "\n  ".join(failures))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--apps", default="srad,qiskit",
                    help="comma list of apps for the oversubscription sweep "
                         "(GPU-init apps show the device-pool squeeze; "
                         "CPU-init apps live on host either way)")
    args = ap.parse_args(argv)

    smoke = os.environ.get("CLUSTER_SMOKE") == "1"
    preset = "small" if smoke else "fig11"
    ratios = (1.5,) if smoke else RATIOS
    scale = 0.25 if smoke else 1.0
    tps = (2,) if smoke else (2, 4)

    header()
    rows = sweep([a.strip() for a in args.apps.split(",") if a.strip()],
                 preset, ratios)
    rows += tp_serve(scale, tps, _floors())

    topo = ClusterTopology()
    write_json("cluster", {"rows": rows},
               hardware=",".join(f"gh200_x{n}" for n in NODE_COUNTS),
               policies=POLICIES,
               extra_meta={"topology": {
                   "node_counts": list(NODE_COUNTS),
                   "nvlink_bw": topo.nvlink_bw,
                   "nvlink_latency": topo.nvlink_latency,
                   "fabric_bw": topo.fabric_bw,
                   "fabric_latency": topo.fabric_latency}})
    return 0


if __name__ == "__main__":
    sys.exit(main())
