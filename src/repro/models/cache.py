"""Decode caches: dense KV, ring-buffer (sliding window), recurrent state.

The *paged* KV cache (software page table; the paper's mechanism applied to
serving) lives in serve/paged.py + kernels/paged_attention; this module is the
dense baseline layout used by the dry-run decode cells.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.models.layout import HeadLayout


def kv_head_layout(cfg, tp: int) -> HeadLayout:
    return HeadLayout.make(cfg.num_heads, cfg.num_kv_heads, tp)


def init_cache(cfg, B: int, S: int, *, tp: int = 1, dtype=jnp.bfloat16,
               kv_quant: bool = False) -> List[Dict[str, Any]]:
    caches: List[Dict[str, Any]] = []
    if cfg.mixer in ("attention", "rglru_hybrid"):
        lay = kv_head_layout(cfg, tp)
    for kind in cfg.layer_kinds():
        if kind == "attention":
            kv_dtype = jnp.int8 if kv_quant else dtype
            c = {
                "k": jnp.zeros((B, S, lay.n_kv_eff, cfg.head_dim), kv_dtype),
                "v": jnp.zeros((B, S, lay.n_kv_eff, cfg.head_dim), kv_dtype),
            }
            if kv_quant:  # per-(token, head) scales
                c["ks"] = jnp.zeros((B, S, lay.n_kv_eff, 1), jnp.float32)
                c["vs"] = jnp.zeros((B, S, lay.n_kv_eff, 1), jnp.float32)
            caches.append(c)
        elif kind == "local":
            W = min(cfg.local_window, S)
            caches.append({
                "k": jnp.zeros((B, W, lay.n_kv_eff, cfg.head_dim), dtype),
                "v": jnp.zeros((B, W, lay.n_kv_eff, cfg.head_dim), dtype),
            })
        elif kind == "rglru":
            w = cfg.lru_width or cfg.d_model
            caches.append({
                "h": jnp.zeros((B, w), jnp.float32),
                "conv": jnp.zeros((B, cfg.conv_width - 1, w), dtype),
            })
        elif kind == "rwkv6":
            hs = cfg.rwkv_head_size
            H = cfg.d_model // hs
            caches.append({
                "s": jnp.zeros((B, H, hs, hs), jnp.float32),
                "xa": jnp.zeros((B, cfg.d_model), dtype),
                "xf": jnp.zeros((B, cfg.d_model), dtype),
            })
        else:
            raise ValueError(kind)
    return caches


def cache_specs(cfg, B: int, S: int, *, tp: int = 1, dtype=jnp.bfloat16,
                kv_quant: bool = False):
    """ShapeDtypeStruct tree for the decode cache (no allocation)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, B, S, tp=tp, dtype=dtype, kv_quant=kv_quant))
