"""Exact TP head layout: KV-head replication + Q-head zero-padding.

Tensor parallelism over a model axis of size ``tp`` requires head counts
divisible by ``tp``. Real archs rarely satisfy this (qwen2.5: 40q/8kv, tp=16),
so we build an *exact* effective layout:

  rep   = smallest r >= 1 with (n_kv * r) % tp == 0     (KV replication)
  p     = ceil(g / rep), g = n_q / n_kv                 (Q heads per eff KV head)
  n_kv_eff = n_kv * rep ;  n_q_eff = n_kv_eff * p       (both divisible by tp)

KV replication is the GQA repeat-kv identity transform. Q padding is exact
because padded heads have zero W_o columns, so whatever they attend to
contributes nothing. Two trainer hooks preserve exactness *under training*:

  * ``grad_mask``: zeroes gradients of structurally-padded parameters,
  * ``sync_kv_replica_grads``: sums KV-projection gradients across replicas so
    replicas remain bit-identical (the sum is the true gradient of the shared
    logical parameter).

With tp=1 the layout degenerates to the identity. Property- and unit-tests
assert padded == unpadded for forward and for a multi-step training run.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class HeadLayout:
    n_q: int
    n_kv: int
    tp: int
    rep: int
    p: int
    pad: int = 0  # MHA: extra padded (q,kv) pairs instead of replication

    @staticmethod
    def make(n_q: int, n_kv: int, tp: int) -> "HeadLayout":
        assert n_q % n_kv == 0, (n_q, n_kv)
        g = n_q // n_kv
        if g == 1:
            # MHA: pad (q,kv) pairs to a multiple of tp. Fake pairs are
            # isolated (fake q only attends fake kv) and W_o-zeroed => exact,
            # and strictly cheaper than kv replication (cache + FLOPs).
            n_eff = math.ceil(n_q / tp) * tp
            return HeadLayout(n_q=n_q, n_kv=n_kv, tp=tp, rep=1, p=1,
                              pad=n_eff - n_q)
        rep = 1
        while (n_kv * rep) % tp != 0:
            rep += 1
        p = math.ceil(g / rep)
        return HeadLayout(n_q=n_q, n_kv=n_kv, tp=tp, rep=rep, p=p)

    @property
    def g(self) -> int:
        return self.n_q // self.n_kv

    @property
    def n_kv_eff(self) -> int:
        return self.n_kv * self.rep + self.pad

    @property
    def n_q_eff(self) -> int:
        return self.n_kv_eff * self.p

    @property
    def identity(self) -> bool:
        return self.n_q_eff == self.n_q and self.n_kv_eff == self.n_kv

    # -- index maps ---------------------------------------------------------
    def q_src(self) -> np.ndarray:
        """eff q index -> original q index, or -1 for structural padding."""
        out = np.full(self.n_q_eff, -1, dtype=np.int64)
        if self.pad:
            out[: self.n_q] = np.arange(self.n_q)
            return out
        for j in range(self.n_kv):
            for c in range(self.rep):
                for s in range(self.p):
                    l = c * self.p + s  # local q index within the kv group
                    if l < self.g:
                        out[(j * self.rep + c) * self.p + s] = j * self.g + l
        return out

    def kv_src(self) -> np.ndarray:
        """eff kv index -> original kv index (replicas share a source;
        MHA pads borrow head 0 — their values are unreachable by real q)."""
        if self.pad:
            src = np.concatenate([np.arange(self.n_kv), np.zeros(self.pad)])
            return src.astype(np.int64)
        return np.repeat(np.arange(self.n_kv, dtype=np.int64), self.rep)

    def q_pad_mask(self) -> np.ndarray:
        """bool (n_q_eff,): True where the eff q head is structural padding."""
        return self.q_src() < 0

    def kv_pad_mask(self) -> np.ndarray:
        """bool (n_kv_eff,): True for padded MHA kv heads (grads masked)."""
        out = np.zeros(self.n_kv_eff, bool)
        if self.pad:
            out[self.n_kv:] = True
        return out

    # -- weight expansion (used at init and in equivalence tests) -----------
    def expand_q(self, w, head_axis: int):
        """Expand an (..., n_q, ...) tensor to eff layout, zero-filling pads."""
        import jax.numpy as jnp

        src = self.q_src()
        taken = jnp.take(w, jnp.asarray(np.where(src < 0, 0, src)), axis=head_axis)
        mask_shape = [1] * w.ndim
        mask_shape[head_axis] = self.n_q_eff
        mask = jnp.asarray((src >= 0).reshape(mask_shape), dtype=w.dtype)
        return taken * mask

    def expand_kv(self, w, head_axis: int):
        """Expand an (..., n_kv, ...) tensor to eff layout (replication)."""
        import jax.numpy as jnp

        return jnp.take(w, jnp.asarray(self.kv_src()), axis=head_axis)

    def reduce_kv_grad(self, g, head_axis: int):
        """Sum replica grads and broadcast back (keeps replicas identical)."""
        import jax.numpy as jnp

        shp = list(g.shape)
        new = shp[:head_axis] + [self.n_kv, self.rep] + shp[head_axis + 1 :]
        gr = g.reshape(new)
        s = jnp.sum(gr, axis=head_axis + 1, keepdims=True)
        return jnp.broadcast_to(s, new).reshape(shp)
