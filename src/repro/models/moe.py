"""Token-choice top-k MoE with capacity-bounded dense dispatch (GShard-style).

Experts are padded to a multiple of the model axis (granite: 40 -> 48) with
-inf router logits on pads — exact, pads are never routed to. Expert weights
shard over the model axis (expert parallelism); the dispatch/combine einsums
lower to all-to-all-like collectives under GSPMD.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import RunPolicy, dense_init, zeros_init

NEG_INF = -1e30


def num_experts_eff(cfg, tp: int) -> int:
    return int(math.ceil(cfg.num_experts / tp) * tp)


def moe_init(cfg, key, dtype, tp: int) -> Dict[str, Any]:
    """Draw logical-size experts, then zero-pad to E_eff: the padded init is
    exactly the unpadded init (tp-equivalence, like the attention layout)."""
    d, f = cfg.d_model, cfg.d_ff
    E0 = cfg.num_experts
    E = num_experts_eff(cfg, tp)
    ks = jax.random.split(key, 4)
    pad = E - E0
    p = {
        "router": dense_init(ks[0], (d, E0), dtype, in_axis_size=d),
        "w_gate": dense_init(ks[1], (E0, d, f), dtype, in_axis_size=d),
        "w_up": dense_init(ks[2], (E0, d, f), dtype, in_axis_size=d),
        "w_down": dense_init(ks[3], (E0, f, d), dtype, in_axis_size=f),
    }
    if pad:
        p["router"] = jnp.pad(p["router"], ((0, 0), (0, pad)))
        for k in ("w_gate", "w_up", "w_down"):
            p[k] = jnp.pad(p[k], ((0, pad), (0, 0), (0, 0)))
    return p


def moe_apply(cfg, p, x, policy: RunPolicy, tp: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Dispatcher: GShard-style dense dispatch (baseline) or sort-based
    scatter dispatch (beyond-paper §Perf: removes the O(T*E*C*d) dispatch
    einsums — the dominant waste in MoE prefill)."""
    if getattr(policy, "moe_impl", "dense") == "sorted":
        return moe_apply_sorted(cfg, p, x, policy, tp=tp)
    return moe_apply_dense(cfg, p, x, policy, tp=tp)


def moe_apply_sorted(cfg, p, x, policy: RunPolicy, tp: int = 1
                     ) -> Tuple[jax.Array, jax.Array]:
    """Sort/scatter dispatch (megablocks-style, capacity-padded).

    FLOPs = expert FFN only (~2*3*T*k*d*f); dispatch/combine are scatters and
    gathers, not matmuls. Same drop semantics as the dense path (per-expert
    capacity, slot-0-first priority). Distributed: shard_map EP — each model
    rank routes its data-shard's tokens to its local experts and the partial
    outputs psum over 'model' (one bf16 all-reduce, like any TP layer)."""
    if policy.mesh is not None and tp > 1:
        return _moe_sorted_ep(cfg, p, x, policy, tp)
    B, S, d = x.shape
    E, K = num_experts_eff(cfg, tp), cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    if E != cfg.num_experts:
        padm = jnp.arange(E) >= cfg.num_experts
        logits = jnp.where(padm[None, :], NEG_INF, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(max(4, math.ceil(T * K / cfg.num_experts * policy.moe_capacity_factor)))
    cap = min(cap, T)

    # slot-major flattening: ALL slot-0 routings take queue positions before
    # any slot-1 (bit-identical drop priority to the dense path)
    expert_flat = idx.T.reshape(-1)  # (K*T,)
    token_flat = jnp.tile(jnp.arange(T), K)
    gate_flat = gate_vals.T.reshape(-1)
    order = jnp.argsort(expert_flat, stable=True)
    e_sorted = expert_flat[order]
    t_sorted = token_flat[order]
    g_sorted = gate_flat[order]
    counts = jnp.bincount(expert_flat, length=E)
    starts = jnp.cumsum(counts) - counts  # exclusive
    pos_in_e = jnp.arange(T * K) - starts[e_sorted]
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, E * cap)  # E*cap = trash

    xe = jnp.zeros((E * cap + 1, d), x.dtype).at[slot].set(xt[t_sorted])
    xe = policy.c(xe[:-1].reshape(E, cap, d), "moe_experts")
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"],
                               preferred_element_type=jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"], preferred_element_type=jnp.float32)
    h = (g * u).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    ye = policy.c(ye, "moe_experts").reshape(E * cap, d)
    contrib = jnp.where(keep, g_sorted, 0.0)[:, None].astype(x.dtype) * ye[
        jnp.minimum(slot, E * cap - 1)]
    y = jnp.zeros((T, d), x.dtype).at[t_sorted].add(contrib)

    me = probs[:, : cfg.num_experts].mean(axis=0)
    ce = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=1)[
        :, : cfg.num_experts].mean(axis=0)
    aux = cfg.num_experts * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux


def _moe_sorted_ep(cfg, p, x, policy: RunPolicy, tp: int) -> Tuple[jax.Array, jax.Array]:
    """shard_map expert parallelism for the sorted dispatch (see above)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = policy.mesh
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_entry = dp if len(dp) > 1 else dp[0]
    B, S, d = x.shape
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if B % dp_size != 0:
        dp_entry = None
        dp_size = 1
    E, K = num_experts_eff(cfg, tp), cfg.top_k
    E_loc = E // tp
    T_loc = (B // dp_size) * S
    cap = int(max(4, math.ceil(
        T_loc * K / cfg.num_experts * policy.moe_capacity_factor)))
    cap = min(cap, T_loc)

    def f(x_loc, router, wg, wu, wd):
        Bl, Sl, _ = x_loc.shape
        T = Bl * Sl
        xt = x_loc.reshape(T, d)
        logits = (xt @ router.astype(jnp.float32)).astype(jnp.float32)
        if E != cfg.num_experts:
            padm = jnp.arange(E) >= cfg.num_experts
            logits = jnp.where(padm[None, :], NEG_INF, logits)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        rank = jax.lax.axis_index("model")
        e_lo = rank * E_loc
        expert_flat = idx.T.reshape(-1)
        token_flat = jnp.tile(jnp.arange(T), K)
        gate_flat = gate_vals.T.reshape(-1)
        order = jnp.argsort(expert_flat, stable=True)
        e_sorted = expert_flat[order]
        t_sorted = token_flat[order]
        g_sorted = gate_flat[order]
        counts = jnp.bincount(expert_flat, length=E)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(T * K) - starts[e_sorted]
        local = (e_sorted >= e_lo) & (e_sorted < e_lo + E_loc)
        keep = (pos_in_e < cap) & local
        slot = jnp.where(keep, (e_sorted - e_lo) * cap + pos_in_e, E_loc * cap)

        xe = jnp.zeros((E_loc * cap + 1, d), x_loc.dtype).at[slot].set(xt[t_sorted])
        xe = xe[:-1].reshape(E_loc, cap, d)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg,
                                   preferred_element_type=jnp.float32))
        u = jnp.einsum("ecd,edf->ecf", xe, wu, preferred_element_type=jnp.float32)
        h = (g * u).astype(x_loc.dtype)
        ye = jnp.einsum("ecf,efd->ecd", h, wd,
                        preferred_element_type=jnp.float32).astype(x_loc.dtype)
        ye = ye.reshape(E_loc * cap, d)
        contrib = jnp.where(keep, g_sorted, 0.0)[:, None].astype(x_loc.dtype) * ye[
            jnp.minimum(slot, E_loc * cap - 1)]
        y = jnp.zeros((T, d), x_loc.dtype).at[t_sorted].add(contrib)
        y = jax.lax.psum(y, "model")  # sum expert-shard contributions

        me = probs[:, : cfg.num_experts].mean(axis=0)
        ce = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(axis=1)[
            :, : cfg.num_experts].mean(axis=0)
        aux = cfg.num_experts * jnp.sum(me * ce)
        return y.reshape(Bl, Sl, d), aux[None]

    y, aux = shard_map(
        f, mesh=mesh,
        in_specs=(P(dp_entry, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dp_entry, None, None), P(dp_entry)),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux.mean()


def moe_apply_dense(cfg, p, x, policy: RunPolicy, tp: int = 1) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (y, aux_loss). Capacity-dropped tokens pass through (residual)."""
    B, S, d = x.shape
    E, K = num_experts_eff(cfg, tp), cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    if E != cfg.num_experts:
        pad = jnp.arange(E) >= cfg.num_experts
        logits = jnp.where(pad[None, :], NEG_INF, logits)
    probs = jax.nn.softmax(logits, axis=-1)  # (T,E)
    gate_vals, idx = jax.lax.top_k(probs, K)  # (T,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(max(4, math.ceil(T * K / cfg.num_experts * policy.moe_capacity_factor)))
    cap = min(cap, T)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (T,K,E)
    # position of each (t,k) routing decision in its expert queue; slot 0 first.
    # top-k indices are distinct, so per (t,e) at most one slot fires and the
    # per-slot quantities can be summed into single (T,E) maps before building
    # the ONE (T,E,cap) combine tensor (keeps transients to a single buffer).
    pos_te = jnp.zeros((T, E), jnp.float32)
    gate_te = jnp.zeros((T, E), jnp.float32)
    hit_te = jnp.zeros((T, E), jnp.float32)
    prior = jnp.zeros((E,), jnp.float32)
    for s in range(K):
        m = onehot[:, s, :]
        pos_s = jnp.cumsum(m, axis=0) - m + prior[None, :]
        prior = prior + m.sum(axis=0)
        pos_te = pos_te + pos_s * m
        gate_te = gate_te + gate_vals[:, s, None] * m
        hit_te = hit_te + m
    within = hit_te * (pos_te < cap).astype(jnp.float32)
    slot = jax.nn.one_hot(jnp.minimum(pos_te, cap - 1).astype(jnp.int32), cap,
                          dtype=jnp.float32)  # (T,E,cap)
    combine = (gate_te * within)[:, :, None] * slot
    dispatch = (within[:, :, None] * slot).astype(x.dtype)

    xe = jnp.einsum("tec,td->ecd", dispatch, xt, preferred_element_type=jnp.float32).astype(x.dtype)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"], preferred_element_type=jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"], preferred_element_type=jnp.float32)
    h = (g * u).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"], preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("tec,ecd->td", combine.astype(x.dtype), ye,
                   preferred_element_type=jnp.float32).astype(x.dtype)

    # Switch-style load-balance aux loss over the *real* experts
    me = probs[:, : cfg.num_experts].mean(axis=0)
    ce = onehot.sum(axis=1)[:, : cfg.num_experts].mean(axis=0)
    aux = cfg.num_experts * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux
