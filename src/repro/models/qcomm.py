"""Quantized tensor-parallel collectives (beyond-paper, §Perf lever).

Megatron row-parallel projections end in an all-reduce of full activations —
the collective-term bottleneck of 32k-token prefill at TP=16. This module
replaces that all-reduce with an int8 two-phase reduce:

  partial (B,S,d) --quantize--> int8 + per-(token,shard-block) scales
    --all_to_all--> dequant-sum of my d-shard --quantize-->
    --all_gather--> dequant -> full (B,S,d)

Wire bytes/device: ~2*(n-1)/n * E * 1B vs 2*(n-1)/n * E * 2B for the bf16
all-reduce -> ~2x reduction (plus f32 scales, ~d/(d/n)/4 overhead). Intended
for inference lowerings (prefill/decode); rounding is not differentiated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quant_blocks(y, n: int):
    """y: (..., n, m) f32 -> int8 codes + per-(..., n) scales."""
    scale = jnp.max(jnp.abs(y), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(y / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantized_allreduce(y, axis_name: str):
    """int8 two-phase all-reduce along a mesh axis. y: (B,S,d) f32/bf16 partial."""
    n = jax.lax.axis_size(axis_name)
    B, S, d = y.shape
    assert d % n == 0, (d, n)
    y4 = y.astype(jnp.float32).reshape(B, S, n, d // n)
    q, s = _quant_blocks(y4, n)
    # exchange: piece j of every device lands on device j
    q = jax.lax.all_to_all(q, axis_name, split_axis=2, concat_axis=2, tiled=False)
    s = jax.lax.all_to_all(s, axis_name, split_axis=2, concat_axis=2, tiled=False)
    part = jnp.sum(q.astype(jnp.float32) * s, axis=2)  # (B,S,d/n): my shard, reduced
    q2, s2 = _quant_blocks(part[..., None, :], 1)
    q2 = q2[..., 0, :]
    s2 = s2[..., 0, :]
    qg = jax.lax.all_gather(q2, axis_name, axis=2, tiled=False)  # (B,S,n,d/n)
    sg = jax.lax.all_gather(s2, axis_name, axis=2, tiled=False)
    out = (qg.astype(jnp.float32) * sg[..., None].reshape(B, S, n, 1)).reshape(B, S, d)
    return out


def rowparallel_matmul_q8(x_sharded_contract, w, mesh, *, x_spec: P, w_spec: P,
                          out_dtype):
    """shard_map'd row-parallel projection with the quantized all-reduce.

    x: (B,S,K) with K sharded over 'model'; w: (K, d) sharded on K.
    Returns (B,S,d) replicated over 'model'.
    """
    from jax.experimental.shard_map import shard_map

    def f(x_loc, w_loc):
        y_part = jnp.einsum("bsk,kd->bsd", x_loc, w_loc,
                            preferred_element_type=jnp.float32)
        return quantized_allreduce(y_part, "model").astype(out_dtype)

    return shard_map(f, mesh=mesh, in_specs=(x_spec, w_spec),
                     out_specs=P(*([None] * 3)), check_rep=False)(
        x_sharded_contract, w)
