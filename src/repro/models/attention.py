"""Attention: GQA with exact TP head layout, full / blocked / decode paths.

Full path:    one (Sq x Sk) logits tensor per kv-group     (train_4k)
Blocked path: block-causal online-softmax, python-unrolled  (prefill_32k;
              only lower-triangular blocks are emitted, so compiled FLOPs
              track the causal S^2/2 and live buffers stay block-sized)
Decode path:  one query token against a dense KV cache      (decode_32k)
Sliding-window (local) attention reuses all three with a window mask and a
ring-buffer cache for decode.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import RunPolicy, dense_init, head_rmsnorm, ones_init, rope_apply, zeros_init
from repro.models.layout import HeadLayout

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_init(cfg, layout: HeadLayout, key, dtype) -> Dict[str, Any]:
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    wq = dense_init(ks[0], (d, layout.n_q, hd), dtype, in_axis_size=d)
    wk = dense_init(ks[1], (d, layout.n_kv, hd), dtype, in_axis_size=d)
    wv = dense_init(ks[2], (d, layout.n_kv, hd), dtype, in_axis_size=d)
    wo = dense_init(ks[3], (layout.n_q, hd, d), dtype, in_axis_size=layout.n_q * hd)
    p = {
        "wq": layout.expand_q(wq, 1),
        "wk": layout.expand_kv(wk, 1),
        "wv": layout.expand_kv(wv, 1),
        "wo": layout.expand_q(wo, 0),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((layout.n_q_eff, hd), dtype)
        p["bk"] = zeros_init((layout.n_kv_eff, hd), dtype)
        p["bv"] = zeros_init((layout.n_kv_eff, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), dtype)
        p["k_norm"] = ones_init((hd,), dtype)
    return p


def _project_qkv(cfg, p, x, layout: HeadLayout, positions):
    """x: (B,S,d) -> q (B,S,N,P,D), k,v (B,S,N,D); RoPE applied."""
    B, S, _ = x.shape
    N, P, D = layout.n_kv_eff, layout.p, cfg.head_dim
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"], preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"], preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"], preferred_element_type=jnp.float32)
    q, k, v = q.astype(x.dtype), k.astype(x.dtype), v.astype(x.dtype)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"])
        k = head_rmsnorm(k, p["k_norm"])
    if cfg.pos_emb == "rope":
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, N, P, D)
    return q, k, v


def _out_proj(p, o, layout: HeadLayout, policy: Optional[RunPolicy] = None):
    # bf16 contraction: the row-parallel TP all-reduce then runs in bf16
    # (Megatron practice — halves wire bytes and collective buffer size)
    B, S = o.shape[:2]
    hd = p["wo"].shape[1]
    o = o.reshape(B, S, layout.n_q_eff * hd)
    if (policy is not None and policy.quantize_tp_collectives
            and policy.mesh is not None):
        from jax.sharding import PartitionSpec as P

        from repro.models.qcomm import rowparallel_matmul_q8

        w = p["wo"].reshape(layout.n_q_eff * hd, -1)
        return rowparallel_matmul_q8(
            o, w, policy.mesh,
            x_spec=P(None, None, "model"), w_spec=P("model", None),
            out_dtype=o.dtype)
    o = o.reshape(B, S, layout.n_q_eff, hd)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])


# ---------------------------------------------------------------------------
# Core scaled-dot-product over grouped heads
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, bias):
    """q (B,Sq,N,P,D); k,v (B,Sk,N,D); bias broadcastable to (B,N,P,Sq,Sk)."""
    D = q.shape[-1]
    logits = jnp.einsum("bqnpd,bknd->bnpqk", q, k, preferred_element_type=jnp.float32)
    logits = logits * (1.0 / math.sqrt(D)) + bias
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnpqk,bknd->bqnpd", probs.astype(v.dtype), v)
    return out


def _causal_bias(qpos, kpos, window: int):
    """Additive mask from absolute positions. qpos (Sq,)|(B,Sq); kpos (Sk,)|(B,Sk)."""
    if qpos.ndim == 1:
        qpos, kpos = qpos[:, None], kpos[None, :]
        expand = (1, 1, 1)
    else:
        qpos, kpos = qpos[:, :, None], kpos[:, None, :]
        expand = None
    ok = kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    ok &= kpos >= 0  # ring-buffer slots not yet written
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    if expand is not None:
        return bias[None, None, None]  # (1,1,1,Sq,Sk)
    return bias[:, None, None]  # (B,1,1,Sq,Sk)


# ---------------------------------------------------------------------------
# Full attention (train_4k) — also returns KV for cache building
# ---------------------------------------------------------------------------


def attn_apply(cfg, p, x, layout: HeadLayout, policy: RunPolicy, *, window: int = 0,
               positions=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, layout, positions)
    qb = policy.attn_q_block
    if qb and S > qb:
        o = _blocked_causal(q, k, v, qb, policy.attn_kv_block or qb, window)
    else:
        bias = _causal_bias(jnp.arange(S), jnp.arange(S), window)
        o = _sdpa(q, k, v, bias)
    return _out_proj(p, o, layout, policy), {"k": k, "v": v}


def _blocked_causal(q, k, v, QB: int, KB: int, window: int):
    """Python-unrolled block-causal online-softmax attention.

    Only blocks intersecting the causal (and window) band are emitted, so the
    lowered HLO carries ~S^2/2 logits FLOPs and O(QB*KB) live buffers. All
    einsums run head-major ((B,N,P,S,D) x (B,N,S,D)) so they lower to plain
    batched dot_generals — no materialized transpose copies of (QB,KB)
    buffers — and a zero-cost data dependency on the online-softmax carry
    serializes pairs so only one logits buffer is live at a time.
    """
    B, S, N, P, D = q.shape
    assert S % QB == 0 and S % KB == 0, (S, QB, KB)
    nq, nk = S // QB, S // KB
    scale = 1.0 / math.sqrt(D)
    qh = jnp.moveaxis(q, 1, 3)  # (B,N,P,S,D)
    kh = jnp.moveaxis(k, 1, 2)  # (B,N,S,D)
    vh = jnp.moveaxis(v, 1, 2)
    outs = []
    chain = jnp.zeros((), jnp.float32)  # serializes q-blocks
    for i in range(nq):
        qi = qh[:, :, :, i * QB : (i + 1) * QB]
        m = jnp.full((B, N, P, QB), NEG_INF, jnp.float32) + chain
        l = jnp.zeros((B, N, P, QB), jnp.float32)
        acc = jnp.zeros((B, N, P, QB, D), jnp.float32)
        q_lo, q_hi = i * QB, (i + 1) * QB - 1
        for j in range(nk):
            k_lo, k_hi = j * KB, (j + 1) * KB - 1
            if k_lo > q_hi:  # fully future
                continue
            if window > 0 and k_hi <= q_lo - window:  # fully out of window
                continue
            kj = kh[:, :, k_lo : k_lo + KB]
            vj = vh[:, :, k_lo : k_lo + KB]
            # data-dependency on the carry: stops XLA hoisting every pair's
            # logits matmul (one live (QB,KB) buffer instead of all pairs)
            kj = kj + (m[0, 0, 0, 0] * 0.0).astype(kj.dtype)
            logits = jnp.einsum("bnpqd,bnkd->bnpqk", qi, kj,
                                preferred_element_type=jnp.float32) * scale
            full_inside = k_hi <= q_lo and (window == 0 or k_lo > q_hi - window)
            if not full_inside:
                qpos = jnp.arange(q_lo, q_hi + 1)
                kpos = jnp.arange(k_lo, k_hi + 1)
                logits = logits + _causal_bias(qpos, kpos, window)[0]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pr = jnp.exp(logits - m_new[..., None])
            l = l * alpha + pr.sum(axis=-1)
            acc = acc * alpha[..., None]
            acc = acc + jnp.einsum("bnpqk,bnkd->bnpqd", pr.astype(v.dtype), vj,
                                   preferred_element_type=jnp.float32)
            m = m_new
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(o.astype(q.dtype))
        chain = o[0, 0, 0, 0, 0].astype(jnp.float32) * 0.0
    out = jnp.concatenate(outs, axis=3)  # (B,N,P,S,D)
    return jnp.moveaxis(out, 3, 1)  # (B,S,N,P,D)


# ---------------------------------------------------------------------------
# Decode (one token, dense or ring-buffer cache)
# ---------------------------------------------------------------------------


def _quant_heads(t):
    """t: (B,1,N,D) -> (int8 codes, f32 scales (B,1,N,1))."""
    s = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def attn_decode(cfg, p, x, layout: HeadLayout, policy: RunPolicy, pos, cache,
                *, window: int = 0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B,1,d); pos: (B,) absolute position of the new token.

    cache: {'k','v'}: (B, S, N, D) dense, or (B, W, N, D) ring when window>0.
    int8-quantized cache adds {'ks','vs'} per-(token,head) scales (the decode
    memory-term lever — halves HBM bytes per step).
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(cfg, p, x, layout, pos[:, None])
    quant = "ks" in cache
    if quant:
        k_w, ks_w = _quant_heads(k_new)
        v_w, vs_w = _quant_heads(v_new)
    else:
        k_w, v_w = k_new, v_new
    ck, cv = cache["k"], cache["v"]
    Sc = ck.shape[1]
    bidx = jnp.arange(B)
    if window > 0 and Sc == window:  # ring buffer
        slot = pos % window
        idx = slot
        s = jnp.arange(window)[None, :]
        # slot s holds absolute position pos - ((pos - s) mod W); neg => unwritten
        kpos = pos[:, None] - jnp.mod(pos[:, None] - s, window)
    else:
        idx = pos
        kpos = jnp.broadcast_to(jnp.arange(Sc)[None, :], (B, Sc))
    ck = ck.at[bidx, idx].set(k_w[:, 0])
    cv = cv.at[bidx, idx].set(v_w[:, 0])
    out_cache = {"k": ck, "v": cv}
    if quant:
        ks = cache["ks"].at[bidx, idx].set(ks_w[:, 0])
        vs = cache["vs"].at[bidx, idx].set(vs_w[:, 0])
        out_cache["ks"], out_cache["vs"] = ks, vs
        ck = (ck.astype(jnp.float32) * ks).astype(k_new.dtype)
        cv = (cv.astype(jnp.float32) * vs).astype(v_new.dtype)
    bias = _causal_bias(pos[:, None], kpos, window)  # (B,1,1,1,Sc)

    o = _sdpa(q, ck, cv, bias)
    return _out_proj(p, o, layout, policy), out_cache
