"""RWKV6 (Finch): attention-free time-mix with data-dependent decay + channel-mix.

wkv6 recurrence per head (K=V=head_size):
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(exp(wlog_t)) S_{t-1} + k_t v_t^T ,   wlog_t = -exp(w0 + lora(x_t)) < 0

`wkv6_ref` is the per-token scan oracle; `wkv6_chunked` is the chunkwise-parallel
form used by the model (all pairwise decay exponents are differences of cumsums
with s <= t, hence <= 0: exp() never overflows). Chunks advance under lax.scan;
see DESIGN.md §Roofline for the while-loop FLOPs-accounting note.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import RunPolicy, dense_init, ones_init, zeros_init

_COMPONENTS = 5  # w, k, v, r, g


def rwkv_att_init(cfg, key, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    r = cfg.rwkv_lora_rank
    ks = jax.random.split(key, 12)
    w0 = -6.0 + 5.0 * (np.arange(d) / max(1, d - 1)) ** 0.7  # slow->fast decay
    return {
        "mu_x": 0.5 * ones_init((d,), dtype),
        "mu": 0.5 * ones_init((_COMPONENTS, d), dtype),
        "lora_A": dense_init(ks[0], (d, _COMPONENTS * r), dtype),
        "lora_B": 0.0 * dense_init(ks[1], (_COMPONENTS, r, d), dtype),
        "w0": jnp.asarray(w0, jnp.float32),
        "w_lora_A": dense_init(ks[2], (d, 2 * r), dtype),
        "w_lora_B": 0.0 * dense_init(ks[3], (2 * r, d), dtype),
        "wr": dense_init(ks[4], (d, d), dtype),
        "wk": dense_init(ks[5], (d, d), dtype),
        "wv": dense_init(ks[6], (d, d), dtype),
        "wg": dense_init(ks[7], (d, d), dtype),
        "wo": dense_init(ks[8], (d, d), dtype),
        "u": 0.1 * dense_init(ks[9], (H, hs), jnp.float32, in_axis_size=1),
        "ln_scale": ones_init((d,), dtype),
        "ln_bias": zeros_init((d,), dtype),
    }


def rwkv_ffn_init(cfg, key, dtype) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": 0.5 * ones_init((d,), dtype),
        "mu_r": 0.5 * ones_init((d,), dtype),
        "wk": dense_init(ks[0], (d, f), dtype),
        "wv": dense_init(ks[1], (f, d), dtype),
        "wr": dense_init(ks[2], (d, d), dtype),
    }


# ---------------------------------------------------------------------------
# wkv6 core
# ---------------------------------------------------------------------------


def wkv6_ref(r, k, v, wlog, u, s0):
    """Per-token scan oracle. r,k,v,wlog: (B,S,H,K) ; u: (H,K) ; s0: (B,H,K,K)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, wlog))

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,K)
        bonus = jnp.einsum("bhk,hk,bhk->bh", rt, u, kt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S) + bonus[..., None] * vt
        S = jnp.exp(wt)[..., None] * S + kt[..., None] * vt[..., None, :]
        return S, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), sT


def wkv6_chunked(r, k, v, wlog, u, s0, chunk: int):
    """Chunkwise-parallel wkv6; exact (all decay exponents <= 0)."""
    B, S, H, K = r.shape
    C = min(chunk, S)
    while S % C:
        C //= 2
    n = S // C

    def to_chunks(a):
        return jnp.moveaxis(a.astype(jnp.float32).reshape(B, n, C, H, K), 3, 2)  # (B,n,H,C,K)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, wlog))
    lcum = jnp.cumsum(wc, axis=3)  # (B,n,H,C,K)
    pexc = lcum - wc  # exclusive cumsum  = Lcum_{t-1}
    tri = jnp.tril(jnp.ones((C, C), jnp.float32), -1)  # s < t

    def one_chunk(S0, inp):
        rt, kt, vt, lc, pe = inp  # (B,H,C,K) each
        # intra-chunk pairwise decay: exp(P[t] - Lcum[s]) for s<t  (<=0 exponent)
        E = jnp.exp(pe[:, :, :, None, :] - lc[:, :, None, :, :])  # (B,H,C,C,K)
        A = jnp.einsum("bhtk,bhsk,bhtsk->bhts", rt, kt, E) * tri
        bonus = jnp.einsum("bhtk,hk,bhtk->bht", rt, u, kt)
        A = A + jnp.eye(C, dtype=jnp.float32) * bonus[..., None]
        y = jnp.einsum("bhts,bhsv->bhtv", A, vt)
        # inter-chunk: r_t decayed back to chunk start, applied to S0
        y = y + jnp.einsum("bhtk,bhkv->bhtv", rt * jnp.exp(pe), S0)
        # state to chunk end
        decay_end = jnp.exp(lc[:, :, -1:, :] - lc)  # (B,H,C,K), <=0 exponent
        S1 = jnp.exp(lc[:, :, -1, :])[..., None] * S0 + jnp.einsum(
            "bhsk,bhsv->bhkv", kt * decay_end, vt)
        return S1, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, lcum, pexc))
    sT, ys = jax.lax.scan(one_chunk, s0.astype(jnp.float32), xs)
    ys = jnp.moveaxis(ys, 0, 1)  # (B,n,H,C,K)
    return jnp.moveaxis(ys, 2, 3).reshape(B, S, H, K).astype(r.dtype), sT


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _ddlerp(p, x, sx):
    """Data-dependent token-shift mixes -> per-component mixed inputs (5, B,S,d)."""
    xx = x + sx * p["mu_x"]
    r = p["lora_B"].shape[1]
    lo = jnp.tanh(xx @ p["lora_A"])  # (B,S,5r)
    lo = lo.reshape(lo.shape[:-1] + (_COMPONENTS, r))
    lo = jnp.einsum("bscr,crd->cbsd", lo, p["lora_B"])
    mixes = p["mu"][:, None, None, :] + lo  # (5,B,S,d)
    return x[None] + sx[None] * mixes


def rwkv_att_apply(cfg, p, x, policy: RunPolicy, x_prev=None, s0=None,
                   return_cache: bool = False):
    """x: (B,S,d). x_prev: (B,d) last token of the previous segment (or zeros)."""
    B, S, d = x.shape
    hs = cfg.rwkv_head_size
    H = d // hs
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    if s0 is None:
        s0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    sx = shifted - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)
    r = (xr @ p["wr"]).reshape(B, S, H, hs)
    k = (xk @ p["wk"]).reshape(B, S, H, hs)
    v = (xv @ p["wv"]).reshape(B, S, H, hs)
    g = jax.nn.silu(xg @ p["wg"])
    wlog = -jnp.exp(p["w0"] + jnp.tanh(xw @ p["w_lora_A"]) @ p["w_lora_B"])
    wlog = wlog.reshape(B, S, H, hs)
    y, sT = wkv6_chunked(r, k, v, wlog, p["u"], s0, policy.rwkv_chunk)
    y = _head_groupnorm(y.reshape(B, S, d), p["ln_scale"], p["ln_bias"], H)
    out = (y * g) @ p["wo"]
    if return_cache:
        return out, {"s": sT, "x_prev": x[:, -1]}
    return out


def _head_groupnorm(y, scale, bias, H, eps: float = 64e-5):
    B, S, d = y.shape
    yf = y.astype(jnp.float32).reshape(B, S, H, d // H)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yn = ((yf - mu) * jax.lax.rsqrt(var + eps)).reshape(B, S, d)
    return (yn * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(y.dtype)


def rwkv_ffn_apply(cfg, p, x, x_prev=None, return_cache: bool = False):
    B, S, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    sx = shifted - x
    xk = x + sx * p["mu_k"]
    xr = x + sx * p["mu_r"]
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (h @ p["wv"])
    if return_cache:
        return out, x[:, -1]
    return out
