"""TransformerLM: one composable decoder covering all 10 assigned archs.

Pure-functional: params are nested dict pytrees; `forward`/`prefill`/
`decode_step` are jit/pjit-compatible. Layers run python-unrolled (accurate
HLO cost/collective accounting — see DESIGN.md §Roofline) or under
lax.scan + remat for the memory-bounded full train_step artifact.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv as rwkv_mod
from repro.models.cache import kv_head_layout
from repro.models.layers import (
    RunPolicy,
    apply_norm,
    dense_init,
    mlp_apply,
    mlp_init,
    norm_init,
    sinusoidal_table,
)
from repro.models.layout import HeadLayout


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_init(cfg: ArchConfig, kind: str, key, dtype, tp: int) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.norm, cfg.d_model, dtype),
                         "norm2": norm_init(cfg.norm, cfg.d_model, dtype)}
    if kind in ("attention", "local"):
        p["mixer"] = attn.attn_init(cfg, kv_head_layout(cfg, tp), k1, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.rglru_init(cfg, k1, dtype)
    elif kind == "rwkv6":
        p["mixer"] = rwkv_mod.rwkv_att_init(cfg, k1, dtype)
    else:
        raise ValueError(kind)
    if kind == "rwkv6":
        p["ffn"] = rwkv_mod.rwkv_ffn_init(cfg, k2, dtype)
    elif cfg.is_moe:
        p["ffn"] = moe_mod.moe_init(cfg, k2, dtype, tp)
    else:
        p["ffn"] = mlp_init(cfg, k2, dtype)
    return p


def init_params(cfg: ArchConfig, key, *, dtype=jnp.float32, tp: int = 1) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.num_layers + 3)
    params: Dict[str, Any] = {
        "layers": [
            _layer_init(cfg, kind, keys[i], dtype, tp)
            for i, kind in enumerate(cfg.layer_kinds())
        ],
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    need_embed = cfg.input_kind == "tokens" or cfg.tie_embeddings
    if need_embed:
        params["embed"] = {
            "w": dense_init(keys[-1], (cfg.vocab_size, cfg.d_model), dtype,
                            in_axis_size=cfg.d_model)
        }
    if not cfg.tie_embeddings:
        params["head"] = {"w": dense_init(keys[-2], (cfg.d_model, cfg.vocab_size), dtype)}
    return params


def init_params_specs(cfg: ArchConfig, *, dtype=jnp.bfloat16, tp: int = 1):
    """ShapeDtypeStruct tree of params (no allocation) — dry-run input."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype=dtype, tp=tp))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_apply(cfg, kind: str, p, x, policy: RunPolicy, layout: Optional[HeadLayout],
                 positions) -> Tuple[jax.Array, jax.Array]:
    """Residual block. Returns (x, moe_aux)."""
    h = apply_norm(cfg.norm, x, p["norm1"])
    if kind in ("attention", "local"):
        window = cfg.local_window if kind == "local" else 0
        mixed, _ = attn.attn_apply(cfg, p["mixer"], h, layout, policy,
                                   window=window, positions=positions)
    elif kind == "rglru":
        mixed = rglru_mod.rglru_apply(cfg, p["mixer"], h, policy)
    elif kind == "rwkv6":
        mixed = rwkv_mod.rwkv_att_apply(cfg, p["mixer"], h, policy)
    else:
        raise ValueError(kind)
    x = x + mixed
    x = policy.c(x, "residual")
    h = apply_norm(cfg.norm, x, p["norm2"])
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv6":
        y = rwkv_mod.rwkv_ffn_apply(cfg, p["ffn"], h)
    elif cfg.is_moe:
        y, aux = moe_mod.moe_apply(cfg, p["ffn"], h, policy, tp=policy_tp(policy))
    else:
        y = mlp_apply(cfg, p["ffn"], h, policy)
    x = x + y
    return policy.c(x, "residual"), aux


def policy_tp(policy: RunPolicy) -> int:
    return getattr(policy, "_tp", 1)


def set_policy_tp(policy: RunPolicy, tp: int) -> RunPolicy:
    policy._tp = tp  # stored out-of-band; moe padding depends on it
    return policy


# ---------------------------------------------------------------------------
# Stacked-layer layout (scan-friendly: one (L, ...) tree instead of L dicts).
# MaxText-style storage for scanned layers — the memory-artifact lowerings use
# it so scan-bwd accumulates into param-shaped buffers, not L separate ones.
# ---------------------------------------------------------------------------


def is_stacked(params) -> bool:
    return isinstance(params["layers"], dict)


def stack_params(params):
    """{'layers': [d0..dL-1]} -> {'layers': tree with leading L dim}."""
    if is_stacked(params):
        return params
    out = dict(params)
    out["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *params["layers"])
    return out


def layer_slice(layers, i: int):
    """Layer i's param dict from either layout."""
    if isinstance(layers, dict):
        return jax.tree.map(lambda a: a[i], layers)
    return layers[i]


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed_in(cfg, params, tokens, policy: RunPolicy, positions):
    if cfg.input_kind == "embeddings" and tokens.ndim == 3:
        x = tokens
    else:
        w = params["embed"]["w"]
        if policy.onehot_embed:
            oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=w.dtype)
            x = jnp.einsum("bsv,vd->bsd", oh, w)
        else:
            x = jnp.take(w, tokens, axis=0)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_table(positions, cfg.d_model).astype(x.dtype)
    return x


def logits_out(cfg, params, x, policy: RunPolicy):
    if cfg.tie_embeddings:
        w = params["embed"]["w"]  # (V,d)
        logits = jnp.einsum("bsd,vd->bsv", x, w, preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)
    return policy.c(logits, "logits")


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(cfg: ArchConfig, params, tokens, policy: RunPolicy,
            positions=None) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B,S) int32 or (B,S,d) embeddings. Returns (logits, moe_aux)."""
    S = tokens.shape[1]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    layout = kv_head_layout(cfg, policy_tp(policy)) if cfg.mixer != "rwkv6" else None
    x = embed_in(cfg, params, tokens, policy, positions)
    x = policy.c(x, "residual")
    kinds = cfg.layer_kinds()
    aux_total = jnp.zeros((), jnp.float32)

    homogeneous = len(set(kinds)) == 1
    if policy.scan_layers and homogeneous:
        stacked = stack_params(params)["layers"]

        def body(carry, lp):
            h, aux = carry
            h, a = _block_apply(cfg, kinds[0], lp, h, policy, layout, positions)
            return (h, aux + a), None

        body_fn = jax.checkpoint(body) if policy.remat else body
        (x, aux_total), _ = jax.lax.scan(body_fn, (x, aux_total), stacked)
    else:
        for i, kind in enumerate(kinds):
            def blk(h, lp, _kind=kind):
                return _block_apply(cfg, _kind, lp, h, policy, layout, positions)

            if policy.remat:
                blk = jax.checkpoint(blk)
            x, a = blk(x, layer_slice(params["layers"], i))
            aux_total = aux_total + a

    x = apply_norm(cfg.norm, x, params["final_norm"])
    return logits_out(cfg, params, x, policy), aux_total


def loss_fn(cfg: ArchConfig, params, batch: Dict[str, Any], policy: RunPolicy):
    """Next-token cross-entropy (labels already shifted by the data pipeline)."""
    logits, aux = forward(cfg, params, batch["tokens"], policy)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = ce + 0.01 * aux / max(1, cfg.num_layers)
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(cfg: ArchConfig, params, tokens, policy: RunPolicy
            ) -> Tuple[jax.Array, List[Dict[str, Any]]]:
    """Run the full prompt, return (last-position logits, decode cache).

    With policy.scan_layers (homogeneous archs) layers run under lax.scan and
    the cache comes back L-stacked — the memory-bounded lowering used by the
    dry-run's prefill_memory artifact.
    """
    B, S = tokens.shape[0], tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    layout = kv_head_layout(cfg, policy_tp(policy)) if cfg.mixer != "rwkv6" else None
    kinds = cfg.layer_kinds()
    if policy.scan_layers and len(set(kinds)) == 1 and kinds[0] in ("attention", "rwkv6"):
        x = embed_in(cfg, params, tokens, policy, positions)
        stacked = stack_params(params)["layers"]

        def body(h, lp):
            h0 = apply_norm(cfg.norm, h, lp["norm1"])
            if kinds[0] == "attention":
                mixed, c = attn.attn_apply(cfg, lp["mixer"], h0, layout, policy,
                                           positions=positions)
            else:
                mixed, ac = rwkv_mod.rwkv_att_apply(cfg, lp["mixer"], h0, policy,
                                                    return_cache=True)
                c = {"s": ac["s"], "xa": ac["x_prev"]}
            h = policy.c(h + mixed, "residual")
            h2 = apply_norm(cfg.norm, h, lp["norm2"])
            if kinds[0] == "rwkv6":
                y, xf = rwkv_mod.rwkv_ffn_apply(cfg, lp["ffn"], h2, return_cache=True)
                c["xf"] = xf
            elif cfg.is_moe:
                y, _ = moe_mod.moe_apply(cfg, lp["ffn"], h2, policy,
                                         tp=policy_tp(policy))
            else:
                y = mlp_apply(cfg, lp["ffn"], h2, policy)
            return policy.c(h + y, "residual"), c

        x, caches = jax.lax.scan(body, x, stacked)
        x = apply_norm(cfg.norm, x, params["final_norm"])
        return logits_out(cfg, params, x[:, -1:], policy), caches
    x = embed_in(cfg, params, tokens, policy, positions)
    caches: List[Dict[str, Any]] = []
    for i, kind in enumerate(cfg.layer_kinds()):
        p = layer_slice(params["layers"], i)
        h = apply_norm(cfg.norm, x, p["norm1"])
        if kind in ("attention", "local"):
            window = cfg.local_window if kind == "local" else 0
            mixed, kv = attn.attn_apply(cfg, p["mixer"], h, layout, policy,
                                        window=window, positions=positions)
            if kind == "local" and S > cfg.local_window:
                W = cfg.local_window
                ring_k = jnp.roll(kv["k"][:, S - W:], S % W, axis=1)
                ring_v = jnp.roll(kv["v"][:, S - W:], S % W, axis=1)
                caches.append({"k": ring_k, "v": ring_v})
            else:
                caches.append(kv)
        elif kind == "rglru":
            mixed, c = rglru_mod.rglru_apply(cfg, p["mixer"], h, policy, return_cache=True)
            caches.append(c)
        elif kind == "rwkv6":
            mixed, c = rwkv_mod.rwkv_att_apply(cfg, p["mixer"], h, policy, return_cache=True)
            caches.append({"s": c["s"], "xa": c["x_prev"], "xf": None})
        x = x + mixed
        x = policy.c(x, "residual")
        h2 = apply_norm(cfg.norm, x, p["norm2"])
        if kind == "rwkv6":
            y, xf = rwkv_mod.rwkv_ffn_apply(cfg, p["ffn"], h2, return_cache=True)
            caches[-1]["xf"] = xf
        elif cfg.is_moe:
            y, _ = moe_mod.moe_apply(cfg, p["ffn"], h2, policy, tp=policy_tp(policy))
        else:
            y = mlp_apply(cfg, p["ffn"], h2, policy)
        x = policy.c(x + y, "residual")
    x = apply_norm(cfg.norm, x, params["final_norm"])
    logits = logits_out(cfg, params, x[:, -1:], policy)
    return logits, caches


def decode_step(cfg: ArchConfig, params, tokens, pos, cache, policy: RunPolicy
                ) -> Tuple[jax.Array, List[Dict[str, Any]]]:
    """One token: tokens (B,1) | (B,1,d); pos (B,) absolute positions.

    With policy.scan_layers + stacked params + stacked cache (leading L dim),
    layers run under lax.scan — the dry-run's decode_memory lowering (cache
    update buffers are reused across layers)."""
    layout = kv_head_layout(cfg, policy_tp(policy)) if cfg.mixer != "rwkv6" else None
    kinds = cfg.layer_kinds()
    if (policy.scan_layers and len(set(kinds)) == 1
            and kinds[0] in ("attention", "rwkv6") and is_stacked(params)
            and isinstance(cache, dict)):
        x = embed_in(cfg, params, tokens, policy, pos[:, None])

        def body(h, lp_c):
            lp, c = lp_c
            h0 = apply_norm(cfg.norm, h, lp["norm1"])
            if kinds[0] == "attention":
                mixed, nc = attn.attn_decode(cfg, lp["mixer"], h0, layout, policy,
                                             pos, c)
            else:
                mixed, ac = rwkv_mod.rwkv_att_apply(cfg, lp["mixer"], h0, policy,
                                                    x_prev=c["xa"], s0=c["s"],
                                                    return_cache=True)
                nc = {"s": ac["s"], "xa": ac["x_prev"], "xf": c["xf"]}
            h = h + mixed
            h2 = apply_norm(cfg.norm, h, lp["norm2"])
            if kinds[0] == "rwkv6":
                y, xf = rwkv_mod.rwkv_ffn_apply(cfg, lp["ffn"], h2, x_prev=c["xf"],
                                                return_cache=True)
                nc["xf"] = xf
            elif cfg.is_moe:
                y, _ = moe_mod.moe_apply(cfg, lp["ffn"], h2, policy,
                                         tp=policy_tp(policy))
            else:
                y = mlp_apply(cfg, lp["ffn"], h2, policy)
            return h + y, nc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        x = apply_norm(cfg.norm, x, params["final_norm"])
        return logits_out(cfg, params, x, policy), new_cache

    x = embed_in(cfg, params, tokens, policy, pos[:, None])
    new_cache: List[Dict[str, Any]] = []
    for i, kind in enumerate(kinds):
        p = layer_slice(params["layers"], i)
        c = cache[i]
        h = apply_norm(cfg.norm, x, p["norm1"])
        if kind in ("attention", "local"):
            window = cfg.local_window if kind == "local" else 0
            mixed, nc = attn.attn_decode(cfg, p["mixer"], h, layout, policy, pos, c,
                                         window=window)
        elif kind == "rglru":
            mixed, nc = rglru_mod.rglru_decode(cfg, p["mixer"], h, policy, c)
        elif kind == "rwkv6":
            mixed, ac = rwkv_mod.rwkv_att_apply(cfg, p["mixer"], h, policy,
                                                x_prev=c["xa"], s0=c["s"],
                                                return_cache=True)
            nc = {"s": ac["s"], "xa": ac["x_prev"], "xf": c["xf"]}
        x = x + mixed
        h2 = apply_norm(cfg.norm, x, p["norm2"])
        if kind == "rwkv6":
            y, xf = rwkv_mod.rwkv_ffn_apply(cfg, p["ffn"], h2, x_prev=c["xf"],
                                            return_cache=True)
            nc["xf"] = xf
        elif cfg.is_moe:
            y, _ = moe_mod.moe_apply(cfg, p["ffn"], h2, policy, tp=policy_tp(policy))
        else:
            y = mlp_apply(cfg, p["ffn"], h2, policy)
        x = x + y
        new_cache.append(nc)
    x = apply_norm(cfg.norm, x, params["final_norm"])
    return logits_out(cfg, params, x, policy), new_cache


# ---------------------------------------------------------------------------
# Exactness hooks for the padded TP head layout (see models/layout.py)
# ---------------------------------------------------------------------------


def grad_mask(cfg: ArchConfig, params, tp: int):
    """0/1 tree: zero out grads of structurally-padded parameters.

    Masks broadcast from the right, so the same mask tree serves both the
    per-layer-list and the stacked (L, ...) layouts."""
    mask = jax.tree.map(lambda a: jnp.ones((), a.dtype), params)
    if cfg.mixer == "rwkv6":
        return mask
    lay = kv_head_layout(cfg, tp)
    qm = jnp.asarray(~lay.q_pad_mask(), jnp.float32)  # 1 = real head
    km = jnp.asarray(~lay.kv_pad_mask(), jnp.float32)
    stacked = is_stacked(params)
    entries = [mask["layers"]] if stacked else [
        mask["layers"][i] for i, kind in enumerate(cfg.layer_kinds())
        if kind in ("attention", "local")]
    for m_l in entries:
        m = m_l["mixer"]
        m["wq"] = qm[None, :, None]
        m["wo"] = qm[:, None, None]
        if lay.pad:
            m["wk"] = km[None, :, None]
            m["wv"] = km[None, :, None]
        if cfg.qkv_bias:
            m["bq"] = qm[:, None]
            if lay.pad:
                m["bk"] = km[:, None]
                m["bv"] = km[:, None]
    if cfg.is_moe and moe_mod.num_experts_eff(cfg, tp) != cfg.num_experts:
        em = (jnp.arange(moe_mod.num_experts_eff(cfg, tp)) < cfg.num_experts
              ).astype(jnp.float32)
        ffns = [mask["layers"]["ffn"]] if stacked else [
            mask["layers"][i]["ffn"] for i in range(cfg.num_layers)]
        for f in ffns:
            f["router"] = em[None, :]
            for kname in ("w_gate", "w_up", "w_down"):
                f[kname] = em[:, None, None]
    return mask


def sync_replica_grads(cfg: ArchConfig, grads, tp: int):
    """Sum KV-projection grads across replicas (keeps replicas identical)."""
    if cfg.mixer == "rwkv6":
        return grads
    lay = kv_head_layout(cfg, tp)
    if lay.rep == 1:
        return grads
    if is_stacked(grads):
        g = grads["layers"]["mixer"]
        g["wk"] = lay.reduce_kv_grad(g["wk"], 2)  # (L, d, Hkv, hd)
        g["wv"] = lay.reduce_kv_grad(g["wv"], 2)
        if cfg.qkv_bias:
            g["bk"] = lay.reduce_kv_grad(g["bk"], 1)
            g["bv"] = lay.reduce_kv_grad(g["bv"], 1)
        return grads
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind not in ("attention", "local"):
            continue
        g = grads["layers"][i]["mixer"]
        g["wk"] = lay.reduce_kv_grad(g["wk"], 1)
        g["wv"] = lay.reduce_kv_grad(g["wv"], 1)
        if cfg.qkv_bias:
            g["bk"] = lay.reduce_kv_grad(g["bk"], 0)
            g["bv"] = lay.reduce_kv_grad(g["bv"], 0)
    return grads
