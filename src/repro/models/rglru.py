"""Griffin/RecurrentGemma recurrent block: temporal conv + RG-LRU.

RG-LRU: a_t = exp(-c * softplus(L) * r_t),  h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t x_t)
with block-diagonal (per-head) input/recurrence gates. Sequence mixing uses
jax.lax.associative_scan (log-depth, FLOPs fully visible to HLO cost analysis);
decode is a single fused step carrying (h, conv window).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import RunPolicy, dense_init, zeros_init

_C = 8.0


def rglru_init(cfg, key, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    w = cfg.lru_width or d
    H = cfg.num_heads
    hw = w // H
    ks = jax.random.split(key, 6)
    # Lambda init so a^c in ~U[0.9, 0.999] (paper's stable range)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.exp(-jnp.log(u) / _C) - 1.0)  # softplus^-1(-log(u)/c)
    return {
        "w_y": dense_init(ks[0], (d, w), dtype),
        "w_gate": dense_init(ks[1], (d, w), dtype),
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), dtype, in_axis_size=cfg.conv_width),
        "conv_b": zeros_init((w,), dtype),
        "gate_i": dense_init(ks[3], (H, hw, hw), dtype, in_axis_size=hw),
        "gate_r": dense_init(ks[4], (H, hw, hw), dtype, in_axis_size=hw),
        "bias_i": zeros_init((w,), dtype),
        "bias_r": zeros_init((w,), dtype),
        "lambda": lam.astype(jnp.float32),
        "w_out": dense_init(key, (w, d), dtype),
    }


def _blockdiag(x, w, H):
    """x: (...,w) @ blockdiag w -> (...,w); w: (H, hw, hw)."""
    shp = x.shape
    xh = x.reshape(shp[:-1] + (H, shp[-1] // H))
    y = jnp.einsum("...hi,hij->...hj", xh, w, preferred_element_type=jnp.float32)
    return y.reshape(shp).astype(x.dtype)


def _gates(cfg, p, xc):
    H = cfg.num_heads
    i_t = jax.nn.sigmoid(_blockdiag(xc, p["gate_i"], H).astype(jnp.float32) + p["bias_i"])
    r_t = jax.nn.sigmoid(_blockdiag(xc, p["gate_r"], H).astype(jnp.float32) + p["bias_r"])
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r_t  # (B,[S],w) f32, <= 0
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = beta * i_t * xc.astype(jnp.float32)
    return jnp.exp(log_a), gated


def _conv_train(p, y, conv_width):
    """Causal depthwise temporal conv via shifts. y: (B,S,w)."""
    out = y * p["conv_w"][conv_width - 1]
    for k in range(1, conv_width):
        shifted = jnp.pad(y, ((0, 0), (k, 0), (0, 0)))[:, : y.shape[1]]
        out = out + shifted * p["conv_w"][conv_width - 1 - k]
    return out + p["conv_b"]


def rglru_apply(cfg, p, x, policy: RunPolicy, return_cache: bool = False):
    """Train/prefill over full sequence. x: (B,S,d)."""
    y = x @ p["w_y"]
    gate = jax.nn.gelu((x @ p["w_gate"]).astype(jnp.float32), approximate=True)
    yc = _conv_train(p, y, cfg.conv_width)
    a, gated = _gates(cfg, p, yc)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    out = (h * gate).astype(x.dtype)
    out = out @ p["w_out"]
    if return_cache:
        cw = cfg.conv_width
        cache = {"h": h[:, -1], "conv": y[:, -(cw - 1):]}
        return out, cache
    return out


def rglru_decode(cfg, p, x, policy: RunPolicy, cache: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One step. x: (B,1,d); cache: {'h': (B,w) f32, 'conv': (B,cw-1,w)}."""
    xt = x[:, 0]
    y = xt @ p["w_y"]  # (B,w)
    gate = jax.nn.gelu((xt @ p["w_gate"]).astype(jnp.float32), approximate=True)
    win = jnp.concatenate([cache["conv"], y[:, None]], axis=1)  # (B,cw,w)
    yc = jnp.einsum("bkw,kw->bw", win, p["conv_w"]) + p["conv_b"]
    a, gated = _gates(cfg, p, yc)
    h = a * cache["h"] + gated
    out = (h * gate).astype(x.dtype) @ p["w_out"]
    return out[:, None], {"h": h, "conv": win[:, 1:]}
