"""Shared layer primitives: norms, RoPE, MLPs, inits, run policy."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Run policy: how to execute a forward (chunking, remat, sharding hooks)
# ---------------------------------------------------------------------------


def _no_constrain(x, name: str):
    return x


@dataclass
class RunPolicy:
    """Execution knobs for a forward/step lowering.

    constrain(x, name) inserts sharding constraints (installed by
    launch/sharding.py); names: 'residual', 'logits', 'heads'.
    """

    scan_layers: bool = False
    remat: bool = False
    attn_q_block: int = 0  # 0 => unblocked attention
    attn_kv_block: int = 0
    rwkv_chunk: int = 128
    onehot_embed: bool = False  # TPU-friendly sharded embedding lookup
    constrain: Callable = _no_constrain
    moe_capacity_factor: float = 1.25
    # beyond-paper perf levers (§Perf)
    quantize_tp_collectives: bool = False  # int8 two-phase TP all-reduce
    kv_cache_quant: bool = False  # int8 KV cache (decode memory term)
    moe_impl: str = "dense"  # dense (GShard einsum) | sorted (scatter)
    mesh: Any = None  # set by launch/sharding.make_run_policy

    def c(self, x, name):
        return self.constrain(x, name)


# ---------------------------------------------------------------------------
# Inits
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis_size: Optional[int] = None):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def zeros_init(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms (compute in f32, cast back)
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(x, scale, eps: float = 1e-6):
    """Per-head qk-norm over head_dim. Affine scale only (keeps zero heads zero)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def apply_norm(kind: str, x, p: Dict[str, Any]):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def norm_init(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"scale": ones_init((d,), dtype)}
    return {"scale": ones_init((d,), dtype), "bias": zeros_init((d,), dtype)}


# ---------------------------------------------------------------------------
# Positional embeddings
# ---------------------------------------------------------------------------


def rope_apply(x, positions, theta: float):
    """x: (..., S, H, D); positions broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_table(positions, d: int):
    """positions: (...,) int -> (..., d) sinusoidal embedding."""
    half = d // 2
    freq = (1.0 / 10_000.0) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------


def mlp_init(cfg, key, dtype) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d, f), dtype),
            "w_up": dense_init(ks[1], (d, f), dtype),
            "w_down": dense_init(ks[2], (f, d), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d, f), dtype),
        "b_up": zeros_init((f,), dtype),
        "w_down": dense_init(ks[1], (f, d), dtype),
        "b_down": zeros_init((d,), dtype),
    }


def _down_proj(h, w_down, policy: RunPolicy):
    if policy.quantize_tp_collectives and policy.mesh is not None:
        from jax.sharding import PartitionSpec as P

        from repro.models.qcomm import rowparallel_matmul_q8

        return rowparallel_matmul_q8(
            h, w_down, policy.mesh,
            x_spec=P(None, None, "model"), w_spec=P("model", None),
            out_dtype=h.dtype)
    return h @ w_down


def mlp_apply(cfg, p, x, policy: RunPolicy):
    if cfg.mlp_act == "swiglu":
        g = jax.nn.silu(x @ p["w_gate"])
        h = g * (x @ p["w_up"])
        return _down_proj(h, p["w_down"], policy)
    if cfg.mlp_act == "geglu":
        g = jax.nn.gelu(x @ p["w_gate"], approximate=True)
        h = g * (x @ p["w_up"])
        return _down_proj(h, p["w_down"], policy)
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"], approximate=True)
    return _down_proj(h, p["w_down"], policy) + p["b_down"]
