from repro.models.layers import RunPolicy  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    grad_mask,
    init_params,
    init_params_specs,
    loss_fn,
    prefill,
    set_policy_tp,
    sync_replica_grads,
)
