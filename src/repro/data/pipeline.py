"""Data pipeline: deterministic synthetic LM stream + sharded prefetching loader.

Deterministic per (seed, step): restart-safe — resuming from a checkpoint at
step k reproduces the exact batch sequence, which the fault-tolerance tests
rely on. Documents are sampled with power-law lengths and packed into fixed
seq_len rows with EOS separators (realistic label masking at pack joints).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

EOS = 1
PAD_LABEL = -1


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    emb_dim: int = 0  # >0: emit embeddings (vlm/audio backbone stubs)

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens, labels); tokens (B,S) int32 or (B,S,E) f32."""
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, S = self.global_batch, self.seq_len
        toks = np.empty((B, S), np.int32)
        labels = np.empty((B, S), np.int32)
        for b in range(B):
            row = []
            while len(row) < S + 1:
                ln = max(8, int(rng.pareto(2.0) * self.mean_doc_len))
                doc = rng.integers(2, self.vocab_size, size=ln)
                row.extend(doc.tolist())
                row.append(EOS)
            row = np.asarray(row[: S + 1], np.int32)
            toks[b] = row[:-1]
            labels[b] = row[1:]
            labels[b][row[:-1] == EOS] = PAD_LABEL  # don't predict across joints
        if self.emb_dim:
            emb = rng.standard_normal((B, S, self.emb_dim), np.float32)
            return emb, labels
        return toks, labels


class DataLoader:
    """Background-thread prefetching iterator with explicit step state."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0, prefetch: int = 2,
                 shard_fn=None):
        self.ds = ds
        self.step = start_step
        self.shard_fn = shard_fn or (lambda x: x)
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.ds.batch(step)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, (tokens, labels) = self._q.get()
        self.step = step + 1
        return step, {"tokens": self.shard_fn(tokens), "labels": self.shard_fn(labels)}

    def seek(self, step: int) -> None:
        """Rewind/advance the stream to `step` (checkpoint restore path)."""
        self._stop.set()
        self._thread.join(timeout=2)
        while not self._q.empty():
            self._q.get_nowait()
        self.step = step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator:
        return self

    def close(self):
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()
        self._thread.join(timeout=2)
