from repro.data.pipeline import DataLoader, SyntheticLM  # noqa: F401
