"""recurrentgemma-2b: RG-LRU + local attention, 2 recurrent : 1 attention.

[arXiv:2402.19427; hf]. Hybrid => runs long_500k (sub-quadratic).
"""
from repro.configs.base import ArchConfig, register

ARCH = register(
    ArchConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        source="arXiv:2402.19427; hf",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,  # MQA on local-attention layers
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        mixer="rglru_hybrid",
        layer_pattern=("rglru", "rglru", "local"),
        local_window=2048,
        lru_width=2560,
        conv_width=4,
        mlp_act="geglu",
        norm="rmsnorm",
        rope_theta=10_000.0,
        tie_embeddings=True,
    )
)
