"""chameleon-34b: early-fusion VLM backbone, VQ image tokens in vocab, qk-norm.

[arXiv:2405.09818; unverified]. Backbone only: the modality frontend is a stub
— input_specs() provides precomputed patch embeddings (input_kind='embeddings').
"""
from repro.configs.base import ArchConfig, register

ARCH = register(
    ArchConfig(
        name="chameleon-34b",
        family="vlm",
        source="arXiv:2405.09818; unverified",
        num_layers=48,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        mixer="attention",
        mlp_act="swiglu",
        norm="rmsnorm",
        qk_norm=True,
        rope_theta=10_000.0,
        input_kind="embeddings",
    )
)
