"""olmoe-1b-7b: 64-expert top-8 MoE. [arXiv:2409.02060; hf]"""
from repro.configs.base import ArchConfig, register

ARCH = register(
    ArchConfig(
        name="olmoe-1b-7b",
        family="moe",
        source="arXiv:2409.02060; hf",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,  # MHA
        head_dim=128,
        d_ff=1024,  # per-expert
        vocab_size=50304,
        mixer="attention",
        mlp_act="swiglu",
        norm="rmsnorm",
        qk_norm=True,
        rope_theta=10_000.0,
        num_experts=64,
        top_k=8,
    )
)
