"""rwkv6-1.6b (Finch): attention-free, data-dependent decay. [arXiv:2404.05892; unverified]

SSM family => runs long_500k (state is O(L * H * hs^2), sequence-length free).
"""
from repro.configs.base import ArchConfig, register

ARCH = register(
    ArchConfig(
        name="rwkv6-1.6b",
        family="ssm",
        source="arXiv:2404.05892; unverified",
        num_layers=24,
        d_model=2048,
        num_heads=0,  # attention-free
        num_kv_heads=0,
        d_ff=7168,
        vocab_size=65536,
        mixer="rwkv6",
        norm="layernorm",
        pos_emb="none",
        rwkv_head_size=64,
        rwkv_lora_rank=32,
    )
)
