"""granite-moe-3b-a800m: 40-expert top-8 MoE, GQA.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]. 40 experts are padded to 48
(-inf router logits on pads; exact) so experts shard over the model axis of 16.
"""
from repro.configs.base import ArchConfig, register

ARCH = register(
    ArchConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,  # per-expert
        vocab_size=49155,
        mixer="attention",
        mlp_act="swiglu",
        norm="rmsnorm",
        rope_theta=10_000.0,
        num_experts=40,
        top_k=8,
        tie_embeddings=True,
    )
)
