"""starcoder2-7b: GQA + RoPE, GELU MLP, layernorm. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig, register

ARCH = register(
    ArchConfig(
        name="starcoder2-7b",
        family="dense",
        source="arXiv:2402.19173; hf",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        head_dim=128,
        d_ff=18432,
        vocab_size=49152,
        mixer="attention",
        mlp_act="gelu",
        norm="layernorm",
        qkv_bias=True,
        rope_theta=100_000.0,
    )
)
