"""qwen2.5-32b: GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ArchConfig, register

ARCH = register(
    ArchConfig(
        name="qwen2.5-32b",
        family="dense",
        source="hf:Qwen/Qwen2.5-0.5B; hf",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab_size=152064,
        mixer="attention",
        mlp_act="swiglu",
        norm="rmsnorm",
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
)
