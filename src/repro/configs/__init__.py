"""Assigned-architecture registry. Importing this package registers all archs."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    RunShape,
    cells,
    get_config,
    input_specs,
    list_archs,
    register,
)

# one module per assigned architecture (registration side-effect)
from repro.configs import (  # noqa: F401
    chameleon_34b,
    granite_moe_3b_a800m,
    musicgen_medium,
    olmoe_1b_7b,
    qwen2_5_32b,
    recurrentgemma_2b,
    rwkv6_1_6b,
    starcoder2_7b,
    yi_6b,
    yi_9b,
)
