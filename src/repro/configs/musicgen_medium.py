"""musicgen-medium: decoder-only over EnCodec tokens (MHA). [arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a stub — input_specs() provides
precomputed frame embeddings. Cross-attention text conditioning is out of the
assigned backbone scope (see DESIGN.md).
"""
from repro.configs.base import ArchConfig, register

ARCH = register(
    ArchConfig(
        name="musicgen-medium",
        family="audio",
        source="arXiv:2306.05284; hf",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,  # MHA
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        mixer="attention",
        mlp_act="gelu",
        norm="layernorm",
        pos_emb="sinusoidal",
        input_kind="embeddings",
    )
)
