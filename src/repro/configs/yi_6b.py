"""yi-6b: llama-arch GQA dense LM. [arXiv:2403.04652; hf]"""
from repro.configs.base import ArchConfig, register

ARCH = register(
    ArchConfig(
        name="yi-6b",
        family="dense",
        source="arXiv:2403.04652; hf",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        mixer="attention",
        mlp_act="swiglu",
        norm="rmsnorm",
        rope_theta=10_000.0,
    )
)
