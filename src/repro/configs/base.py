"""Architecture configs, run shapes, and dry-run input specs.

Every assigned architecture is a selectable config (``--arch <id>``). Configs are
exact per the assignment sheet; ``reduced()`` yields a same-family tiny config for
CPU smoke tests. ``input_specs()`` returns ShapeDtypeStruct stand-ins (no device
allocation) for every model input of a (arch x run-shape) cell.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Run shapes (assigned; identical set for every LM arch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunShape:
    """A named (seq_len, global_batch) workload cell.

    kind: 'train'   -> lowers train_step
          'prefill' -> lowers prefill (serve) over the full sequence
          'decode'  -> lowers serve_step: ONE new token against a KV cache of
                       seq_len (per the assignment, decode_*/long_* lower
                       serve_step, not train_step).
    """

    name: str
    kind: str
    seq_len: int
    global_batch: int
    grad_accum: int = 1  # train only: number of microbatch steps
    sub_quadratic_only: bool = False


SHAPES: Dict[str, RunShape] = {
    "train_4k": RunShape("train_4k", "train", 4096, 256, grad_accum=16),
    "prefill_32k": RunShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": RunShape("decode_32k", "decode", 32768, 128),
    "long_500k": RunShape("long_500k", "decode", 524288, 1, sub_quadratic_only=True),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | vlm | audio | hybrid | moe | ssm
    source: str  # provenance [arXiv/hf; tier]

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0  # query heads (0 for attention-free archs)
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # block details
    mixer: str = "attention"  # attention | rglru_hybrid | rwkv6
    mlp_act: str = "swiglu"  # swiglu | gelu | geglu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False
    qk_norm: bool = False
    pos_emb: str = "rope"  # rope | sinusoidal | none
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    top_k: int = 0

    # hybrid (recurrentgemma): cycle of layer kinds; empty => [mixer]*L
    layer_pattern: Tuple[str, ...] = ()
    local_window: int = 0  # sliding-window size for 'local' attention layers
    lru_width: int = 0  # RG-LRU state width
    conv_width: int = 4  # temporal conv width (hybrid)

    # rwkv6
    rwkv_head_size: int = 64
    rwkv_lora_rank: int = 32

    # modality frontend stub: 'tokens' or 'embeddings' (vlm/audio backbones take
    # precomputed patch/frame embeddings from input_specs(); frontend is a stub)
    input_kind: str = "tokens"

    def __post_init__(self):
        if self.mixer == "attention" or self.mixer == "rglru_hybrid":
            assert self.num_heads > 0
            if self.head_dim == 0:
                object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived -----------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.mixer == "rwkv6"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run 500k-token decode (SSM / hybrid-local)."""
        return self.mixer in ("rwkv6", "rglru_hybrid")

    def layer_kinds(self) -> List[str]:
        """Per-layer mixer kind, length num_layers."""
        if self.layer_pattern:
            pat = list(self.layer_pattern)
            return [pat[i % len(pat)] for i in range(self.num_layers)]
        return [self.mixer] * self.num_layers

    def param_count(self) -> int:
        """Total parameter count (exact, pre-TP-padding)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d  # lm head
        for kind in self.layer_kinds():
            if kind in ("attention", "local"):
                hq, hk, hd = self.num_heads, self.num_kv_heads, self.head_dim
                n += d * hq * hd + 2 * d * hk * hd + hq * hd * d
                if self.qkv_bias:
                    n += (hq + 2 * hk) * hd
                n += d  # norm
            elif kind == "rglru":
                w = self.lru_width or d
                # in-proj (2 branches), conv, lru params (a, input/rec gates), out
                n += 2 * d * w + self.conv_width * w + 3 * w + 2 * (w * (w // max(1, self.num_heads)) if False else w) + w * d
                n += d
            elif kind == "rwkv6":
                hs = self.rwkv_head_size
                H = d // hs
                r = self.rwkv_lora_rank
                n += 4 * d * d  # r,k,v,out  (w via lora)
                n += d * d  # gate
                n += 5 * (d * r + r * d) + 6 * d  # ddlerp loras + mus
                n += H * hs  # u bonus
                n += d  # norm
            # ffn
            if self.is_moe:
                n += d * self.num_experts  # router
                if self.mlp_act in ("swiglu", "geglu"):
                    n += self.num_experts * 3 * d * self.d_ff
                else:
                    n += self.num_experts * 2 * d * self.d_ff
            elif kind == "rwkv6":
                n += 2 * d * self.d_ff + 2 * d  # channel-mix (k,v) + mixes
            else:
                if self.mlp_act in ("swiglu", "geglu"):
                    n += 3 * d * self.d_ff
                else:
                    n += 2 * d * self.d_ff
            n += d  # ffn norm
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of num_experts)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        per_expert = (3 if self.mlp_act in ("swiglu", "geglu") else 2) * d * self.d_ff
        inactive = L * (self.num_experts - self.top_k) * per_expert
        return self.param_count() - inactive

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        kw: Dict[str, Any] = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 if not self.layer_pattern else 3),
            d_model=64,
            d_ff=128,
            vocab_size=256,
        )
        if self.mixer in ("attention", "rglru_hybrid"):
            kw.update(num_heads=4, num_kv_heads=max(1, min(self.num_kv_heads, 2)), head_dim=16)
            if self.num_kv_heads == self.num_heads:
                kw.update(num_kv_heads=4)
        if self.mixer == "rglru_hybrid":
            kw.update(lru_width=64, local_window=16)
        if self.is_moe:
            kw.update(num_experts=8, top_k=2)
        if self.mixer == "rwkv6":
            kw.update(rwkv_head_size=16, rwkv_lora_rank=8)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (trigger registration)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


def cells(include_skipped: bool = False):
    """All assigned (arch x shape) dry-run cells.

    Pure full-attention archs skip long_500k (quadratic); see DESIGN.md
    §Arch-applicability. 8 skips => 32 live cells of the 40.
    """
    out = []
    for arch in list_archs():
        cfg = get_config(arch)
        for s in SHAPES.values():
            live = cfg.sub_quadratic or not s.sub_quadratic_only
            if live or include_skipped:
                out.append((arch, s.name, live))
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: RunShape, *, tp: int = 1) -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for the step function that `shape` lowers.

    train  -> train_step(state, batch) 'batch' part: tokens/embeddings + labels
    prefill-> prefill(params, tokens) inputs
    decode -> serve_step(params, cache, tokens, pos) inputs (cache included)

    The modality frontend of [vlm]/[audio] archs is a stub: input_specs
    provides precomputed patch/frame embeddings (input_kind == 'embeddings').
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16

    def tok(b, s):
        if cfg.input_kind == "embeddings":
            return jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16)
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "train":
        micro = B // shape.grad_accum
        return {
            "tokens": tok(B, S),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
            "micro_batch": micro,
        }
    if shape.kind == "prefill":
        return {"tokens": tok(B, S)}
    if shape.kind == "decode":
        from repro.models.cache import cache_specs  # local import: avoid cycle

        return {
            "tokens": tok(B, 1),
            "pos": jax.ShapeDtypeStruct((B,), i32),
            "cache": cache_specs(cfg, B, S, tp=tp),
        }
    raise ValueError(shape.kind)
