"""Pallas TPU paged decode attention.

The KV cache lives in a page pool (P, PS, Hkv, D); each sequence owns a row
of the page table — the serving-side materialization of the paper's system
page table. The page table and sequence lengths ride in scalar-prefetch
(SMEM): the k/v BlockSpec index_maps dereference the table so each grid step
DMAs exactly one page of one kv head from HBM into VMEM. Pages past a
sequence's length are skipped (no DMA-compute on dead pages).

Grid: (B, Hkv, NP) — page dim innermost, online softmax in VMEM scratch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, tpu_compiler_params


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, page_size: int, group: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    live = j * page_size < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (group, D)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (PS, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(q.shape[-1]))
        kpos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = jnp.broadcast_to(l_ref[:, :1] * alpha + p.sum(1, keepdims=True),
                                      l_ref.shape)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    j_final = jnp.maximum((length - 1) // page_size, 0)

    @pl.when(j == j_final)
    def _write():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_fwd(q, k_pool, v_pool, page_table, lengths, *,
                        interpret: bool = True):
    """q: (B,H,D); pools: (P,PS,Hkv,D); page_table: (B,NP); lengths: (B,)."""
    B, H, D = q.shape
    P, PS, Hkv, _ = k_pool.shape
    NP = page_table.shape[1]
    assert H % Hkv == 0
    group = H // Hkv
    grid = (B, Hkv, NP)
    kernel = functools.partial(_kernel, page_size=PS, group=group)

    # q viewed as (B, Hkv, group, D) so each grid step reads one kv-group
    q4 = q.reshape(B, Hkv, group, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, h, j, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, PS, 1, D), lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, PS, 1, D), lambda b, h, j, pt, ln: (pt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, j, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, 128), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )
    params = tpu_compiler_params(("parallel", "parallel", "arbitrary"))
    kwargs = {"compiler_params": params} if params is not None else {}
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        interpret=interpret,
        **kwargs,
    )(page_table, lengths, q4, k_pool, v_pool)
    return out.reshape(B, H, D)
