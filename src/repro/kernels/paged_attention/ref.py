"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def paged_attention_ref(q, k_pool, v_pool, page_table, lengths):
    """q: (B,H,D); pools: (P, PS, Hkv, D); page_table: (B, NP) int32;
    lengths: (B,) tokens valid per sequence. Returns (B,H,D).

    Gathers each sequence's pages then runs masked decode attention (GQA
    block mapping H = Hkv * group).
    """
    B, H, D = q.shape
    P, PS, Hkv, _ = k_pool.shape
    NP = page_table.shape[1]
    group = H // Hkv
    k = k_pool[page_table]  # (B, NP, PS, Hkv, D)
    v = v_pool[page_table]
    k = k.reshape(B, NP * PS, Hkv, D).astype(jnp.float32)
    v = v.reshape(B, NP * PS, Hkv, D).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(B, Hkv, group, D)
    logits = jnp.einsum("bngd,bknd->bngk", qf, k) / jnp.sqrt(float(D))
    pos = jnp.arange(NP * PS)[None, :]
    ok = pos < lengths[:, None]
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngk,bknd->bngd", probs, v)
    return out.reshape(B, H, D).astype(q.dtype)
