"""jit'd public wrapper for paged decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.common import default_interpret
from repro.kernels.paged_attention.paged_attention import paged_attention_fwd


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pool, v_pool, page_table, lengths, *,
                    interpret: bool | None = None):
    """Decode attention over a paged KV cache.

    q: (B,H,D); k_pool/v_pool: (P, PS, Hkv, D); page_table: (B, NP) int32
    (page ids per sequence, in order); lengths: (B,) valid tokens.
    """
    if interpret is None:
        interpret = default_interpret()
    return paged_attention_fwd(q, k_pool, v_pool, page_table, lengths,
                               interpret=interpret)
