"""jit'd wrapper for the 5-point stencil kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.common import default_interpret
from repro.kernels.stencil5.stencil5 import stencil5_fwd


@functools.partial(jax.jit, static_argnames=("coeff", "tile_h", "interpret"))
def stencil5(grid, coeff: float, *, tile_h: int = 256, interpret: bool | None = None):
    """One 5-point stencil sweep with replicated boundaries. grid: (H, W)."""
    if interpret is None:
        interpret = default_interpret()
    return stencil5_fwd(grid, coeff, tile_h=tile_h, interpret=interpret)
