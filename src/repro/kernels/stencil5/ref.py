"""Pure-jnp oracle for the 5-point stencil (hotspot/SRAD compute phase)."""
from __future__ import annotations

import jax.numpy as jnp


def stencil5_ref(grid, coeff: float):
    """out = c + coeff*(N + S + E + W - 4c), replicated (clamped) boundaries."""
    g = grid.astype(jnp.float32)
    n = jnp.concatenate([g[:1], g[:-1]], axis=0)
    s = jnp.concatenate([g[1:], g[-1:]], axis=0)
    w = jnp.concatenate([g[:, :1], g[:, :-1]], axis=1)
    e = jnp.concatenate([g[:, 1:], g[:, -1:]], axis=1)
    return (g + coeff * (n + s + e + w - 4.0 * g)).astype(grid.dtype)
