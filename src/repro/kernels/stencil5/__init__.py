from repro.kernels.stencil5.ops import stencil5  # noqa: F401
from repro.kernels.stencil5.ref import stencil5_ref  # noqa: F401
