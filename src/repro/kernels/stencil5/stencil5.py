"""Pallas TPU kernel: 5-point stencil over row stripes (hotspot/SRAD).

Halo handling without overlapping BlockSpecs: the grid tiles rows into
(TH, W) stripes and the *same* input array is passed three times with
index_maps i-1 / i / i+1 (clamped at the boundary), so each grid step has
the stripe plus both neighbor stripes resident in VMEM. VMEM footprint =
3*TH*W*4 bytes — ops.py picks TH so this stays under the VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import tpu_compiler_params


def _kernel(prev_ref, cur_ref, next_ref, o_ref, *, coeff: float, th: int, nrows: int):
    i = pl.program_id(0)
    c = cur_ref[...].astype(jnp.float32)  # (TH, W)
    # north: last row of prev stripe (clamped: prev==cur at i==0 -> replicate row 0)
    first = jnp.where(i == 0, c[:1], prev_ref[...].astype(jnp.float32)[-1:])
    north = jnp.concatenate([first, c[:-1]], axis=0)
    last = jnp.where(i == nrows - 1, c[-1:], next_ref[...].astype(jnp.float32)[:1])
    south = jnp.concatenate([c[1:], last], axis=0)
    west = jnp.concatenate([c[:, :1], c[:, :-1]], axis=1)
    east = jnp.concatenate([c[:, 1:], c[:, -1:]], axis=1)
    o_ref[...] = (c + coeff * (north + south + east + west - 4.0 * c)).astype(o_ref.dtype)


def stencil5_fwd(grid_in, coeff: float, *, tile_h: int = 256, interpret: bool = True):
    H, W = grid_in.shape
    tile_h = min(tile_h, H)
    assert H % tile_h == 0, (H, tile_h)
    n = H // tile_h
    kernel = functools.partial(_kernel, coeff=coeff, th=tile_h, nrows=n)
    params = tpu_compiler_params(("arbitrary",))
    kwargs = {"compiler_params": params} if params is not None else {}
    spec = lambda off: pl.BlockSpec(
        (tile_h, W), lambda i, _off=off: (jnp.clip(i + _off, 0, n - 1), 0))
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[spec(-1), spec(0), spec(+1)],
        out_specs=pl.BlockSpec((tile_h, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), grid_in.dtype),
        interpret=interpret,
        **kwargs,
    )(grid_in, grid_in, grid_in)
