"""Pallas TPU kernel: batched 4x4 SU(4) gate application to a statevector.

TPU adaptation of the paper's Qiskit-Aer statevector hot loop: amplitudes
are pre-permuted (ops.py) so the two target qubits form the leading axis of
a (4, M) panel — the matmul then runs with M on the 128-lane axis (MXU/VPU
friendly), streaming M-blocks HBM->VMEM. Complex arithmetic is done as four
real matmuls (re/im planes) since TPUs have no native complex dtype.

This kernel is the *memory-throughput* workload of the paper's Fig. 5/8/9:
bytes moved = 2 * 8 * 2^n per gate, FLOPs = 32 * 2^n (AI ~ 2 -> HBM-bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import tpu_compiler_params


def _kernel(gr_ref, gi_ref, xr_ref, xi_ref, or_ref, oi_ref):
    gr = gr_ref[...]  # (4,4)
    gi = gi_ref[...]
    xr = xr_ref[...]  # (4, BM)
    xi = xi_ref[...]
    dot = functools.partial(jax.lax.dot_general,
                            dimension_numbers=(((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    or_ref[...] = (dot(gr, xr) - dot(gi, xi)).astype(or_ref.dtype)
    oi_ref[...] = (dot(gr, xi) + dot(gi, xr)).astype(oi_ref.dtype)


def qv_gate_panel(xr, xi, gr, gi, *, block_m: int = 2048, interpret: bool = True):
    """xr/xi: (4, M) f32 real/imag amplitude panels; gr/gi: (4,4)."""
    _, M = xr.shape
    block_m = min(block_m, M)
    assert M % block_m == 0, (M, block_m)
    grid = (M // block_m,)
    params = tpu_compiler_params(("parallel",))
    kwargs = {"compiler_params": params} if params is not None else {}
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, 4), lambda m: (0, 0)),
            pl.BlockSpec((4, 4), lambda m: (0, 0)),
            pl.BlockSpec((4, block_m), lambda m: (0, m)),
            pl.BlockSpec((4, block_m), lambda m: (0, m)),
        ],
        out_specs=[
            pl.BlockSpec((4, block_m), lambda m: (0, m)),
            pl.BlockSpec((4, block_m), lambda m: (0, m)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xr.shape, xr.dtype),
            jax.ShapeDtypeStruct(xi.shape, xi.dtype),
        ],
        interpret=interpret,
        **kwargs,
    )(gr, gi, xr, xi)
