"""Pure-jnp oracle: apply a 4x4 unitary to qubits (q1,q2) of a statevector."""
from __future__ import annotations

import jax.numpy as jnp


def apply_two_qubit_gate_ref(state, gate, q1: int, q2: int, n_qubits: int):
    """state: (2**n,) complex64; gate: (4,4) complex64; q1 != q2 (qubit 0 =
    least-significant / fastest-varying axis is qubit n-1 in tensor layout)."""
    assert q1 != q2
    psi = state.reshape((2,) * n_qubits)
    # tensor axis of qubit q is (n-1-q): qubit 0 is the last axis
    a1, a2 = n_qubits - 1 - q1, n_qubits - 1 - q2
    psi = jnp.moveaxis(psi, (a1, a2), (0, 1))
    rest = psi.reshape(4, -1)
    out = gate @ rest
    out = out.reshape((2, 2) + (2,) * (n_qubits - 2))
    out = jnp.moveaxis(out, (0, 1), (a1, a2))
    return out.reshape(-1)
