"""jit'd wrapper: arbitrary-qubit-pair gate application via permute + kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.qv_gate.qv_gate import qv_gate_panel


@functools.partial(jax.jit, static_argnames=("q1", "q2", "n_qubits", "interpret"))
def apply_two_qubit_gate(state, gate, q1: int, q2: int, n_qubits: int,
                         *, interpret: bool | None = None):
    """state: (2**n,) complex64; gate: (4,4) complex64. Returns new state."""
    if interpret is None:
        interpret = default_interpret()
    psi = state.reshape((2,) * n_qubits)
    a1, a2 = n_qubits - 1 - q1, n_qubits - 1 - q2
    psi = jnp.moveaxis(psi, (a1, a2), (0, 1)).reshape(4, -1)
    xr, xi = jnp.real(psi).astype(jnp.float32), jnp.imag(psi).astype(jnp.float32)
    gr, gi = jnp.real(gate).astype(jnp.float32), jnp.imag(gate).astype(jnp.float32)
    yr, yi = qv_gate_panel(xr, xi, gr, gi, interpret=interpret)
    out = (yr + 1j * yi).astype(state.dtype)
    out = out.reshape((2, 2) + (2,) * (n_qubits - 2))
    out = jnp.moveaxis(out, (0, 1), (a1, a2))
    return out.reshape(-1)
