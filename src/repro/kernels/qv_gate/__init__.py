from repro.kernels.qv_gate.ops import apply_two_qubit_gate  # noqa: F401
from repro.kernels.qv_gate.ref import apply_two_qubit_gate_ref  # noqa: F401
