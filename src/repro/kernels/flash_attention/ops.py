"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B,S,H,D); k,v: (B,S,Hkv,D) -> (B,S,H,D). Layout-matches models/attention."""
    if interpret is None:
        interpret = default_interpret()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                            block_q=block_q, block_k=block_k, interpret=interpret)
    return jnp.swapaxes(o, 1, 2)
