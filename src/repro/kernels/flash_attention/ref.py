"""Pure-jnp oracle for the flash attention kernel (GQA, causal, window)."""
from __future__ import annotations

import jax.numpy as jnp
import jax

from repro.kernels.common import NEG_INF


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D); H % Hkv == 0 (block GQA mapping).

    Returns (B,Sq,H,D). All math in f32.
    """
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    p = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, p, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqnpd,bknd->bnpqk", qf, kf) / jnp.sqrt(float(D))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    logits = jnp.where(ok[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnpqk,bknd->bqnpd", probs, vf)
    return out.reshape(B, Sq, H, D).astype(q.dtype)
