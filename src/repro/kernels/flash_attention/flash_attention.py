"""Pallas TPU flash-attention forward kernel (GQA, causal, sliding window).

Tiling: grid (B, H, nQ, nK) with the KV dimension innermost (sequential on
TPU); online-softmax state (m, l, acc) lives in VMEM scratch and survives
across KV blocks. Fully-masked KV blocks are skipped via pl.when on the
block indices, so causal FLOPs track S^2/2 and window FLOPs track S*W.

Block shapes: q/o (1,1,BQ,D), k/v (1,1,BK,D) — MXU-aligned for D in
{64,128,256} and BQ/BK multiples of 128 (VMEM footprint ~ BQ*D + 2*BK*D +
BQ*BK floats).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF, tpu_compiler_params


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, n_k: int,
            causal: bool, window: int, seq_k: int):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = i * block_q
    k_lo = j * block_k
    # causal / window block-level liveness (dynamic on program ids)
    live = jnp.asarray(True)
    if causal:
        live &= k_lo <= q_lo + block_q - 1
    if window > 0:
        live &= k_lo + block_k - 1 > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = kpos < seq_k
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (BQ,1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = jnp.broadcast_to(l_ref[:, :1] * alpha + p.sum(1, keepdims=True),
                                      l_ref.shape)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    # last block that can touch this q row block
    if causal:
        j_last = jnp.minimum((q_lo + block_q - 1) // block_k, n_k - 1)
    else:
        j_last = n_k - 1

    @pl.when(j == j_last)
    def _write():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = True):
    """q: (B,H,Sq,D); k,v: (B,Hkv,Sk,D). Returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_q, n_k = Sq // block_q, Sk // block_k
    grid = (B, H, n_q, n_k)

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(D), block_q=block_q, block_k=block_k,
        n_k=n_k, causal=causal, window=window, seq_k=Sk)

    params = tpu_compiler_params(("parallel", "parallel", "parallel", "arbitrary"))
    kwargs = {"compiler_params": params} if params is not None else {}
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),  # m
            pltpu.VMEM((block_q, 128), jnp.float32),  # l
            pltpu.VMEM((block_q, D), jnp.float32),  # acc
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v)
