"""Shared Pallas helpers: interpret-mode detection, compiler params."""
from __future__ import annotations

import jax

NEG_INF = -1e30


def default_interpret() -> bool:
    """Pallas TPU kernels run compiled on TPU, interpret elsewhere (CPU CI)."""
    return jax.default_backend() != "tpu"


def tpu_compiler_params(dimension_semantics):
    """Best-effort TPU compiler params across jax versions (None if absent)."""
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pragma: no cover
        return None
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            try:
                return cls(dimension_semantics=dimension_semantics)
            except TypeError:  # pragma: no cover
                continue
    return None
