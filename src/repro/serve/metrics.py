"""Serving SLO metrics: per-request timing records + percentile summaries.

Every :class:`~repro.serve.engine.Request` carries modeled-clock
timestamps (``engine.now()``): ``arrival_time`` at enqueue,
``admit_time``, ``first_token_time`` and ``finish_time``.
:func:`collect` snapshots them into immutable :class:`RequestRecord`\\ s
and :func:`summarize` aggregates those into the SLO report the traffic
harness emits — overall and per tenant.

Metric definitions (all in modeled seconds — or engine steps when no
UnifiedMemory governs the pool):

* **TTFT** — ``first_token_time - arrival_time``. Anchored at *arrival*
  (the enqueue instant), never at admission: queueing delay before the
  admission gate is part of the latency a user sees, and measuring from
  admission would understate exactly the p99 tail.
* **queue delay** — ``admit_time - arrival_time`` (the pre-admission
  component of TTFT).
* **TPOT** (time per output token) — ``(finish_time - first_token_time)
  / (new_tokens - 1)`` for multi-token requests; 0 for single-token ones.
* **goodput** — completed tokens per modeled second of makespan
  (first arrival -> last finish). Preempted-and-resumed requests count
  only once, so goodput genuinely degrades when preemption churns.
* **SLO attainment** — fraction of completed requests with
  ``TTFT <= slo_ttft`` (when a deadline is given).

Everything here is a pure function of the modeled timestamps, so a
same-seed traffic run reproduces the report bit-for-bit
(tests/test_traffic.py pins this).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional

import numpy as np


@dataclass(frozen=True)
class RequestRecord:
    """Immutable timing snapshot of one served request."""
    rid: int
    tenant: str
    prompt_len: int
    new_tokens: int
    arrival_time: float
    admit_time: Optional[float]
    first_token_time: Optional[float]
    finish_time: Optional[float]
    preemptions: int
    recoveries: int = 0  # fault replays (KV lost, recomputed from prompt)

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def ttft(self) -> float:
        assert self.first_token_time is not None, "request never produced a token"
        return self.first_token_time - self.arrival_time

    @property
    def queue_delay(self) -> float:
        assert self.admit_time is not None, "request was never admitted"
        return self.admit_time - self.arrival_time

    @property
    def tpot(self) -> float:
        if self.new_tokens <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (self.new_tokens - 1)

    @property
    def e2e(self) -> float:
        return self.finish_time - self.arrival_time


def collect(engine) -> List[RequestRecord]:
    """Snapshot an engine's requests (any state) as records, rid order."""
    return [RequestRecord(rid=r.rid, tenant=r.tenant,
                          prompt_len=len(r.prompt),
                          new_tokens=len(r.generated),
                          arrival_time=r.arrival_time,
                          admit_time=r.admit_time,
                          first_token_time=r.first_token_time,
                          finish_time=r.finish_time,
                          preemptions=r.preemptions,
                          recoveries=r.recoveries)
            for rid, r in sorted(engine.requests.items())]


def _dist(values: Iterable[float]) -> Dict[str, float]:
    a = np.asarray(list(values), dtype=np.float64)
    if a.size == 0:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    return {"p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()),
            "max": float(a.max())}


def _summary_one(records: List[RequestRecord],
                 slo_ttft: Optional[float]) -> Dict[str, object]:
    done = [r for r in records if r.done]
    out: Dict[str, object] = {
        "n": len(records),
        "completed": len(done),
        "tokens": sum(r.new_tokens for r in done),
        "preemptions": sum(r.preemptions for r in records),
        "recoveries": sum(r.recoveries for r in records),
        "ttft": _dist(r.ttft for r in done),
        "queue_delay": _dist(r.queue_delay for r in done),
        "tpot": _dist(r.tpot for r in done if r.new_tokens > 1),
        "e2e": _dist(r.e2e for r in done),
    }
    if done:
        makespan = (max(r.finish_time for r in done)
                    - min(r.arrival_time for r in done))
        out["goodput_tok_s"] = (out["tokens"] / makespan if makespan > 0
                                else float(out["tokens"]))
    else:
        out["goodput_tok_s"] = 0.0
    if slo_ttft is not None:
        out["slo_attainment"] = (
            sum(1 for r in done if r.ttft <= slo_ttft) / len(done)
            if done else 0.0)
    return out


def summarize(records: List[RequestRecord], *,
              slo_ttft: Optional[float] = None) -> Dict[str, object]:
    """Aggregate records into the SLO report: the overall numbers plus a
    ``tenants`` sub-report keyed by tenant name. JSON-serializable and a
    pure function of the modeled timestamps (bit-deterministic per seed)."""
    out = _summary_one(records, slo_ttft)
    tenants = sorted({r.tenant for r in records})
    out["tenants"] = {t: _summary_one([r for r in records if r.tenant == t],
                                      slo_ttft)
                      for t in tenants}
    return out
