"""Oversubscription-aware continuous-batching serve engine.

Requests move through the scheduler states

    pending -> prefill -> decoding -> (preempted <-> decoding)* -> done

driven by one ``step()`` per engine iteration:

  1. **Admission control** — preempted sequences resume first (oldest rid
     first), then pending requests are admitted FIFO. Admission consults
     both the KV pool (enough free pages for the whole prompt plus a
     watermark) and, when a :class:`UnifiedMemory` governs the pool, device
     memory pressure: a request is only admitted while
     ``um.device_free()`` covers ``admit_device_fraction`` of its projected
     KV growth (prompt + max_new_tokens). The pressure gate is skipped when
     nothing is running, so the engine always makes progress.
  2. **Chunked prefill** — at most ``prefill_chunk`` prompt tokens are
     prefilled per step (shared FIFO budget), so one long prompt cannot
     stall decode for everyone else. Each chunk attends over the KV already
     in the pool (gathered per layer), which makes chunked and unchunked
     prefill bit-identical.
  3. **Async prefetch** — resumed sequences' pool extents are promoted
     ahead of their decode turn via ``um.prefetch_async`` (cost hides under
     the decode kernel through ``_pending_overlap``).
  4. **Batched decode** — one paged-attention step over every decoding
     sequence. If the pool cannot back the batch's new-token pages, the
     youngest decoding sequences are *preempted* instead of hitting a
     ``page pool exhausted`` assert: their KV is demoted host-side
     (``um.demote`` + ``PagedKVCache.swap_out``) and scattered back on
     resume, after which the access-counter path re-promotes the hot pages.

Decode uses the paged_attention Pallas kernel over the umem-governed page
pool, which may be allocated larger than device capacity (``num_pages``):
overflow pages live host-side under the system policy and decode reads
them remotely — the paper's §7 graceful oversubscription, applied to
serving. Attention-arch only (recurrent archs serve via the dense decode
path in models/transformer.py — their state is O(1) in sequence length).

**Timing.** The engine keeps a modeled clock (:meth:`ServeEngine.now`:
``um.clock`` under a UnifiedMemory, the step index otherwise, plus any
idle time skipped by :meth:`ServeEngine.advance_to`). Every request
records ``arrival_time`` at enqueue — NOT at admission — so TTFT
(``first_token_time - arrival_time``) includes the queueing delay a
request spends waiting for the admission gate; measuring from admission
would understate exactly the tail the SLO metrics exist to expose.
serve/traffic.py drives arrival processes against this clock and
serve/metrics.py aggregates the records into SLO reports.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import HostSpillError, UnifiedMemory
from repro.kernels.paged_attention import paged_attention
from repro.models.attention import _causal_bias, _out_proj, _project_qkv, _sdpa
from repro.models.cache import kv_head_layout
from repro.models.layers import RunPolicy, apply_norm, mlp_apply
from repro.models import moe as moe_mod
from repro.models.transformer import embed_in, logits_out, policy_tp
from repro.serve.paged import PagedKVCache


class SeqState(Enum):
    PENDING = "pending"      # not yet admitted
    PREFILL = "prefill"      # admitted, prompt partially prefilled
    DECODING = "decoding"    # generating tokens
    PREEMPTED = "preempted"  # KV swapped host-side, waiting to resume
    DONE = "done"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    sid: int = -1
    state: SeqState = SeqState.PENDING
    prefill_pos: int = 0  # prompt tokens whose KV is in the pool
    saved: Optional[dict] = None  # host-side KV while preempted
    preemptions: int = 0
    recoveries: int = 0  # fault replays (KV lost, recomputed from prompt)
    tenant: str = ""
    # modeled-clock timestamps (engine.now()); TTFT anchors at arrival_time,
    # the enqueue instant, so pre-admission queueing delay is attributed to
    # the request
    arrival_time: float = 0.0
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.state is SeqState.DONE


@dataclass
class EngineStats:
    admitted: int = 0
    preempted: int = 0
    resumed: int = 0
    prefill_chunks: int = 0
    decode_batches: int = 0
    decode_tokens: int = 0
    # fault-recovery accounting (zero in a fault-free run)
    node_losses: int = 0
    recovered_requests: int = 0
    replayed_tokens: int = 0  # token work thrown away and recomputed
    # (prefilled prompt positions + generated tokens at replay time)
    spill_failures: int = 0
    admission_retries: int = 0  # admissions deferred by the post-fault hold
    lane_degraded_steps: int = 0


class ServeEngine:
    def __init__(self, cfg, params, *, max_seqs: int = 8, max_len: int = 512,
                 page_size: int = 64, num_pages: Optional[int] = None,
                 policy: Optional[RunPolicy] = None,
                 um: Optional[UnifiedMemory] = None, greedy: bool = True,
                 prefill_chunk: int = 128, watermark_pages: int = 0,
                 admit_device_fraction: float = 0.5,
                 counter_threshold: int = 16, mem_policy=None,
                 tp_plan=None, fault_plan=None,
                 admit_backoff_steps: int = 2):
        assert cfg.mixer == "attention", "paged serving targets attention archs"
        assert set(cfg.layer_kinds()) == {"attention"}, \
            "the chunked-prefill path needs homogeneous global attention"
        self.cfg = cfg
        self.params = params
        self.policy = policy or RunPolicy()
        self.layout = kv_head_layout(cfg, policy_tp(self.policy))
        # tp_plan (e.g. repro.cluster.serve.ClusterTPPlan) maps sequences to
        # serving superchips and charges per-token tensor-parallel collective
        # traffic; it only ADDS modeled charges and node pins, so generated
        # tokens stay bit-identical to the single-node engine
        self.tp_plan = tp_plan
        seq_node = (tp_plan.node_of_seq if tp_plan is not None
                    and um is not None else None)
        self.cache = PagedKVCache(cfg, self.layout, max_seqs=max_seqs,
                                  max_len=max_len, page_size=page_size,
                                  num_pages=num_pages, um=um,
                                  counter_threshold=counter_threshold,
                                  mem_policy=mem_policy, seq_node=seq_node)
        self.um = um
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self.greedy = greedy
        self.max_len = max_len
        self.prefill_chunk = max(1, prefill_chunk)
        self.watermark_pages = watermark_pages
        self.admit_device_fraction = admit_device_fraction
        self.stats = EngineStats()
        self._needs_prefetch: List[Request] = []
        self._steps = 0
        self._idle_skipped = 0.0
        # fault plan (runtime/fault.py FaultPlan): a frozen, sorted schedule
        # this engine consumes through its own cursor, so one plan can be
        # shared across every engine of a traffic simulation. None costs a
        # single identity check per step — fault-free runs stay bit-identical
        if fault_plan is not None and not fault_plan:
            fault_plan = None  # empty plan: take the zero-cost path
        if fault_plan is not None and um is None:
            raise ValueError(
                "fault_plan needs a UnifiedMemory-governed engine: faults "
                "are delivered through um.fail_node / set_lane_degradation "
                "/ set_spill_failure")
        self.fault_plan = fault_plan
        self._fault_idx = 0
        self._degrade_until = -1  # step the active lane window expires at
        self._spill_until = -1    # step the active spill window expires at
        self.admit_backoff_steps = max(1, admit_backoff_steps)
        self._backoff = self.admit_backoff_steps
        self._hold_admit = 0  # steps fresh admission stays held post-fault
        self.draining = False

    # ----------------------------------------------------------------- clock
    def now(self) -> float:
        """Modeled time: the UnifiedMemory clock when one governs the pool
        (seconds of modeled kernel/migration time), the step index otherwise,
        plus idle time skipped via :meth:`advance_to`."""
        base = self.um.clock if self.um is not None else float(self._steps)
        return base + self._idle_skipped

    def advance_to(self, t: float) -> float:
        """Fast-forward the clock to ``t`` (an arrival-driven caller skipping
        idle time between the last completion and the next arrival). Never
        moves time backwards. Returns now()."""
        cur = self.now()
        if t > cur:
            self._idle_skipped += t - cur
        return self.now()

    # ---------------------------------------------------------------- admin
    def add_request(self, prompt: np.ndarray, max_new_tokens: int = 16, *,
                    arrival_time: Optional[float] = None,
                    tenant: str = "") -> int:
        rid = self._next_rid
        self._next_rid += 1
        # enqueue time IS the arrival: TTFT must cover pre-admission queueing
        self.requests[rid] = Request(
            rid, np.asarray(prompt), max_new_tokens, tenant=tenant,
            arrival_time=self.now() if arrival_time is None else arrival_time)
        return rid

    def _in_state(self, state: SeqState) -> List[Request]:
        return [r for r in self.requests.values() if r.state is state]

    def _projected_kv_bytes(self, req: Request) -> int:
        """KV bytes this request still has to materialize: its full projected
        footprint (prompt + max_new_tokens, capped at max_len) minus the pool
        pages it already holds."""
        total = min(self.max_len, len(req.prompt) + req.max_new_tokens)
        have = (int(np.count_nonzero(self.cache.page_table[req.sid]))
                if req.sid >= 0 else 0)
        return max(0, self.cache.pages_for(total) - have) * self.cache.page_bytes

    # ----------------------------------------------------------- admission
    def _admission_ok(self, req: Request, running: List[Request]) -> bool:
        need = self.cache.pages_for(len(req.prompt)) + 1  # prompt + 1st decode
        if self.cache.free_pages() < need + self.watermark_pages:
            return False
        if self.um is not None and running and self.admit_device_fraction > 0:
            # memory-pressure gate: only admit while device memory can absorb
            # a fraction of the projected KV growth of this request PLUS what
            # the already-running sequences still have to materialize (skipped
            # when nothing runs, so pressure can never deadlock the engine)
            demand = self._projected_kv_bytes(req) + sum(
                self._projected_kv_bytes(r) for r in running)
            if self.um.device_free() < self.admit_device_fraction * demand:
                return False
        return True

    def _admit(self) -> int:
        progressed = 0
        running = self._in_state(SeqState.PREFILL) + \
            self._in_state(SeqState.DECODING)
        # resume preempted sequences first, oldest rid first (FIFO fairness:
        # a younger request never resumes past a stalled older one)
        for req in sorted(self._in_state(SeqState.PREEMPTED), key=lambda r: r.rid):
            if self.cache.free_slots() == 0:
                break
            need = self.cache.pages_for(int(req.saved["len"]) + 1)
            if self.cache.free_pages() < need + self.watermark_pages:
                break
            self._resume(req)
            running.append(req)
            progressed += 1
        if self._in_state(SeqState.PREEMPTED):
            return progressed  # don't admit fresh work while old work waits
        for req in sorted(self._in_state(SeqState.PENDING), key=lambda r: r.rid):
            if self.cache.free_slots() == 0:
                break
            # a fault-replayed request re-enters PENDING with its admit_time
            # already stamped; drain mode and the post-fault admission hold
            # apply only to genuinely fresh work, and skip (not break) so a
            # held fresh request never blocks a replayed one behind it
            fresh = req.admit_time is None
            if fresh and self.draining:
                continue
            if fresh and self._hold_admit > 0:
                self.stats.admission_retries += 1
                continue
            if not self._admission_ok(req, running):
                break
            req.sid = self.cache.new_seq()
            req.state = SeqState.PREFILL
            if req.admit_time is None:
                req.admit_time = self.now()
            self.stats.admitted += 1
            running.append(req)
            progressed += 1
        return progressed

    # ---------------------------------------------------------------- faults
    def start_drain(self) -> None:
        """Enter drain mode: in-flight requests run to completion, but no
        fresh request is admitted (fault-replayed requests still re-enter —
        they were already admitted once). run_to_completion then returns as
        soon as the admitted work finishes."""
        self.draining = True

    def _apply_faults(self) -> None:
        """Deliver the fault plan's due events for this step and expire any
        active lane-degradation / spill-failure window."""
        ev = self.fault_plan.events
        while self._fault_idx < len(ev) and ev[self._fault_idx].step <= self._steps:
            e = ev[self._fault_idx]
            self._fault_idx += 1
            if e.kind == "node_loss":
                self._on_node_loss(e.node)
            elif e.kind == "lane_degrade":
                self.um.set_lane_degradation(
                    (e.nvlink_factor, e.fabric_factor))
                self._degrade_until = e.step + e.duration
            elif e.kind == "spill_fail":
                self.um.set_spill_failure(True)
                self._spill_until = e.step + e.duration
            else:
                raise ValueError(f"unknown fault kind {e.kind!r}")
        if self._degrade_until >= 0:
            if self._steps >= self._degrade_until:
                self.um.set_lane_degradation(None)
                self._degrade_until = -1
            else:
                self.stats.lane_degraded_steps += 1
        if self._spill_until >= 0 and self._steps >= self._spill_until:
            self.um.set_spill_failure(False)
            self._spill_until = -1

    def _on_node_loss(self, node: int) -> None:
        """A serving superchip died: poison its resident pages, shrink the
        TP plan to the survivors, and replay every sequence whose KV pages
        are gone. Fresh admission backs off (doubling hold) so the shrunken
        pool re-stabilizes before taking new load."""
        self.stats.node_losses += 1
        lost = self.um.fail_node(node)
        if self.tp_plan is not None:
            self.tp_plan = self.tp_plan.without_node(node)
            # re-pin sequence placement to the surviving ranks
            self.cache.seq_node = self.tp_plan.node_of_seq
        runs = lost.get(self.cache.alloc.name, [])
        for sid in self.cache.seqs_touching_pages(runs):
            req = next((r for r in self.requests.values()
                        if r.sid == sid and not r.done), None)
            if req is not None:
                self._replay(req)
        self._hold_admit = max(self._hold_admit, self._backoff)
        self._backoff = min(self._backoff * 2, 64)

    def _replay(self, req: Request) -> None:
        """Drop a sequence whose KV is lost (or unsavable) and requeue it
        for recompute from its prompt. Greedy decode is per-row batch-
        independent, so the replayed tokens come back bit-identical to the
        lost ones — the fault regression test pins the full stream against
        a fault-free run."""
        self.stats.recovered_requests += 1
        self.stats.replayed_tokens += len(req.generated) + req.prefill_pos
        if req.sid >= 0:
            self.cache.release(req.sid)
            req.sid = -1
        req.saved = None
        req.generated = []
        req.prefill_pos = 0
        req.state = SeqState.PENDING
        req.recoveries += 1

    # ---------------------------------------------------------- preemption
    def _node_ctx(self, sid: int):
        """Pin umem ops to the sequence's serving superchip under a TP plan
        (node-aware pools spill/promote as seen from that node)."""
        if self.tp_plan is not None and self.um is not None:
            return self.um.on_node(self.tp_plan.node_of_seq(sid))
        return contextlib.nullcontext()

    def _preempt(self, req: Request) -> None:
        if self.um is not None:
            try:
                with self._node_ctx(req.sid):
                    for band in self.cache.seq_views(req.sid):
                        self.um.demote(band)
            except HostSpillError:
                # spill window active: the KV cannot be saved host-side.
                # Fall back to dropping it and recomputing from the prompt
                # — greedy decode replays bit-identically, so correctness
                # survives at a recompute (not preemption) cost
                self.stats.spill_failures += 1
                self._replay(req)
                return
        req.saved = self.cache.swap_out(req.sid)
        req.sid = -1
        req.state = SeqState.PREEMPTED
        req.preemptions += 1
        self.stats.preempted += 1

    def _resume(self, req: Request) -> None:
        req.sid = self.cache.swap_in(req.saved)
        req.saved = None
        # a sequence preempted mid-prefill picks its prompt back up
        req.state = (SeqState.DECODING if req.prefill_pos == len(req.prompt)
                     else SeqState.PREFILL)
        self.stats.resumed += 1
        if self.um is not None:
            self._needs_prefetch.append(req)

    def _prefetch_resumed(self) -> None:
        """Promote resumed sequences' extents ahead of their decode turn."""
        if self.um is None or not self._needs_prefetch:
            self._needs_prefetch = []
            return
        todo, self._needs_prefetch = self._needs_prefetch, []
        # per-request issue, pinned to each sequence's serving node; the
        # per-band charges accrue in the same order the flattened single
        # prefetch_async call used, so single-node charges are unchanged
        for req in todo:
            if req.sid < 0:
                continue
            bands = self.cache.seq_views(req.sid)
            if bands:
                with self._node_ctx(req.sid):
                    self.um.prefetch_async(bands)

    # -------------------------------------------------------------- prefill
    def _prefill_step(self) -> int:
        budget = self.prefill_chunk
        chunks = 0
        for req in sorted(self._in_state(SeqState.PREFILL), key=lambda r: r.rid):
            if budget == 0:
                break
            want = min(budget, len(req.prompt) - req.prefill_pos)
            # clamp the chunk to the pages the pool can back right now,
            # keeping one page in reserve per decoding sequence so prefill
            # never starves the decode batch of its new-token pages; a
            # stalled chunk retries next step once decode frees pages
            reserve = len(self._in_state(SeqState.DECODING))
            afford = (self.cache.allocated_until(req.sid)
                      + max(0, self.cache.free_pages() - reserve)
                      * self.cache.page_size
                      - req.prefill_pos)
            chunk = min(want, afford)
            if chunk <= 0:
                continue
            self._prefill_chunk_run(req, chunk)
            budget -= chunk
            chunks += 1
        return chunks

    def _prefill_chunk_run(self, req: Request, chunk: int) -> None:
        cfg, lay, pol = self.cfg, self.layout, self.policy
        s = req.prefill_pos
        e = s + chunk
        self.cache.alloc_range(req.sid, s, e)
        toks = jnp.asarray(req.prompt[s:e])[None, :]
        positions = jnp.arange(s, e, dtype=jnp.int32)
        kpos = jnp.arange(e, dtype=jnp.int32)
        x = embed_in(cfg, self.params, toks, pol, positions)
        for i in range(cfg.num_layers):
            p = self.params["layers"][i]
            h = apply_norm(cfg.norm, x, p["norm1"])
            q, k_new, v_new = _project_qkv(cfg, p["mixer"], h, lay, positions)
            self.cache.write_at(req.sid, i, k_new[0], v_new[0], s)
            k_full, v_full = self.cache.gather_kv(req.sid, i, e)
            bias = _causal_bias(positions, kpos, 0)
            o = _sdpa(q, k_full[None], v_full[None], bias)
            x = x + _out_proj(p["mixer"], o, lay)
            h2 = apply_norm(cfg.norm, x, p["norm2"])
            if cfg.is_moe:
                y, _ = moe_mod.moe_apply(cfg, p["ffn"], h2, pol, tp=policy_tp(pol))
            else:
                y = mlp_apply(cfg, p["ffn"], h2, pol)
            x = x + y
        req.prefill_pos = e
        self.cache.commit_prefill(req.sid, e)
        if self.tp_plan is not None:
            self.tp_plan.on_prefill(self, chunk)
        self.stats.prefill_chunks += 1
        if e == len(req.prompt):
            x = apply_norm(cfg.norm, x, self.params["final_norm"])
            logits = logits_out(cfg, self.params, x[:, -1:], pol)
            req.generated.append(int(jnp.argmax(logits[0, -1])))
            if req.first_token_time is None:
                req.first_token_time = self.now()
            req.state = SeqState.DECODING
            if (len(req.generated) >= req.max_new_tokens
                    or len(req.prompt) + len(req.generated) >= self.max_len - 1):
                self._finish(req)

    # --------------------------------------------------------------- decode
    def _ensure_decode_pages(self, reqs: List[Request]) -> List[Request]:
        """Back every batch member's new-token page, preempting the youngest
        page-holding sequences (their KV demoted host-side) when the pool
        runs dry. Victims may be decoding OR mid-prefill — only the oldest
        page-holder is shielded, so it always makes progress."""
        reqs = sorted(reqs, key=lambda r: r.rid)
        while True:
            need = sum(1 for r in reqs
                       if self.cache.missing_pages(
                           r.sid, int(self.cache.lengths[r.sid]) + 1))
            if need <= self.cache.free_pages():
                break
            holders = sorted(
                (r for r in self.requests.values() if r.sid >= 0
                 and r.state in (SeqState.DECODING, SeqState.PREFILL)),
                key=lambda r: r.rid)
            if len(holders) <= 1:
                raise RuntimeError(
                    "KV page pool too small for a single sequence: "
                    f"num_pages={self.cache.num_pages}, "
                    f"seq needs page {int(self.cache.lengths[reqs[0].sid]) + 1}")
            victim = holders[-1]  # youngest first: the oldest always runs
            self._preempt(victim)
            if victim in reqs:
                reqs.remove(victim)
            if not reqs:
                return reqs  # whole batch preempted; the oldest is prefilling
        for r in reqs:
            self.cache.alloc_range(r.sid, 0, int(self.cache.lengths[r.sid]) + 1)
        return reqs

    def _decode_batch(self, reqs: List[Request]) -> None:
        cfg, lay, pol = self.cfg, self.layout, self.policy
        sids = [r.sid for r in reqs]
        pos = [int(self.cache.lengths[r.sid]) for r in reqs]
        tokens = jnp.asarray([[r.generated[-1]] for r in reqs], jnp.int32)
        pt, ln = self.cache.batch_view(sids)

        x = embed_in(cfg, self.params, tokens, pol, jnp.asarray(pos)[:, None])
        for i in range(cfg.num_layers):
            p = self.params["layers"][i]
            h = apply_norm(cfg.norm, x, p["norm1"])
            q, k_new, v_new = _project_qkv(cfg, p["mixer"], h, lay,
                                           jnp.asarray(pos)[:, None])
            self.cache.write_token(sids, i, np.asarray(k_new[:, 0]),
                                   np.asarray(v_new[:, 0]), pos)
            B = len(reqs)
            qd = q.reshape(B, lay.n_q_eff, cfg.head_dim)
            o = paged_attention(qd, self.cache.k_pools[i], self.cache.v_pools[i],
                                pt, ln + 1)
            o = _out_proj(p["mixer"], o[:, None], lay)
            x = x + o
            h2 = apply_norm(cfg.norm, x, p["norm2"])
            if cfg.is_moe:
                y, _ = moe_mod.moe_apply(cfg, p["ffn"], h2, pol, tp=policy_tp(pol))
            else:
                y = mlp_apply(cfg, p["ffn"], h2, pol)
            x = x + y
        x = apply_norm(cfg.norm, x, self.params["final_norm"])
        logits = logits_out(cfg, self.params, x, pol)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        self.cache.commit_token(sids, pos)
        if self.tp_plan is not None:
            self.tp_plan.on_decode(self, len(reqs))
        self.stats.decode_batches += 1
        self.stats.decode_tokens += len(reqs)
        for r, t in zip(reqs, nxt):
            r.generated.append(int(t))
            total = len(r.prompt) + len(r.generated)
            if len(r.generated) >= r.max_new_tokens or total >= self.max_len - 1:
                self._finish(r)

    def _finish(self, req: Request) -> None:
        req.state = SeqState.DONE
        req.finish_time = self.now()
        if req.sid >= 0:
            self.cache.release(req.sid)
            req.sid = -1

    # ------------------------------------------------------------------ run
    def _in_flight(self) -> bool:
        if self.draining:
            # fresh never-admitted requests are not in flight while draining
            # — they will not be admitted, so waiting on them would stall
            return any(not r.done and not (r.state is SeqState.PENDING
                                           and r.admit_time is None)
                       for r in self.requests.values())
        return any(not r.done for r in self.requests.values())

    def step(self) -> bool:
        """One engine step: admit/resume, chunked prefill, prefetch, decode.
        Returns True while any request is in flight."""
        if self.fault_plan is not None:
            self._apply_faults()
        pre0 = self.stats.preempted
        rec0 = self.stats.recovered_requests
        progress = 0
        if self._hold_admit > 0:
            # the post-fault backoff window ticking down IS forward motion:
            # held admissions land when it expires
            self._hold_admit -= 1
            progress += 1
            if self._hold_admit == 0:
                self._backoff = self.admit_backoff_steps
        progress += self._admit()
        progress += self._prefill_step()
        decoding = self._in_state(SeqState.DECODING)
        if decoding:
            batch = self._ensure_decode_pages(decoding)
            if batch:
                self._prefetch_resumed()
                self._decode_batch(batch)
                progress += len(batch)
        # a preemption frees pages for next step's admit/prefill/decode, so it
        # counts as progress (a genuine deadlock preempts nothing either);
        # likewise a fault replay requeues real work for the next step
        progress += self.stats.preempted - pre0
        progress += self.stats.recovered_requests - rec0
        if self.um is not None:
            self.um.sync()  # sync point: apply counter-driven delayed migrations
        self._steps += 1
        in_flight = self._in_flight()
        if in_flight and progress == 0:
            raise RuntimeError(
                "scheduler stalled: KV pool cannot back any in-flight request "
                f"(free_pages={self.cache.free_pages()}, "
                f"states={[r.state.value for r in self.requests.values()]})")
        return in_flight

    def run_to_completion(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while self.step():
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serve did not converge")
        return {rid: r.generated for rid, r in self.requests.items()}
