"""Batched serving engine: prefill -> paged decode with continuous batching.

Decode uses the paged_attention Pallas kernel over the umem-governed page
pool. Attention-arch only (recurrent archs serve via the dense decode path
in models/transformer.py — their state is O(1) in sequence length).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import UnifiedMemory
from repro.kernels.paged_attention import paged_attention
from repro.models import prefill as model_prefill
from repro.models.attention import _out_proj, _project_qkv
from repro.models.cache import kv_head_layout
from repro.models.layers import RunPolicy, apply_norm, mlp_apply
from repro.models import moe as moe_mod
from repro.models.transformer import embed_in, logits_out, policy_tp
from repro.serve.paged import PagedKVCache


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    sid: int = -1
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, max_seqs: int = 8, max_len: int = 512,
                 page_size: int = 64, policy: Optional[RunPolicy] = None,
                 um: Optional[UnifiedMemory] = None, greedy: bool = True):
        assert cfg.mixer == "attention", "paged serving targets attention archs"
        self.cfg = cfg
        self.params = params
        self.policy = policy or RunPolicy()
        self.layout = kv_head_layout(cfg, policy_tp(self.policy))
        self.cache = PagedKVCache(cfg, self.layout, max_seqs=max_seqs,
                                  max_len=max_len, page_size=page_size, um=um)
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self.greedy = greedy
        self.max_len = max_len

    # ---------------------------------------------------------------- admin
    def add_request(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(rid, np.asarray(prompt), max_new_tokens)
        return rid

    def _active(self) -> List[Request]:
        return [r for r in self.requests.values() if not r.done and r.sid >= 0]

    def _pending(self) -> List[Request]:
        return [r for r in self.requests.values() if not r.done and r.sid < 0]

    # -------------------------------------------------------------- prefill
    def _prefill_one(self, req: Request) -> None:
        req.sid = self.cache.new_seq()
        toks = jnp.asarray(req.prompt)[None, :]
        logits, dense_cache = model_prefill(self.cfg, self.params, toks, self.policy)
        for layer, kv in enumerate(dense_cache):
            self.cache.write_prefill(req.sid, layer, kv["k"][0], kv["v"][0])
        nxt = int(jnp.argmax(logits[0, -1]))
        req.generated.append(nxt)

    # --------------------------------------------------------------- decode
    def _decode_batch(self, reqs: List[Request]) -> None:
        cfg, lay, pol = self.cfg, self.layout, self.policy
        sids = [r.sid for r in reqs]
        pos = [int(self.cache.lengths[r.sid]) for r in reqs]
        tokens = jnp.asarray([[r.generated[-1]] for r in reqs], jnp.int32)
        for s, p in zip(sids, pos):  # pre-allocate the new token's page
            self.cache._page_for(s, p)
        pt, ln = self.cache.batch_view(sids)

        x = embed_in(cfg, self.params, tokens, pol, jnp.asarray(pos)[:, None])
        for i in range(cfg.num_layers):
            p = self.params["layers"][i]
            h = apply_norm(cfg.norm, x, p["norm1"])
            q, k_new, v_new = _project_qkv(cfg, p["mixer"], h, lay,
                                           jnp.asarray(pos)[:, None])
            self.cache.write_token(sids, i, np.asarray(k_new[:, 0]), np.asarray(v_new[:, 0]), pos)
            B = len(reqs)
            qd = q.reshape(B, lay.n_q_eff, cfg.head_dim)
            o = paged_attention(qd, self.cache.k_pools[i], self.cache.v_pools[i],
                                pt, ln + 1)
            o = _out_proj(p["mixer"], o[:, None], lay)
            x = x + o
            h2 = apply_norm(cfg.norm, x, p["norm2"])
            if cfg.is_moe:
                y, _ = moe_mod.moe_apply(cfg, p["ffn"], h2, pol, tp=policy_tp(pol))
            else:
                y = mlp_apply(cfg, p["ffn"], h2, pol)
            x = x + y
        x = apply_norm(cfg.norm, x, self.params["final_norm"])
        logits = logits_out(cfg, self.params, x, pol)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        self.cache.commit_token(sids, pos)
        for r, t in zip(reqs, nxt):
            r.generated.append(int(t))
            total = len(r.prompt) + len(r.generated)
            if len(r.generated) >= r.max_new_tokens or total >= self.max_len - 1:
                r.done = True
                self.cache.release(r.sid)
                r.sid = -1

    # ------------------------------------------------------------------ run
    def step(self) -> bool:
        """One engine step: admit pending (prefill) then decode the batch.
        Returns True while any request is in flight."""
        for req in self._pending():
            if np.count_nonzero(~self.cache.active) == 0:
                break
            self._prefill_one(req)
        active = self._active()
        if active:
            self._decode_batch(active)
        return any(not r.done for r in self.requests.values())

    def run_to_completion(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while self.step():
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serve did not converge")
        return {rid: r.generated for rid, r in self.requests.items()}
