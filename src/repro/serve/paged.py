"""Paged KV cache: a software page table for serving, umem-integrated.

The pool is one allocation in the UnifiedMemory runtime: page residency
(HBM vs host), access counters and migrations follow the paper's system-
memory policy — hot sequences' pages migrate device-side, cold ones are
read remotely. kernels/paged_attention consumes the pool directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Actor, UnifiedMemory, system_policy
from repro.models.layout import HeadLayout


class PagedKVCache:
    def __init__(self, cfg, layout: HeadLayout, *, max_seqs: int, max_len: int,
                 page_size: int = 64, num_pages: Optional[int] = None,
                 dtype=jnp.float32, um: Optional[UnifiedMemory] = None):
        self.cfg = cfg
        self.layout = layout
        self.page_size = page_size
        self.max_seqs = max_seqs
        self.pages_per_seq = -(-max_len // page_size)
        self.num_pages = num_pages or (max_seqs * self.pages_per_seq + 1)
        N, D = layout.n_kv_eff, cfg.head_dim
        L = cfg.num_layers
        self.k_pools = [jnp.zeros((self.num_pages, page_size, N, D), dtype)
                        for _ in range(L)]
        self.v_pools = [jnp.zeros((self.num_pages, page_size, N, D), dtype)
                        for _ in range(L)]
        self.page_table = np.zeros((max_seqs, self.pages_per_seq), np.int32)
        self.lengths = np.zeros((max_seqs,), np.int32)
        self.active = np.zeros((max_seqs,), bool)
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))  # 0 = null

        self.um = um
        self.page_bytes = 2 * L * page_size * N * D * jnp.dtype(dtype).itemsize
        if um is not None:
            self.alloc = um.alloc("kv_pool", self.num_pages * self.page_bytes,
                                  system_policy(page_size=self.page_bytes))

    # ------------------------------------------------------------- slots
    def new_seq(self) -> int:
        sid = int(np.nonzero(~self.active)[0][0])
        self.active[sid] = True
        self.lengths[sid] = 0
        self.page_table[sid] = 0
        return sid

    def release(self, sid: int) -> None:
        for p in self.page_table[sid]:
            if p:
                self._free.append(int(p))
        self.active[sid] = False
        self.page_table[sid] = 0
        self.lengths[sid] = 0

    def _page_for(self, sid: int, pos: int) -> int:
        j = pos // self.page_size
        if self.page_table[sid, j] == 0:
            assert self._free, "page pool exhausted"
            self.page_table[sid, j] = self._free.pop()
        return int(self.page_table[sid, j])

    # ------------------------------------------------------------- writes
    def write_prefill(self, sid: int, layer: int, k, v) -> None:
        """k,v: (S, N, D) for one sequence; fills pages [0, S)."""
        S = k.shape[0]
        PS = self.page_size
        for j in range(-(-S // PS)):
            pid = self._page_for(sid, j * PS)
            blk_k = k[j * PS: (j + 1) * PS]
            blk_v = v[j * PS: (j + 1) * PS]
            n = blk_k.shape[0]
            self.k_pools[layer] = jax.lax.dynamic_update_slice(
                self.k_pools[layer], blk_k[None], (pid, 0, 0, 0))
            self.v_pools[layer] = jax.lax.dynamic_update_slice(
                self.v_pools[layer], blk_v[None], (pid, 0, 0, 0))
        if layer == self.cfg.num_layers - 1:
            self.lengths[sid] = S
            self._touch(sid, S)

    def write_token(self, sid_list, layer: int, k, v, pos_list) -> None:
        """k,v: (B, N, D) new-token KV for sequences sid_list at pos_list."""
        PS = self.page_size
        pids = np.array([self._page_for(s, p) for s, p in zip(sid_list, pos_list)])
        slots = np.array([p % PS for p in pos_list])
        kp = self.k_pools[layer].at[pids, slots].set(k)
        vp = self.v_pools[layer].at[pids, slots].set(v)
        self.k_pools[layer] = kp
        self.v_pools[layer] = vp

    def commit_token(self, sid_list, pos_list) -> None:
        for s, p in zip(sid_list, pos_list):
            self.lengths[s] = p + 1
            self._touch(s, 1)

    def _touch(self, sid: int, ntok: int) -> None:
        if self.um is None:
            return
        # account page-granular access in the unified-memory runtime: batch
        # every resident page of the sequence into ONE kernel call, coalescing
        # consecutive pool pages into extents (the pool allocator is mostly
        # sequential, so a sequence usually collapses to a handful of ranges)
        npages = -(-int(self.lengths[sid]) // self.page_size)
        pids = np.sort(self.page_table[sid, :npages].astype(np.int64))
        if len(pids) == 0:
            return
        splits = np.flatnonzero(np.diff(pids) != 1) + 1
        starts = pids[np.concatenate(([0], splits))]
        ends = pids[np.concatenate((splits - 1, [len(pids) - 1]))] + 1
        reads = [(self.alloc, int(s) * self.page_bytes, int(e) * self.page_bytes)
                 for s, e in zip(starts, ends)]
        self.um.kernel(reads=reads, actor=Actor.GPU, name=f"kv_seq{sid}")

    # ------------------------------------------------------------- views
    def batch_view(self, sids):
        pt = jnp.asarray(self.page_table[sids])
        ln = jnp.asarray(self.lengths[sids])
        return pt, ln
