"""Paged KV cache: a software page table for serving, umem-integrated.

The pool is one allocation in the UnifiedMemory runtime: page residency
(HBM vs host), access counters and migrations follow the paper's system-
memory policy by default — hot sequences' pages migrate device-side, cold
ones are read remotely (``mem_policy`` swaps the pool onto any registered
backend, see docs/memspace.md). kernels/paged_attention consumes the pool
directly.

The pool may be allocated *larger than device capacity* (``num_pages``):
under the system policy first-touch simply maps the overflow host-side and
decode runs with remote KV pages — the paper's graceful-oversubscription
behavior (§7) applied to serving. The scheduler in serve/engine.py drives
the lifecycle: sequences that lose their pool pages to preemption are
swapped out host-side (``swap_out``) and scattered back on resume
(``swap_in``), at which point the access-counter path re-promotes their
pages.

Write paths are vectorized: a whole prefill chunk lands in one fancy-index
scatter (no per-page Python loop, no ``dynamic_update_slice``), sliced to
the real block length so partial pages never zero-pad into the pool.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import (Actor, BufferView, KernelBatch, MemPolicy,
                        UnifiedMemory, coalesce_runs, make_policy,
                        system_policy)
from repro.models.layout import HeadLayout


class PagedKVCache:
    @staticmethod
    def page_bytes_for(cfg, layout: HeadLayout, page_size: int,
                       dtype=jnp.float32) -> int:
        """Bytes of one pool page (k+v, all layers) — usable without building
        the pools, e.g. to size a modeled device capacity."""
        return (2 * cfg.num_layers * page_size * layout.n_kv_eff
                * cfg.head_dim * jnp.dtype(dtype).itemsize)

    def __init__(self, cfg, layout: HeadLayout, *, max_seqs: int, max_len: int,
                 page_size: int = 64, num_pages: Optional[int] = None,
                 dtype=jnp.float32, um: Optional[UnifiedMemory] = None,
                 counter_threshold: int = 16,
                 mem_policy: "MemPolicy | str | None" = None,
                 seq_node=None):
        self.cfg = cfg
        self.layout = layout
        # sid -> issuing superchip for node-aware pools (None: ambient node).
        # Tracked launches over a sequence's pages are pinned through this,
        # so first touch places each sequence's KV on its serving node.
        self.seq_node = seq_node
        self.page_size = page_size
        self.max_seqs = max_seqs
        self.pages_per_seq = -(-max_len // page_size)
        self.num_pages = num_pages or (max_seqs * self.pages_per_seq + 1)
        N, D = layout.n_kv_eff, cfg.head_dim
        L = cfg.num_layers
        self.k_pools = [jnp.zeros((self.num_pages, page_size, N, D), dtype)
                        for _ in range(L)]
        self.v_pools = [jnp.zeros((self.num_pages, page_size, N, D), dtype)
                        for _ in range(L)]
        self.page_table = np.zeros((max_seqs, self.pages_per_seq), np.int32)
        self.lengths = np.zeros((max_seqs,), np.int32)
        self.active = np.zeros((max_seqs,), bool)
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))  # 0 = null

        self.um = um
        self.page_bytes = self.page_bytes_for(cfg, layout, page_size, dtype)
        if um is not None:
            # serving pages are big (page_bytes >> the HW remote-access grain),
            # so one decode touch of a remote page already counts several
            # transactions — a low threshold keeps the counter path responsive.
            # The pool is a typed buffer (num_pages x page_bytes), the same
            # front-end the paper apps use: one umem page per pool page, and
            # buf.rows(lo, hi) is the extent of a pool-page run.
            # mem_policy opens the pool to other registered backends: a
            # MemPolicy instance is used AS-IS — it carries its own
            # threshold, and counter_threshold only applies when mem_policy
            # is None or a registry name whose factory takes the knob — and
            # its page_size must equal page_bytes; a registry name is built
            # at pool-page granularity.
            if mem_policy is None:
                mem_policy = system_policy(page_size=self.page_bytes,
                                           threshold=counter_threshold)
            elif isinstance(mem_policy, str):
                mem_policy = make_policy(mem_policy, page_size=self.page_bytes,
                                         threshold=counter_threshold)
            assert mem_policy.paged, \
                f"KV pool needs a paged backend; {mem_policy.kind!r} has no " \
                "page table (its swap/demote/extent paths cannot work)"
            assert mem_policy.page_size == self.page_bytes, \
                f"pool policy must be paged at one umem page per KV pool " \
                f"page ({mem_policy.kind!r} came back with page_size=" \
                f"{mem_policy.page_size}, pool pages are {self.page_bytes} B " \
                "— its factory must honor the page_size knob)"
            self.buf = um.array("kv_pool", (self.num_pages, self.page_bytes),
                                np.uint8, mem_policy)
            self.alloc = self.buf.alloc

    # ------------------------------------------------------------- slots
    def free_slots(self) -> int:
        return int(np.count_nonzero(~self.active))

    def free_pages(self) -> int:
        return len(self._free)

    def pages_for(self, ntok: int) -> int:
        return -(-ntok // self.page_size)

    def new_seq(self) -> int:
        sid = int(np.nonzero(~self.active)[0][0])
        self.active[sid] = True
        self.lengths[sid] = 0
        self.page_table[sid] = 0
        return sid

    def release(self, sid: int) -> None:
        row = self.page_table[sid]
        self._free.extend(int(p) for p in row[row != 0])
        self.active[sid] = False
        self.page_table[sid] = 0
        self.lengths[sid] = 0

    # ------------------------------------------------------- page accounting
    def alloc_range(self, sid: int, start: int, end: int) -> None:
        """Ensure pages backing positions [start, end) are allocated.

        Vectorized: all holes fill from the free stack in one shot, in the
        exact order sequential pop() calls would have used (so pool page ids
        — and therefore the umem pool's run layout — are unchanged)."""
        j0, j1 = start // self.page_size, -(-end // self.page_size)
        row = self.page_table[sid, j0:j1]
        holes = np.flatnonzero(row == 0)
        if len(holes):
            assert len(self._free) >= len(holes), "page pool exhausted"
            row[holes] = self._free[:-len(holes) - 1:-1]
            del self._free[-len(holes):]

    def missing_pages(self, sid: int, end: int) -> int:
        """Pages still unallocated among those backing positions [0, end)."""
        j1 = min(self.pages_per_seq, -(-end // self.page_size))
        return int(np.count_nonzero(self.page_table[sid, :j1] == 0))

    def allocated_until(self, sid: int) -> int:
        """First position not covered by an already-allocated page."""
        row = self.page_table[sid]
        holes = np.flatnonzero(row == 0)
        j = int(holes[0]) if len(holes) else self.pages_per_seq
        return j * self.page_size

    def _flat_idx(self, sid: int, start: int, n: int):
        pos = start + np.arange(n)
        pids = self.page_table[sid, pos // self.page_size]
        assert (pids != 0).all(), "write into unallocated page"
        return pids, pos % self.page_size

    # ------------------------------------------------------------- writes
    def write_at(self, sid: int, layer: int, k, v, start: int) -> None:
        """Scatter S tokens' KV at positions [start, start+S) of sequence sid.

        k, v: (S, N, D). One fancy-index scatter per pool — every page of the
        chunk lands at once, and the update covers exactly S slots (a partial
        tail page is never zero-padded)."""
        S = k.shape[0]
        pids, slots = self._flat_idx(sid, start, S)
        self.k_pools[layer] = self.k_pools[layer].at[pids, slots].set(k)
        self.v_pools[layer] = self.v_pools[layer].at[pids, slots].set(v)

    def write_prefill(self, sid: int, layer: int, k, v) -> None:
        """k, v: (S, N, D) for one sequence; fills positions [0, S)."""
        S = k.shape[0]
        self.alloc_range(sid, 0, S)
        self.write_at(sid, layer, k, v, 0)
        if layer == self.cfg.num_layers - 1:
            self.commit_prefill(sid, S)

    def commit_prefill(self, sid: int, new_len: int) -> None:
        self.lengths[sid] = new_len
        self._touch(sid)

    def write_token(self, sid_list, layer: int, k, v, pos_list) -> None:
        """k, v: (B, N, D) new-token KV for sequences sid_list at pos_list."""
        sids = np.asarray(sid_list)
        pos = np.asarray(pos_list)
        pids = self.page_table[sids, pos // self.page_size]
        assert (pids != 0).all(), "decode write into unallocated page"
        slots = pos % self.page_size
        self.k_pools[layer] = self.k_pools[layer].at[pids, slots].set(k)
        self.v_pools[layer] = self.v_pools[layer].at[pids, slots].set(v)

    def commit_token(self, sid_list, pos_list) -> None:
        # lengths first, then one batched engine step over every decoded
        # sequence's pool pages: sids are unique within a decode batch, so
        # each kv_seq launch sees exactly the views the sequential
        # touch-per-sequence loop would have (charges are bit-identical)
        for s, p in zip(sid_list, pos_list):
            self.lengths[s] = p + 1
        if self.um is None:
            return
        batch = KernelBatch()
        for s in sid_list:
            views = self.seq_views(s)
            if views:
                batch.launch(f"kv_seq{s}", reads=views, actor=Actor.GPU,
                             node=self._node_of(s))
        if len(batch):
            self.um.launch_batch(batch)

    # ------------------------------------------------------------- reads
    def gather_kv(self, sid: int, layer: int, length: int):
        """Gather positions [0, length) of sequence sid -> (length, N, D) pair."""
        pids, slots = self._flat_idx(sid, 0, length)
        return self.k_pools[layer][pids, slots], self.v_pools[layer][pids, slots]

    # ------------------------------------------------------------- swap
    def swap_out(self, sid: int) -> Dict[str, object]:
        """Demote a sequence host-side: copy its KV out of the pool and release
        every pool page. Returns the saved state for swap_in."""
        L = int(self.lengths[sid])
        pairs = [self.gather_kv(sid, layer, L)
                 for layer in range(self.cfg.num_layers)]
        self.release(sid)
        return {"len": L, "k": [np.asarray(k) for k, _ in pairs],
                "v": [np.asarray(v) for _, v in pairs]}

    def swap_in(self, saved: Dict[str, object]) -> int:
        """Re-admit a swapped-out sequence: allocate fresh pages and scatter the
        saved KV back into the pool. Returns the new sid."""
        sid = self.new_seq()
        L = int(saved["len"])
        self.alloc_range(sid, 0, L)
        for layer in range(self.cfg.num_layers):
            self.write_at(sid, layer, jnp.asarray(saved["k"][layer]),
                          jnp.asarray(saved["v"][layer]), 0)
        self.lengths[sid] = L
        return sid

    # ------------------------------------------------------------- umem
    def close(self) -> None:
        """Free the pool's UnifiedMemory allocation. Residency (host and
        device) must return to its pre-pool baseline — the serve-path
        clause of the policy-conformance contract pins this symmetry."""
        if self.um is not None:
            self.um.free(self.alloc)

    def _seq_page_runs(self, sid: int) -> List[Tuple[int, int]]:
        """[lo, hi) pool-page runs of the sequence, consecutive pages
        coalesced (the allocator is mostly sequential, so a sequence usually
        collapses to a handful of runs)."""
        npages = -(-int(self.lengths[sid]) // self.page_size)
        pids = np.sort(self.page_table[sid, :npages].astype(np.int64))
        return coalesce_runs(pids[pids != 0])

    def seq_views(self, sid: int) -> List[BufferView]:
        """The sequence's pool pages as buffer row bands — what the engine
        hands to um.demote / um.prefetch_async and _touch launches over."""
        return [self.buf.rows(s, e) for s, e in self._seq_page_runs(sid)]

    def seq_extents(self, sid: int) -> List[Tuple[int, int]]:
        """Byte extents of the sequence's pool pages (coalesced runs)."""
        return [(s * self.page_bytes, e * self.page_bytes)
                for s, e in self._seq_page_runs(sid)]

    def seqs_touching_pages(self, runs) -> List[int]:
        """Active sequence ids whose pool pages intersect the given [lo, hi)
        pool-page runs. The pool is paged at one umem page per pool page, so
        the poisoned runs ``um.fail_node`` reports for the pool allocation
        index pool pages directly — the engine replays the sequences this
        returns from their prompts."""
        if not runs:
            return []
        dead = np.zeros(self.num_pages, bool)
        for s, e in runs:
            dead[int(s):int(e)] = True
        out = []
        for sid in np.flatnonzero(self.active):
            row = self.page_table[sid]
            pids = row[row != 0]
            if len(pids) and dead[pids].any():
                out.append(int(sid))
        return out

    def _node_of(self, sid: int):
        return None if self.seq_node is None else self.seq_node(sid)

    def _touch(self, sid: int) -> None:
        if self.um is None:
            return
        # account page-granular access in the unified-memory runtime: batch
        # every resident page of the sequence into ONE tracked launch
        views = self.seq_views(sid)
        if views:
            self.um.launch(f"kv_seq{sid}", reads=views, actor=Actor.GPU,
                           node=self._node_of(sid))

    # ------------------------------------------------------------- views
    def batch_view(self, sids):
        pt = jnp.asarray(self.page_table[sids])
        ln = jnp.asarray(self.lengths[sids])
        return pt, ln
