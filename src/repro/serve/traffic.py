"""Production traffic harness: arrival processes, tenants, scenario presets.

Drives the real model stack (models/transformer prefill + the
paged-attention Pallas decode kernel) through :class:`ServeEngine` over
the UM-backed KV pool under *realistic* load instead of a fixed sweep:

* **Arrival processes** — seeded Poisson, bursty (Poisson burst starts,
  near-simultaneous arrivals within a burst) and uniform spacing, all in
  modeled seconds against the engine clock (``engine.now()``).
* **Heavy-tail lengths** — lognormal / bounded-Pareto prompt and output
  length distributions (the paper-adjacent serving reality: most requests
  short, a fat tail of long ones).
* **Multi-tenant mixes** — each :class:`TenantSpec` names a model config
  from ``repro.configs``; tenants sharing a config share one engine
  (continuous batching across tenants), different configs get independent
  engines over the same virtual timebase. SLO metrics come back per
  tenant (serve/metrics.py).
* **Scenario presets** — ``steady`` / ``burst`` / ``oversubscribed``
  (:data:`SCENARIOS`), each runnable under any registered memory-policy
  backend (PR 5 registry) via ``TrafficSim(scenario, policy=...)``.

Everything is seeded: the schedule (arrival times, prompt token ids,
output lengths) is generated up front from ``np.random.default_rng([seed,
tenant_index])``, and the engine charges are a deterministic function of
the schedule — so a same-seed run reproduces token streams AND SLO
metrics bit-for-bit (tests/test_traffic.py pins this).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import UnifiedMemory, get_hardware, make_policy
from repro.models.cache import kv_head_layout
from repro.serve.engine import ServeEngine
from repro.serve.metrics import RequestRecord, collect, summarize
from repro.serve.paged import PagedKVCache


# --------------------------------------------------------------- arrivals
@dataclass(frozen=True)
class ArrivalProcess:
    """Seeded arrival-time generator (modeled seconds).

    kind='poisson': exponential inter-arrivals at ``rate`` req/s.
    kind='bursty' : burst *starts* are Poisson at ``rate / burst_size``;
                    each burst delivers ``burst_size`` requests spread by
                    exponential jitter at scale ``burst_spread`` — the
                    near-simultaneous arrival spikes that force queueing
                    and preemption however generous the mean rate is.
    kind='uniform': deterministic spacing ``1 / rate``.
    """
    kind: str = "poisson"
    rate: float = 100.0
    burst_size: int = 8
    burst_spread: float = 1e-6

    def times(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.kind == "poisson":
            return np.cumsum(rng.exponential(1.0 / self.rate, n))
        if self.kind == "uniform":
            return (1.0 + np.arange(n, dtype=np.float64)) / self.rate
        if self.kind == "bursty":
            nb = -(-n // self.burst_size)
            starts = np.cumsum(
                rng.exponential(self.burst_size / self.rate, nb))
            jitter = np.cumsum(
                rng.exponential(self.burst_spread, (nb, self.burst_size)),
                axis=1)
            return (starts[:, None] + jitter).reshape(-1)[:n]
        raise ValueError(f"unknown arrival kind {self.kind!r}")


# ---------------------------------------------------------------- lengths
@dataclass(frozen=True)
class LengthDist:
    """Heavy-tail (or fixed) integer length sampler, clipped to [lo, hi].

    kind='lognormal': mean ``mean`` (pre-clip), shape ``sigma``.
    kind='pareto'   : bounded Pareto starting at ``lo``, tail ``alpha``.
    kind='fixed'    : every sample is ``mean``.
    """
    kind: str = "lognormal"
    lo: int = 1
    hi: int = 64
    mean: float = 16.0
    sigma: float = 0.8
    alpha: float = 1.5

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        if self.kind == "lognormal":
            mu = np.log(self.mean) - 0.5 * self.sigma ** 2
            raw = rng.lognormal(mu, self.sigma, n)
        elif self.kind == "pareto":
            raw = self.lo * (1.0 + rng.pareto(self.alpha, n))
        elif self.kind == "fixed":
            raw = np.full(n, float(self.mean))
        else:
            raise ValueError(f"unknown length kind {self.kind!r}")
        return np.clip(np.rint(raw).astype(np.int64), self.lo, self.hi)


# ---------------------------------------------------------------- tenants
@dataclass(frozen=True)
class TenantSpec:
    """One traffic class: which model it hits, how it arrives, how long
    its prompts/outputs are. Tenants with the same ``arch`` share an
    engine (continuous batching across tenants)."""
    name: str
    arch: str
    num_requests: int
    arrival: ArrivalProcess = ArrivalProcess()
    prompt: LengthDist = LengthDist(lo=4, hi=48, mean=14.0)
    output: LengthDist = LengthDist(lo=1, hi=16, mean=6.0)


@dataclass(frozen=True)
class Scenario:
    """A named preset: tenant mix + engine/pool shape + oversubscription.

    ``oversub`` > 1 shrinks the modeled device capacity to ``pool_bytes /
    oversub`` (the fig11 methodology applied to serving); the overflow KV
    lives host-side under migratable backends.
    """
    name: str
    tenants: Tuple[TenantSpec, ...]
    oversub: float = 1.0
    page_size: int = 8
    max_seqs: int = 8
    max_len: int = 96
    prefill_chunk: int = 32
    num_pages: Optional[int] = None  # per-engine pool override
    # device-pressure admission gate (engine admit_device_fraction); 0
    # disables it — the oversubscribed preset does, so admitted KV really
    # exceeds capacity and first-touch spills host-side (fig11 style)
    # instead of the gate serializing the engine into an in-memory run
    admit_device_fraction: float = 0.5
    description: str = ""


# ---------------------------------------------------------------- presets
# Tuned against the reduced() configs' modeled charge scale: a KV pool page
# is KBs and the modeled link streams GB/s, so an engine step is ~us of
# modeled time — rates are accordingly high to create genuine contention.
_ARCHS = ("yi-6b", "qwen2.5-32b", "olmoe-1b-7b")


def steady(scale: float = 1.0) -> Scenario:
    n = max(2, int(8 * scale))
    return Scenario(
        name="steady",
        description="Poisson arrivals at moderate load, three model "
                    "configs (dense GQA, dense, MoE), heavy-tail lengths",
        tenants=tuple(
            TenantSpec(name=f"t{i}_{arch}", arch=arch, num_requests=n,
                       arrival=ArrivalProcess("poisson", rate=2e5),
                       prompt=LengthDist("lognormal", lo=4, hi=40, mean=12.0),
                       output=LengthDist("lognormal", lo=1, hi=12, mean=5.0))
            for i, arch in enumerate(_ARCHS)),
        max_seqs=6, max_len=64, prefill_chunk=24)


def burst(scale: float = 1.0) -> Scenario:
    n = max(6, int(12 * scale))
    return Scenario(
        name="burst",
        description="On/off bursts (8 near-simultaneous arrivals) against "
                    "a slot- and pool-limited engine: queueing delay plus "
                    "preempt/swap churn under the spikes",
        tenants=tuple(
            TenantSpec(name=f"t{i}_{arch}", arch=arch, num_requests=n,
                       arrival=ArrivalProcess("bursty", rate=4e5,
                                              burst_size=8),
                       prompt=LengthDist("pareto", lo=16, hi=40, alpha=1.4),
                       output=LengthDist("lognormal", lo=6, hi=12,
                                         mean=10.0))
            for i, arch in enumerate(_ARCHS)),
        # 10 pages backs the longest single sequence (40+12 tokens = 7
        # pages) but NOT a burst-load batch of them: admission lazily
        # overcommits the pool, so the decode batch outgrows it and the
        # youngest sequences preempt/swap and resume to drain the burst
        max_seqs=4, max_len=64, prefill_chunk=16, num_pages=10)


def oversubscribed(scale: float = 1.0) -> Scenario:
    n = max(6, int(12 * scale))
    return Scenario(
        name="oversubscribed",
        description="KV pool 1.5x the modeled device capacity with the "
                    "pressure gate off: decode reads remote KV pages, "
                    "migratable backends keep serving",
        tenants=tuple(
            TenantSpec(name=f"t{i}_{arch}", arch=arch, num_requests=n,
                       arrival=ArrivalProcess("poisson", rate=4e5),
                       prompt=LengthDist("lognormal", lo=16, hi=56,
                                         mean=32.0, sigma=0.5),
                       output=LengthDist("lognormal", lo=4, hi=12, mean=8.0))
            for i, arch in enumerate(_ARCHS)),
        # pool sized near the peak concurrent demand (~5 pages per running
        # seq x 6 slots) so a 1.5x capacity shrink really strands KV
        # host-side instead of hiding inside a roomy default pool
        oversub=1.5, max_seqs=6, max_len=64, prefill_chunk=24,
        num_pages=30, admit_device_fraction=0.0)


SCENARIOS = {"steady": steady, "burst": burst,
             "oversubscribed": oversubscribed}


def get_scenario(name: str, scale: float = 1.0) -> Scenario:
    try:
        return SCENARIOS[name](scale)
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; presets: "
                       f"{', '.join(sorted(SCENARIOS))}") from None


# -------------------------------------------------------------- simulator
@dataclass(frozen=True)
class _Arrival:
    t: float
    tenant: str
    prompt: np.ndarray
    max_new: int


@dataclass
class TrafficResult:
    scenario: str
    policy: str
    seed: int
    records: List[RequestRecord]
    tokens: Dict[str, List[int]]  # "<arch>/<rid>" -> generated token stream
    metrics: Dict[str, object]
    per_engine: Dict[str, Dict[str, object]] = field(default_factory=dict)


class TrafficSim:
    """Drive a :class:`Scenario` through one ServeEngine per model config.

    ``policy`` is a PR 5 registry name — the KV pool of every engine is
    placed under that backend (at pool-page granularity). ``models`` maps
    arch name -> (cfg, params) to inject prebuilt models (tests use tiny
    1-layer configs); unlisted archs resolve via
    ``get_config(arch).reduced()``.
    """

    def __init__(self, scenario: Scenario, *, policy: str = "system",
                 hw=None, seed: int = 0, models: Optional[dict] = None,
                 use_um: bool = True, counter_threshold: int = 4,
                 tp: int = 1, fault_plan=None):
        self.scenario = scenario
        self.policy = policy
        self.seed = seed
        self.tp = tp
        # one frozen FaultPlan shared by every engine (each keeps its own
        # cursor), so the same schedule hits each arch's engine at the same
        # engine-step offsets — deterministic across runs
        self.fault_plan = fault_plan
        self.engines: Dict[str, ServeEngine] = {}
        self._arrivals: Dict[str, List[_Arrival]] = {}
        self.pool_bytes: Dict[str, int] = {}

        by_arch: Dict[str, List[Tuple[int, TenantSpec]]] = {}
        for ti, ten in enumerate(scenario.tenants):
            by_arch.setdefault(ten.arch, []).append((ti, ten))

        for arch, tenants in by_arch.items():
            cfg, params = self._model(arch, models, seed)
            lay = kv_head_layout(cfg, 1)
            page_bytes = PagedKVCache.page_bytes_for(cfg, lay,
                                                     scenario.page_size)
            pages_per_seq = -(-scenario.max_len // scenario.page_size)
            num_pages = (scenario.num_pages
                         or scenario.max_seqs * pages_per_seq + 1)
            pool_bytes = num_pages * page_bytes
            self.pool_bytes[arch] = pool_bytes
            um = None
            tp_plan = None
            if use_um:
                hw_model = get_hardware(hw)
                if scenario.oversub > 1.0:
                    # with_device_capacity (not dataclasses.replace): multi-
                    # node models keep their per-node split consistent
                    hw_model = hw_model.with_device_capacity(
                        int(pool_bytes / scenario.oversub))
                um = UnifiedMemory(hw=hw_model)
                if tp > 1:
                    from repro.cluster.serve import ClusterTPPlan
                    tp_plan = ClusterTPPlan(tp)
            self.engines[arch] = ServeEngine(
                cfg, params, max_seqs=scenario.max_seqs,
                max_len=scenario.max_len, page_size=scenario.page_size,
                num_pages=num_pages, um=um,
                prefill_chunk=scenario.prefill_chunk,
                counter_threshold=counter_threshold,
                admit_device_fraction=scenario.admit_device_fraction,
                mem_policy=policy if um is not None else None,
                tp_plan=tp_plan,
                fault_plan=fault_plan if um is not None else None)
            self._arrivals[arch] = self._schedule(cfg, tenants, seed)

    @staticmethod
    def _model(arch: str, models: Optional[dict], seed: int):
        if models and arch in models:
            return models[arch]
        import jax  # deferred: schedule-only use of the sim stays jax-free
        from repro.configs import get_config
        from repro.models import init_params
        cfg = get_config(arch).reduced()
        return cfg, init_params(cfg, jax.random.PRNGKey(seed))

    def _schedule(self, cfg, tenants, seed: int) -> List[_Arrival]:
        """The full arrival list for one engine, generated up front from
        per-tenant seeded streams and merged in (time, tenant_index, i)
        order — the deterministic spine of the whole simulation."""
        out: List[Tuple[float, int, int, _Arrival]] = []
        for ti, ten in tenants:
            rng = np.random.default_rng([self.seed, ti])
            n = ten.num_requests
            times = ten.arrival.times(rng, n)
            plens = np.minimum(ten.prompt.sample(rng, n),
                               self.scenario.max_len - 2)
            outs = ten.output.sample(rng, n)
            for i in range(n):
                prompt = rng.integers(2, cfg.vocab_size, int(plens[i]))
                out.append((float(times[i]), ti, i,
                            _Arrival(float(times[i]), ten.name, prompt,
                                     int(outs[i]))))
        out.sort(key=lambda x: (x[0], x[1], x[2]))
        return [a for *_, a in out]

    # ------------------------------------------------------------------ run
    def _drive(self, eng: ServeEngine, arrivals: List[_Arrival],
               max_steps: int) -> None:
        """Arrival-driven event loop for one engine: deliver due requests,
        fast-forward idle gaps to the next arrival, step while busy."""
        i, steps = 0, 0
        while True:
            in_flight = any(not r.done for r in eng.requests.values())
            if not in_flight and i < len(arrivals):
                eng.advance_to(arrivals[i].t)
            while i < len(arrivals) and arrivals[i].t <= eng.now():
                a = arrivals[i]
                eng.add_request(a.prompt, a.max_new, arrival_time=a.t,
                                tenant=a.tenant)
                i += 1
                in_flight = True
            if not in_flight and i >= len(arrivals):
                return
            eng.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"traffic sim did not converge in {max_steps} steps "
                    f"({i}/{len(arrivals)} arrivals delivered)")

    def run(self, *, max_steps: int = 100_000,
            slo_ttft: Optional[float] = None) -> TrafficResult:
        records: List[RequestRecord] = []
        tokens: Dict[str, List[int]] = {}
        per_engine: Dict[str, Dict[str, object]] = {}
        for arch in sorted(self.engines):
            eng = self.engines[arch]
            self._drive(eng, self._arrivals[arch], max_steps)
            records.extend(collect(eng))
            for rid, r in sorted(eng.requests.items()):
                tokens[f"{arch}/{rid}"] = list(r.generated)
            per_engine[arch] = {
                "clock": eng.now(),
                "stats": dataclasses.asdict(eng.stats),
                "pool_bytes": self.pool_bytes[arch],
                "um_report": (eng.um.report() if eng.um is not None
                              else None),
            }
        return TrafficResult(scenario=self.scenario.name, policy=self.policy,
                             seed=self.seed, records=records, tokens=tokens,
                             metrics=summarize(records, slo_ttft=slo_ttft),
                             per_engine=per_engine)


def policy_supports(policy: str, scenario: Scenario) -> bool:
    """Whether a registry backend can run a scenario at all: the KV pool
    needs a paged backend, and oversubscription needs migratable pages
    (a single-pool backend like mi300a_unified has nowhere to spill)."""
    pol = make_policy(policy, page_size=4096)
    if not pol.paged:
        return False
    if scenario.oversub > 1.0 and not pol.migratable:
        return False
    return True
