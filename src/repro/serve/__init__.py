from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.paged import PagedKVCache  # noqa: F401
