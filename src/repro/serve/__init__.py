"""Oversubscription-aware continuous-batching LM serving.

`ServeEngine` (engine.py) schedules requests through the states
pending -> prefill -> decoding -> preempted -> done: memory-pressure
admission control, chunked prefill, preemption (KV demoted host-side)
with bit-identical resume, and async promotion of a resumed sequence's
extents ahead of its decode turn. `PagedKVCache` (paged.py) is the
umem-governed page pool underneath — it may be allocated larger than
device capacity, with cold pages read remotely under the system policy
(the paper's §7 graceful oversubscription applied to serving).
The production traffic harness (traffic.py: arrival processes,
multi-tenant scenario presets) drives the engine under realistic load
and reports SLO metrics (metrics.py: p50/p99 TTFT, per-token latency,
goodput). See docs/serving.md.
"""
from repro.serve.engine import EngineStats, Request, SeqState, ServeEngine  # noqa: F401
from repro.serve.metrics import RequestRecord, collect, summarize  # noqa: F401
from repro.serve.paged import PagedKVCache  # noqa: F401
from repro.serve.traffic import (  # noqa: F401
    SCENARIOS,
    ArrivalProcess,
    LengthDist,
    Scenario,
    TenantSpec,
    TrafficResult,
    TrafficSim,
    get_scenario,
    policy_supports,
)
