"""Oversubscription-aware continuous-batching LM serving.

`ServeEngine` (engine.py) schedules requests through the states
pending -> prefill -> decoding -> preempted -> done: memory-pressure
admission control, chunked prefill, preemption (KV demoted host-side)
with bit-identical resume, and async promotion of a resumed sequence's
extents ahead of its decode turn. `PagedKVCache` (paged.py) is the
umem-governed page pool underneath — it may be allocated larger than
device capacity, with cold pages read remotely under the system policy
(the paper's §7 graceful oversubscription applied to serving).
See docs/serving.md.
"""
from repro.serve.engine import EngineStats, Request, SeqState, ServeEngine  # noqa: F401
from repro.serve.paged import PagedKVCache  # noqa: F401
