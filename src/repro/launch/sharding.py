"""Sharding rules: param/optimizer/activation PartitionSpecs for any arch.

Megatron TP over 'model' (QKV/up column-parallel; O/down row-parallel; vocab
sharded embedding + logits; MoE experts = EP over 'model'), DP over
('pod','data'), ZeRO-1 optimizer-state sharding over the DP axes. Rules are
path-pattern based with divisibility guards: a dim is sharded only if
divisible by the axis size (the exact TP head layout in models/layout.py
guarantees divisibility for head dims; anything else falls back to
replication rather than failing).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes, dp_size, tp_size
from repro.models.layers import RunPolicy

# (path regex, spec template) — template entries name mesh axes or None;
# 'MODEL' is replaced by 'model', 'DP' by the dp axes tuple.
_PARAM_RULES = [
    (r"embed/w$", ("MODEL", None)),
    (r"head/w$", (None, "MODEL")),
    # attention
    (r"mixer/wq$", (None, "MODEL", None)),
    (r"mixer/wk$", (None, "MODEL", None)),
    (r"mixer/wv$", (None, "MODEL", None)),
    (r"mixer/wo$", ("MODEL", None, None)),
    (r"mixer/b[qkv]$", ("MODEL", None)),
    # dense mlp
    (r"ffn/w_gate$", (None, "MODEL")),
    (r"ffn/w_up$", (None, "MODEL")),
    (r"ffn/w_down$", ("MODEL", None)),
    (r"ffn/b_up$", ("MODEL",)),
    # moe (expert parallelism; 3D expert weights)
    (r"ffn/router$", (None, "MODEL")),
    (r"ffn/w_gate$", ("MODEL", None, None)),
    (r"ffn/w_up$", ("MODEL", None, None)),
    (r"ffn/w_down$", ("MODEL", None, None)),
    # rg-lru
    (r"mixer/w_y$", (None, "MODEL")),
    (r"mixer/w_gate$", (None, "MODEL")),
    (r"mixer/conv_w$", (None, "MODEL")),
    (r"mixer/conv_b$", ("MODEL",)),
    (r"mixer/gate_[ir]$", ("MODEL", None, None)),
    (r"mixer/bias_[ir]$", ("MODEL",)),
    (r"mixer/lambda$", ("MODEL",)),
    (r"mixer/w_out$", ("MODEL", None)),
    # rwkv6
    (r"mixer/w[rkvg]$", (None, "MODEL")),
    (r"mixer/wo$", ("MODEL", None)),
    (r"mixer/u$", ("MODEL", None)),
    (r"mixer/w0$", ("MODEL",)),
    (r"mixer/ln_scale$", ("MODEL",)),
    (r"mixer/ln_bias$", ("MODEL",)),
    (r"ffn/wk$", (None, "MODEL")),
    (r"ffn/wv$", ("MODEL", None)),
    (r"ffn/wr$", (None, "MODEL")),
]


def _flat_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flat_paths(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flat_paths(v, f"{prefix}{i}/")
    else:
        yield prefix[:-1], tree


def _guard(spec_t, shape, mesh) -> P:
    """Drop shardings on non-divisible dims."""
    parts = []
    for dim, ax in zip(shape, spec_t + (None,) * (len(shape) - len(spec_t))):
        if ax is None:
            parts.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        parts.append(ax if dim % size == 0 else None)
    return P(*parts)


def _resolve(template, mesh):
    out = []
    for e in template:
        if e == "MODEL":
            out.append("model")
        elif e == "DP":
            dp = dp_axes(mesh)
            out.append(dp if len(dp) > 1 else dp[0])
        else:
            out.append(e)
    return tuple(out)


def param_specs(params_shape, mesh):
    """Tree of PartitionSpec matching the param tree."""

    def spec_for(path: str, leaf) -> P:
        shape = leaf.shape
        for pat, template in _PARAM_RULES:
            if re.search(pat, path):
                t = _resolve(template, mesh)
                if len(t) != len(shape):
                    continue  # e.g. mlp-vs-moe w_gate rules differ in rank
                return _guard(t, shape, mesh)
        return P()

    flat = {p: spec_for(p, l) for p, l in _flat_paths(params_shape)}
    return _rebuild(params_shape, flat)


def _rebuild(tree, flat, prefix=""):
    if isinstance(tree, dict):
        return {k: _rebuild(tree[k], flat, f"{prefix}{k}/") for k in tree}
    if isinstance(tree, (list, tuple)):
        return type(tree)(
            _rebuild(v, flat, f"{prefix}{i}/") for i, v in enumerate(tree))
    return flat[prefix[:-1]]


def stacked_param_specs(p_specs):
    """Specs for the stacked-layer layout: leading L dim, replicated."""
    out = dict(p_specs)
    out["layers"] = jax.tree.map(lambda s: P(None, *s), p_specs["layers"][0],
                                 is_leaf=lambda x: isinstance(x, P))
    return out


def stacked_params_sds(params_sds):
    """ShapeDtypeStructs for the stacked-layer layout."""
    import jax.numpy as jnp  # noqa: F401

    L = len(params_sds["layers"])
    out = dict(params_sds)
    out["layers"] = jax.tree.map(
        lambda *xs: jax.ShapeDtypeStruct((L,) + xs[0].shape, xs[0].dtype),
        *params_sds["layers"])
    return out


def zero1_specs(p_specs, params_shape, mesh):
    """Optimizer-state specs: param spec + extra shard over the DP axes on the
    first replicated, divisible dim (ZeRO-1)."""
    dp = dp_axes(mesh)
    dsz = dp_size(mesh)
    dp_entry = dp if len(dp) > 1 else dp[0]

    def add_dp(spec: P, leaf) -> P:
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (dim, cur) in enumerate(zip(leaf.shape, parts)):
            if cur is None and dim % dsz == 0:
                parts[i] = dp_entry
                return P(*parts)
        return P(*parts)

    return jax.tree.map(add_dp, p_specs, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


def opt_specs(p_specs, params_shape, mesh):
    z = zero1_specs(p_specs, params_shape, mesh)
    return {"m": z, "v": z, "master": z, "count": P()}


def batch_spec(mesh, *, ndim: int, batch_size: int) -> P:
    dp = dp_axes(mesh)
    entry = dp if len(dp) > 1 else dp[0]
    if batch_size % dp_size(mesh) != 0:
        entry = None  # e.g. long_500k batch=1: replicate
    return P(entry, *([None] * (ndim - 1)))


def cache_specs_tree(cache_shape, mesh, batch_size: int, *, stacked: bool = False):
    """Decode-cache specs: batch over DP; head/state dims over 'model'.

    Head/state dims are addressed from the right so the same rules serve the
    per-layer-list and stacked (L, ...) layouts."""
    dp = dp_axes(mesh)
    entry = dp if len(dp) > 1 else dp[0]
    if batch_size % dp_size(mesh) != 0:
        entry = None
    tsz = tp_size(mesh)
    b_idx = 1 if stacked else 0

    def spec(path: str, leaf) -> P:
        shp = leaf.shape
        nd = len(shp)
        parts = [None] * nd
        parts[b_idx] = entry
        tail = None  # (negative) index of the model-sharded dim
        base = path.split("/")[-1]
        if base in ("k", "v", "ks", "vs"):
            tail = -2  # n_kv_eff
        elif base in ("h", "conv"):
            tail = -1  # lru width
        elif base == "s":
            tail = -3  # rwkv heads
        if tail is not None and shp[tail] % tsz == 0:
            parts[nd + tail] = "model"
        return P(*parts)

    flat = {p: spec(p, l) for p, l in _flat_paths(cache_shape)}
    return _rebuild(cache_shape, flat)


def make_constrain(mesh, *, sequence_parallel: bool = False):
    """RunPolicy.constrain hook: activation sharding constraints by name."""
    dp = dp_axes(mesh)
    entry = dp if len(dp) > 1 else dp[0]

    def constrain(x, name: str):
        if mesh is None:
            return x
        if name == "residual" and x.ndim == 3:
            if x.shape[0] % dp_size(mesh) != 0:
                bspec = None
            else:
                bspec = entry
            if sequence_parallel and x.shape[1] % tp_size(mesh) == 0:
                spec = P(bspec, "model", None)
            else:
                spec = P(bspec, None, None)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        if name == "moe_experts" and x.ndim == 3:
            espec = "model" if x.shape[0] % tp_size(mesh) == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(espec, None, None)))
        if name == "logits" and x.ndim == 3:
            bspec = entry if x.shape[0] % dp_size(mesh) == 0 else None
            vspec = "model" if x.shape[-1] % tp_size(mesh) == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(bspec, None, vspec)))
        return x

    return constrain


def tp_shard_nodes(tp: int, nodes: int) -> Tuple[int, ...]:
    """Superchip index per tensor-parallel rank when ``tp`` ranks spread
    over ``nodes`` superchips: consecutive ranks pack onto a node
    (ceil(tp/nodes) per node), so intra-node ranks share the fast C2C/
    NVLink domain and only the inter-node boundary crosses the fabric.
    Pure integers — the cluster serve plan and cluster benchmarks place
    TP shards through this one mapping."""
    assert tp >= 1 and nodes >= 1, (tp, nodes)
    per = -(-tp // nodes)
    return tuple(min(r // per, nodes - 1) for r in range(tp))


def make_run_policy(mesh, *, scan_layers: bool = False, remat: bool = False,
                    attn_q_block: int = 0, attn_kv_block: int = 0,
                    sequence_parallel: bool = False,
                    quantize_tp_collectives: bool = False,
                    kv_cache_quant: bool = False,
                    moe_impl: str = "dense") -> RunPolicy:
    from repro.models.transformer import set_policy_tp

    pol = RunPolicy(
        scan_layers=scan_layers,
        remat=remat,
        attn_q_block=attn_q_block,
        attn_kv_block=attn_kv_block,
        onehot_embed=mesh is not None and tp_size(mesh) > 1,
        constrain=make_constrain(mesh, sequence_parallel=sequence_parallel),
        quantize_tp_collectives=quantize_tp_collectives and mesh is not None,
        kv_cache_quant=kv_cache_quant,
        moe_impl=moe_impl,
        mesh=mesh,
    )
    return set_policy_tp(pol, tp_size(mesh) if mesh is not None else 1)
