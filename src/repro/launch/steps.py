"""Step-function factories for the dry-run: (fn, args, in_shardings) per cell.

Artifacts per run-shape kind (see DESIGN.md §Roofline for why two train
artifacts exist — XLA's HloCostAnalysis visits while bodies once, so FLOPs/
collectives are read from python-unrolled lowerings while the scan+remat
full step proves memory):

  train   -> 'train_memory' (scan+remat, full global batch, whole update)
             'micro_grads'  (one microbatch fwd+bwd, unrolled, remat)
             'opt_update'   (grad application)
  prefill -> 'prefill' (unrolled, block-causal attention)
  decode  -> 'decode'  (unrolled serve_step: 1 token, dense cache)
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, RunShape
from repro.launch.mesh import dp_axes, dp_size, tp_size
from repro.launch.sharding import (
    batch_spec,
    cache_specs_tree,
    make_run_policy,
    opt_specs,
    param_specs,
    stacked_param_specs,
    stacked_params_sds,
)
from repro.models import loss_fn, sync_replica_grads, grad_mask
from repro.models.cache import cache_specs
from repro.models.transformer import decode_step, forward, init_params_specs, prefill
from repro.optim import adamw_update
from repro.optim.schedule import warmup_cosine


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _token_sds(cfg: ArchConfig, B: int, S: int):
    if cfg.input_kind == "embeddings":
        return jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    return jax.ShapeDtypeStruct((B, S), jnp.int32)


def make_artifacts(cfg: ArchConfig, shape: RunShape, mesh,
                   *, dtype=jnp.bfloat16, attn_block: int = 4096,
                   sequence_parallel: bool = False,
                   mode: str = "full",
                   extra_policy: Optional[Dict[str, Any]] = None
                   ) -> Dict[str, Tuple[Callable, tuple, Any]]:
    """Returns {artifact: (fn, args_SDS, in_shardings)}.

    mode='full'  -> cost probes (unrolled) + memory artifacts (scan).
    mode='proof' -> memory/scan artifacts only (fast compile; used for the
                    multi-pod coherence pass).
    """
    tp = tp_size(mesh)
    dsz = dp_size(mesh)
    B, S = shape.global_batch, shape.seq_len
    pspec = param_specs(init_params_specs(cfg, dtype=dtype, tp=tp), mesh)
    params_sds = init_params_specs(cfg, dtype=dtype, tp=tp)

    blk = min(attn_block, S)
    pol_kw = dict(remat=False,
                  attn_q_block=blk if S > attn_block else 0,
                  attn_kv_block=blk if S > attn_block else 0,
                  sequence_parallel=sequence_parallel)
    if extra_policy:
        pol_kw.update(extra_policy)
    policy = make_run_policy(mesh, **pol_kw)

    out: Dict[str, Tuple[Callable, tuple, Any]] = {}

    if shape.kind == "train":
        micro = max(dsz, B // shape.grad_accum)
        micro = min(micro, B)
        accum = B // micro
        tok = _token_sds(cfg, micro, S)
        lab = jax.ShapeDtypeStruct((micro, S), jnp.int32)
        bspec = {"tokens": batch_spec(mesh, ndim=tok.ndim, batch_size=micro),
                 "labels": batch_spec(mesh, ndim=2, batch_size=micro)}

        def micro_grads(params, batch):
            pol = make_run_policy(mesh, remat=True, **{k: v for k, v in pol_kw.items()
                                                       if k != "remat"})
            (loss, _), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch, pol), has_aux=True)(params)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return loss, grads

        grads_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_sds)
        ospec = opt_specs(pspec, params_sds, mesh)
        gspec = ospec["m"]  # ZeRO grad sharding

        if mode == "full":
            out["micro_grads"] = (
                micro_grads,
                (params_sds, {"tokens": tok, "labels": lab}),
                (_named(mesh, pspec), _named(mesh, bspec)),
            )

        def opt_update(state, grads):
            lr = warmup_cosine(3e-4, 100, 10_000)(state["step"])
            grads = sync_replica_grads(cfg, grads, tp)
            m = grad_mask(cfg, state["params"], tp)
            grads = jax.tree.map(lambda g, mm: g * mm.astype(g.dtype), grads, m)
            p, o, gn = adamw_update(grads, state["opt"], state["params"], lr=lr)
            return {"params": p, "opt": o, "step": state["step"] + 1}

        opt_sds = {
            "m": grads_sds, "v": grads_sds,
            "master": grads_sds,
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        }
        state_sds = {"params": params_sds, "opt": opt_sds,
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
        state_spec = {"params": pspec, "opt": ospec, "step": P()}
        if mode == "full":
            out["opt_update"] = (
                opt_update,
                (state_sds, grads_sds),
                (_named(mesh, state_spec), _named(mesh, gspec)),
            )

        tok_full = _token_sds(cfg, B, S)
        lab_full = jax.ShapeDtypeStruct((B, S), jnp.int32)
        bspec_full = {"tokens": batch_spec(mesh, ndim=tok_full.ndim, batch_size=B),
                      "labels": batch_spec(mesh, ndim=2, batch_size=B)}

        # memory artifact: stacked-layer state (scan-bwd accumulates into
        # param-shaped buffers; ZeRO shards get an extra L-dim cut)
        homogeneous = not cfg.layer_pattern

        def train_memory(state, batch):
            pol = make_run_policy(mesh, scan_layers=homogeneous, remat=True,
                                  **{k: v for k, v in pol_kw.items() if k != "remat"})

            def one_micro(gacc, mb):
                (_, _), g = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, mb, pol), has_aux=True)(state["params"])
                gacc = jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32), gacc, g)
                gacc = jax.tree.map(
                    lambda a, sp: jax.lax.with_sharding_constraint(
                        a, NamedSharding(mesh, sp)), gacc, gspec_mem,
                )
                return gacc, None

            mb_tree = jax.tree.map(
                lambda x: x.reshape((accum, micro) + x.shape[1:]), batch)
            gacc0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                                 state["params"])
            grads, _ = jax.lax.scan(one_micro, gacc0, mb_tree)
            grads = jax.tree.map(lambda g: g / accum, grads)
            return opt_update(state, grads)

        if homogeneous:
            params_sds_m = stacked_params_sds(params_sds)
            pspec_m = stacked_param_specs(pspec)
        else:
            params_sds_m, pspec_m = params_sds, pspec
        ospec_m = opt_specs(pspec_m, params_sds_m, mesh)
        gspec_mem = ospec_m["m"]
        grads_sds_m = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params_sds_m)
        opt_sds_m = {"m": grads_sds_m, "v": grads_sds_m, "master": grads_sds_m,
                     "count": jax.ShapeDtypeStruct((), jnp.int32)}
        state_sds_m = {"params": params_sds_m, "opt": opt_sds_m,
                       "step": jax.ShapeDtypeStruct((), jnp.int32)}
        state_spec_m = {"params": pspec_m, "opt": ospec_m, "step": P()}
        out["train_memory"] = (
            train_memory,
            (state_sds_m, {"tokens": tok_full, "labels": lab_full}),
            (_named(mesh, state_spec_m), _named(mesh, bspec_full)),
            _named(mesh, state_spec_m),  # out: scan ys must keep shardings
        )
        out["__meta__"] = {"accum": accum, "micro": micro}

    elif shape.kind == "prefill":
        tok = _token_sds(cfg, B, S)
        bspec = batch_spec(mesh, ndim=tok.ndim, batch_size=B)

        def prefill_fn(params, tokens):
            return prefill(cfg, params, tokens, policy)

        if mode == "full":
            out["prefill"] = (  # unrolled: the cost/collective probe
                prefill_fn,
                (params_sds, tok),
                (_named(mesh, pspec), NamedSharding(mesh, bspec)),
            )

        scan_pol = make_run_policy(mesh, scan_layers=True, **pol_kw)

        def prefill_mem_fn(params, tokens):
            return prefill(cfg, params, tokens, scan_pol)

        out_sds = jax.eval_shape(prefill_mem_fn, params_sds, tok)
        stacked_out = isinstance(out_sds[1], dict)
        lspec = P(bspec[0], None,
                  "model" if cfg.vocab_size % tp == 0 else None)
        cache_out_spec = cache_specs_tree(out_sds[1], mesh, B, stacked=stacked_out)
        out["prefill_memory"] = (  # scan: the memory verdict
            prefill_mem_fn,
            (params_sds, tok),
            (_named(mesh, pspec), NamedSharding(mesh, bspec)),
            (NamedSharding(mesh, lspec), _named(mesh, cache_out_spec)),
        )

    elif shape.kind == "decode":
        tok = _token_sds(cfg, B, 1)
        pos = jax.ShapeDtypeStruct((B,), jnp.int32)
        csds = cache_specs(cfg, B, S, tp=tp, dtype=dtype,
                           kv_quant=policy.kv_cache_quant)
        cspec = cache_specs_tree(csds, mesh, B)

        def decode_fn(params, cache, tokens, pos):
            return decode_step(cfg, params, tokens, pos, cache, policy)

        if mode == "full":
            out["decode"] = (  # unrolled: cost/collective probe
                decode_fn,
                (params_sds, csds, tok, pos),
                (_named(mesh, pspec), _named(mesh, cspec),
                 NamedSharding(mesh, batch_spec(mesh, ndim=tok.ndim, batch_size=B)),
                 NamedSharding(mesh, batch_spec(mesh, ndim=1, batch_size=B))),
            )

        kinds = set(cfg.layer_kinds())
        if len(kinds) == 1 and next(iter(kinds)) in ("attention", "rwkv6"):
            # scan + stacked params/cache: the memory verdict
            params_sds_d = stacked_params_sds(params_sds)
            pspec_d = stacked_param_specs(pspec)
            L = cfg.num_layers
            csds_d = jax.tree.map(
                lambda *xs: jax.ShapeDtypeStruct((L,) + xs[0].shape, xs[0].dtype),
                *csds)
            cspec_d = cache_specs_tree(csds_d, mesh, B, stacked=True)
            scan_pol = make_run_policy(mesh, scan_layers=True, **pol_kw)

            def decode_mem_fn(params, cache, tokens, pos):
                return decode_step(cfg, params, tokens, pos, cache, scan_pol)

            lspec_d = P(batch_spec(mesh, ndim=1, batch_size=B)[0], None,
                        "model" if cfg.vocab_size % tp == 0 else None)
            out["decode_memory"] = (
                decode_mem_fn,
                (params_sds_d, csds_d, tok, pos),
                (_named(mesh, pspec_d), _named(mesh, cspec_d),
                 NamedSharding(mesh, batch_spec(mesh, ndim=tok.ndim, batch_size=B)),
                 NamedSharding(mesh, batch_spec(mesh, ndim=1, batch_size=B))),
                (NamedSharding(mesh, lspec_d), _named(mesh, cspec_d)),
            )
    else:
        raise ValueError(shape.kind)
    return out
