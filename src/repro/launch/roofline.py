"""Roofline analysis from dry-run JSON records (assignment §Roofline).

Per (arch x shape x mesh), from the compiled artifacts:
  compute term    = HLO_FLOPs / (chips * 197 TFLOP/s)
  memory term     = HLO_bytes / (chips * 819 GB/s)
  collective term = collective_bytes / (chips * 50 GB/s)
cost_analysis() is per-partition, so per-device terms divide by one chip's
peak. Train cells combine accum x micro_grads + opt_update (the unrolled cost
probes — XLA's HloCostAnalysis visits while bodies once, so the scan-based
train_memory artifact is only used for the memory verdict).

roofline_fraction = compute_term / max(all three): the fraction of peak FLOPs
reachable under the binding resource (1.0 = compute-bound). mfu_bound =
(MODEL_FLOPS/chips/peak) / max(all three): the hard MFU ceiling counting only
*useful* model FLOPs — the §Perf score.

Usage: python -m repro.launch.roofline [--dir experiments/dryrun] [--tag baseline]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 1024**3


def cell_terms(rec: Dict) -> Optional[Dict]:
    """Combine artifacts into per-device roofline terms (seconds)."""
    if "skipped" in rec:
        return None
    arts = rec["artifacts"]
    accum = rec.get("meta", {}).get("accum", 1)

    def probe(*names):
        return [arts[n] for n in names if n in arts]

    if "micro_grads" in arts:  # train cell
        f = accum * arts["micro_grads"]["cost"]["flops"] \
            + arts.get("opt_update", {}).get("cost", {}).get("flops", 0.0)
        b = accum * arts["micro_grads"]["cost"]["bytes_accessed"] \
            + arts.get("opt_update", {}).get("cost", {}).get("bytes_accessed", 0.0)
        w = accum * arts["micro_grads"]["collectives"]["wire_bytes"] \
            + arts.get("opt_update", {}).get("collectives", {}).get("wire_bytes", 0.0)
        mem_art = "train_memory"
    elif "prefill" in arts:
        f = arts["prefill"]["cost"]["flops"]
        b = arts["prefill"]["cost"]["bytes_accessed"]
        w = arts["prefill"]["collectives"]["wire_bytes"]
        mem_art = "prefill_memory" if "prefill_memory" in arts else "prefill"
    elif "decode" in arts or "decode_memory" in arts:
        probe_name = "decode" if "decode" in arts else "decode_memory"
        f = arts[probe_name]["cost"]["flops"]
        b = arts[probe_name]["cost"]["bytes_accessed"]
        w = arts[probe_name]["collectives"]["wire_bytes"]
        mem_art = "decode_memory" if "decode_memory" in arts else "decode"
    elif "train_memory" in arts:  # cost probe missing (compile budget):
        # analytic fallback — 8*N_active*D/6 per MODEL_FLOPS (remat fwd x2),
        # bytes/wire from the scan artifact x accum x layer-count correction
        L = max(1, rec.get("meta", {}).get("layers", 0)) or 1
        f = rec["model_flops_global"] / rec["chips"] * (8.0 / 6.0)
        b = accum * arts["train_memory"]["cost"]["bytes_accessed"]
        w = accum * arts["train_memory"]["collectives"]["wire_bytes"]
        mem_art = "train_memory"
    elif "prefill_memory" in arts:
        f = b = w = 0.0
        mem_art = "prefill_memory"
    else:
        return None

    t_c = f / PEAK_FLOPS
    t_m = b / HBM_BW
    t_w = w / ICI_BW
    bound = max(t_c, t_m, t_w)
    proof_only = (f == 0.0 and b == 0.0 and w == 0.0)
    if bound <= 0:
        bound, dominant = 1.0, "n/a"
    elif bound == t_m:
        dominant = "memory"
    elif bound == t_c:
        dominant = "compute"
    else:
        dominant = "collective"
    chips = rec["chips"]
    mf_dev = rec["model_flops_global"] / chips
    peak_mem = arts[mem_art]["memory"]["peak_bytes_est"] if mem_art in arts else 0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "flops_dev": f, "bytes_dev": b, "wire_dev": w,
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_w,
        "dominant": dominant,
        "roofline_fraction": 0.0 if proof_only else (t_c / bound),
        "model_flops_dev": mf_dev,
        "useful_ratio": (mf_dev / f) if f else 0.0,
        "mfu_bound": 0.0 if proof_only else (mf_dev / PEAK_FLOPS) / bound,
        "peak_mem_gib": peak_mem / 2**30,
        "fits": peak_mem < HBM_PER_CHIP,
        "mem_artifact": mem_art,
    }


def load(dir_: str, tag: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dir_, tag, "*.json"))):
        rec = json.load(open(f))
        t = cell_terms(rec)
        if t is not None:
            out.append(t)
        elif "skipped" in rec:
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "skipped": rec["skipped"]})
    return out


def fmt_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | t_compute (s) | t_memory (s) | t_coll (s) | "
           "dominant | roofline-frac | useful-ratio | MFU-bound | peak mem | fits |")
    sep = "|" + "---|" * 12
    lines = [hdr, sep]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— skipped: {r['skipped']} |" + " |" * 8)
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} | {r['t_collective']:.3e} "
            f"| {r['dominant']} | {r['roofline_fraction']:.2f} "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} "
            f"| {r['peak_mem_gib']:.1f} GiB | {'Y' if r['fits'] else 'N'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load(args.dir, args.tag)
    print(fmt_table(rows))
    if args.json_out:
        json.dump(rows, open(args.json_out, "w"), indent=1)


if __name__ == "__main__":
    main()
