"""Training launcher: --arch <id> on the local device set (or a fake mesh).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 50 --batch 4 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataLoader, SyntheticLM
from repro.launch.mesh import dp_size, make_host_mesh, tp_size
from repro.launch.sharding import make_run_policy, param_specs
from repro.launch.steps import _named
from repro.models import init_params
from repro.runtime import FailureInjector
from repro.train import Trainer, TrainerConfig, make_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="data-parallel axis")
    ap.add_argument("--model", type=int, default=1, help="tensor-parallel axis")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    n_dev = args.data * args.model
    assert n_dev <= len(jax.devices()), (n_dev, len(jax.devices()))

    mesh = make_host_mesh(data=args.data, model=args.model) if n_dev > 1 else None
    tp = args.model
    params = init_params(cfg, jax.random.PRNGKey(args.seed), dtype=jnp.float32,
                         tp=tp)
    if mesh is not None:
        params = jax.device_put(params, _named(mesh, param_specs(params, mesh)))
        policy = make_run_policy(mesh, remat=True)
    else:
        from repro.models.layers import RunPolicy
        from repro.models.transformer import set_policy_tp
        policy = set_policy_tp(RunPolicy(remat=True), 1)

    state = make_train_state(cfg, params)
    tc = TrainerConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
                       grad_accum=args.accum, tp=tp,
                       compress_grads=args.compress_grads)
    step = jax.jit(make_train_step(cfg, policy, tc))
    if mesh is not None:
        _step = step

        def step(s, b):
            with mesh:
                return _step(s, b)

    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                     global_batch=args.batch, seed=args.seed,
                     emb_dim=cfg.d_model if cfg.input_kind == "embeddings" else 0)
    loader = DataLoader(ds)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    injector = FailureInjector.at(args.fail_at) if args.fail_at else None
    trainer = Trainer(cfg, state, step, loader, ckpt=ckpt,
                      injector=injector, ckpt_every=args.ckpt_every)
    out = trainer.run(args.steps)
    loader.close()
    losses = [h["loss"] for h in out["history"]]
    print(f"arch={args.arch} steps={len(losses)} restarts={out['restarts']} "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f} "
          f"mean_dt={np.mean([h['dt'] for h in out['history']]):.3f}s")


if __name__ == "__main__":
    main()
