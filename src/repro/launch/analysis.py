"""Compiled-artifact analysis: cost/memory extraction + HLO collective parsing.

Wire-byte model per device (ring algorithms, n = collective group size):
  all-reduce       2*(n-1)/n * bytes
  all-gather       (n-1)/n   * output bytes
  reduce-scatter   (n-1)     * output (shard) bytes
  all-to-all       (n-1)/n   * bytes
  collective-permute         bytes
Async *-start ops are counted; *-done are skipped (same transfer).
"""
from __future__ import annotations

import re
from typing import Any, Dict

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"= (?P<outs>.+?) (?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?(?:\.\d+)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(?P<dims>[0-9,]+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{(?P<first>[0-9,]+)\}")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m or m.group("dt") not in _DTYPE_BYTES:
        return 0
    dims = m.group("dims")
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[m.group("dt")]


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective bytes (tensor and wire) by op type."""
    out: Dict[str, Dict[str, float]] = {}
    total_wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done(" in line:
            continue
        op = m.group("op")
        nbytes = sum(_shape_bytes(s.group(0))
                     for s in _SHAPE_RE.finditer(m.group("outs")))
        gi = _GROUPS_IOTA_RE.search(line)
        if gi:
            dims = [int(x) for x in gi.group("dims").split(",")]
            n = dims[-1] if len(dims) > 1 else dims[0]
        else:
            gl = _GROUPS_LIST_RE.search(line)
            n = len(gl.group("first").split(",")) if gl else 2
        n = max(2, n)
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * nbytes
        elif op == "all-gather":
            wire = (n - 1) / n * nbytes
        elif op == "reduce-scatter":
            wire = float(n - 1) * nbytes
        elif op == "all-to-all":
            wire = (n - 1) / n * nbytes
        else:  # collective-permute
            wire = float(nbytes)
        d = out.setdefault(op, {"count": 0, "tensor_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += 1
        d["tensor_bytes"] += nbytes
        d["wire_bytes"] += wire
        total_wire += wire
    return {"by_op": out, "wire_bytes": total_wire}


def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    ca = dict(ca or {})
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    m = compiled.memory_analysis()
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes")
    out = {f: float(getattr(m, f, 0)) for f in fields}
    out["peak_bytes_est"] = (out["argument_size_in_bytes"]
                             + out["temp_size_in_bytes"]
                             + out["output_size_in_bytes"]
                             - out["alias_size_in_bytes"])
    return out


# ---------------------------------------------------------------------------
# Analytic FLOPs (the MODEL_FLOPS term; cross-checks the HLO count)
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    """6*N_active*D for train, 2*N_active*D for serve (+ attention terms)."""
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    tokens = B * S
    # attention context flops (per token: 2*2*ctx*H*hd fwd)
    attn = 0.0
    if cfg.mixer != "rwkv6":
        kinds = cfg.layer_kinds()
        for k in kinds:
            if k == "attention":
                ctx = S / 2
            elif k == "local":
                ctx = min(cfg.local_window, S / 2)
            else:
                continue
            attn += 4.0 * tokens * ctx * cfg.num_heads * cfg.head_dim
    if shape.kind == "train":
        return 6.0 * n_active * tokens + 3.0 * attn
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens + attn
    # decode: one token per sequence; context = full cache
    dec_tokens = B
    attn_dec = 0.0
    if cfg.mixer != "rwkv6":
        for k in cfg.layer_kinds():
            ctx = S if k == "attention" else min(cfg.local_window, S)
            if k in ("attention", "local"):
                attn_dec += 4.0 * dec_tokens * ctx * cfg.num_heads * cfg.head_dim
    return 2.0 * n_active * dec_tokens + attn_dec
