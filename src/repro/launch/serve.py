"""Serving launcher: batched paged-KV serving of an --arch model.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --requests 4 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import UnifiedMemory, TPU_V5E
from repro.models import init_params
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--umem", action="store_true",
                    help="track the KV pool in the unified-memory runtime")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    assert cfg.mixer == "attention", "paged serving targets attention archs"
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    um = UnifiedMemory(hw=TPU_V5E) if args.umem else None
    eng = ServeEngine(cfg, params, max_seqs=max(4, args.requests),
                      max_len=args.max_len, page_size=args.page_size, um=um)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = max(2, args.prompt_len + int(rng.integers(-4, 5)))
        eng.add_request(rng.integers(2, cfg.vocab_size, plen), args.max_new)
    t0 = time.perf_counter()
    out = eng.run_to_completion()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(v) for v in out.values())
    print(f"arch={args.arch} requests={len(out)} tokens={total_tokens} "
          f"wall={dt:.2f}s tok/s={total_tokens/dt:.1f}")
    for rid, toks in sorted(out.items()):
        print(f"  req {rid}: {toks}")
    if um is not None:
        rep = um.report()
        print("umem:", rep["traffic_total"])


if __name__ == "__main__":
    main()
