import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["XLA_FLAGS"] += " --xla_llvm_disable_expensive_passes=true"  # codegen speed: dry-run never executes

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices stand in for 2 pods x 256 chips. For each cell the artifacts of
launch/steps.py are lowered with explicit in_shardings, compiled, and the
compiled module's memory_analysis / cost_analysis / collective schedule are
recorded to JSON (read by launch/roofline.py and EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
  python -m repro.launch.dryrun --all --both-meshes
Perf-variant knobs (hillclimbing): --attn-block, --seqpar, --tag.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config
from repro.launch.analysis import cost_summary, memory_summary, model_flops, parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_artifacts

HBM_PER_CHIP = 16 * 1024**3  # v5e


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             attn_block: int = 4096, seqpar: bool = False, tag: str = "baseline",
             artifacts=None, force: bool = False, verbose: bool = True,
             extra_policy=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    os.makedirs(os.path.join(out_dir, tag), exist_ok=True)
    base = f"{arch}__{shape_name}__{mesh_name}"
    path = os.path.join(out_dir, tag, base + ".json")
    if os.path.exists(path) and not force and not artifacts:
        if verbose:
            print(f"[skip] {base} (exists)")
        return json.load(open(path))

    if shape.sub_quadratic_only and not cfg.sub_quadratic:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": "full-attention arch at 500k ctx (see DESIGN.md)"}
        json.dump(rec, open(path, "w"), indent=1)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    # single-pod: full artifacts (incl. unrolled cost probes -> roofline table)
    # multi-pod:  proof artifacts (scan lowerings; sharding coherence + memory)
    mode = "proof" if multi_pod else "full"
    arts = make_artifacts(cfg, shape, mesh, attn_block=attn_block,
                          sequence_parallel=seqpar, mode=mode,
                          extra_policy=extra_policy)
    meta = arts.pop("__meta__", {})
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "chips": mesh.size, "meta": meta,
           "model_flops_global": model_flops(cfg, shape),
           "params": cfg.param_count(), "active_params": cfg.active_param_count(),
           "artifacts": {}}
    if os.path.exists(path) and artifacts:  # merge partial redo into record
        rec = json.load(open(path))
        rec["artifacts"] = rec.get("artifacts", {})
    for name, entry in arts.items():
        fn, args, in_sh = entry[:3]
        out_sh = entry[3] if len(entry) > 3 else None
        if artifacts and name not in artifacts:
            continue
        if name in rec["artifacts"] and not force:
            continue  # merged partial redo: keep existing artifact
        t0 = time.time()
        # realistic aliasing: the trainer donates its state, serving donates
        # the KV cache (in-place update)
        donate = {"train_memory": (0,), "opt_update": (0,), "decode": (1,),
                  "decode_memory": (1,)}.get(name, ())
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = memory_summary(compiled)
        cost = cost_summary(compiled)
        coll = parse_collectives(compiled.as_text())
        rec["artifacts"][name] = {
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": mem,
            "cost": cost,
            "collectives": coll,
        }
        if verbose:
            print(f"[ok] {base}/{name}: compile={t_compile:.1f}s "
                  f"flops/dev={cost['flops']:.3e} bytes/dev={cost['bytes_accessed']:.3e} "
                  f"wire/dev={coll['wire_bytes']:.3e} "
                  f"peak_mem={mem['peak_bytes_est']/2**30:.2f}GiB "
                  f"({'FITS' if mem['peak_bytes_est'] < HBM_PER_CHIP else 'OVER'})")
            print(f"     memory_analysis: {compiled.memory_analysis()}")
    json.dump(rec, open(path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--attn-block", type=int, default=4096)
    ap.add_argument("--seqpar", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--q8-collectives", action="store_true")
    ap.add_argument("--moe-sorted", action="store_true")
    ap.add_argument("--artifacts", nargs="*", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    todo = []
    if args.all:
        for arch, shape_name, _live in cells(include_skipped=True):
            todo.append((arch, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo.append((args.arch, args.shape))

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = []
    for arch, shape_name in todo:
        for mp in meshes:
            try:
                extra = {}
                if args.kv_int8:
                    extra["kv_cache_quant"] = True
                if args.q8_collectives:
                    extra["quantize_tp_collectives"] = True
                if args.moe_sorted:
                    extra["moe_impl"] = "sorted"
                run_cell(arch, shape_name, multi_pod=mp, out_dir=args.out,
                         attn_block=args.attn_block, seqpar=args.seqpar,
                         tag=args.tag, artifacts=args.artifacts, force=args.force,
                         extra_policy=extra or None)
            except Exception:
                failures.append((arch, shape_name, mp))
                print(f"[FAIL] {arch}/{shape_name}/mp={mp}")
                traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
