"""Production mesh builders.

make_production_mesh is a FUNCTION (not a module-level constant) so importing
this module never touches jax device state. Single-pod: 256 chips (16, 16)
('data', 'model'); multi-pod: 2 pods x 256 = 512 chips ('pod', 'data',
'model') — the pod axis is an extra data-parallel dimension whose gradient
reduction crosses the inter-pod (DCN/ICI) boundary.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4, pod: int = 0):
    """Small mesh over host (fake or real CPU) devices, for tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """Data-parallel axis names of a mesh (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def tp_size(mesh) -> int:
    return mesh.shape["model"]
