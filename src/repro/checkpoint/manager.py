"""Checkpointing: atomic, async, logically-addressed (elastic-reshardable).

Tensors are stored *logically* (full arrays, path-keyed npz) with a JSON
manifest, so a checkpoint written on one mesh restores onto any other mesh —
the restore path device_puts each tensor with the target sharding. Writes go
to a tmp dir + os.replace (atomic on POSIX); an async writer thread keeps the
training loop off the I/O path with single-slot backpressure; `keep_last`
prunes old steps after a successful commit.

Integrity: the manifest records a crc32 per tensor at save time; restore
recomputes and rejects any mismatch (or a truncated/unreadable archive,
or a shape/dtype drift) with :class:`CheckpointCorruptError` — a corrupted
checkpoint must fail loudly at restore, never resume training on garbage.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zipfile
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """The checkpoint on disk fails its integrity checks (truncated
    archive, missing tensor, shape/dtype drift, or crc32 mismatch)."""


def _crc32(v) -> int:
    return zlib.crc32(np.ascontiguousarray(v).tobytes()) & 0xFFFFFFFF


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}{k}/")
                for k in template}
    if isinstance(template, (list, tuple)):
        seq = [_unflatten_into(v, flat, f"{prefix}{i}/") for i, v in enumerate(template)]
        return type(template)(seq)
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._inflight: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, um=None, drain=()) -> None:
        """Snapshot ``state`` (async unless configured otherwise).

        For UM-backed state pass the runtime as ``um`` and the durable
        buffers/views as ``drain``: the save then behaves as a memory
        pressure event at the step boundary — ``um.sync()`` first (policy-
        deferred migrations land before the snapshot is consistent), then
        the dirty device-resident runs of ``drain`` charge their d2h
        writeback via :meth:`~repro.core.umem.UnifiedMemory.drain_dirty`.
        The drain moves no pages and clears no dirty bits, so a save mid-
        oversubscription neither leaks residency nor perturbs any later
        step's charges."""
        if um is not None:
            um.sync()
            um.drain_dirty(drain)
        host = jax.tree.map(lambda a: np.asarray(a), state,
                            is_leaf=lambda x: hasattr(x, "shape"))
        self.wait()
        if self.async_save:
            self._inflight = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._inflight.start()
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _write(self, step: int, host_state) -> None:
        flat = _flatten(host_state)
        tmp = os.path.join(self.dir, f".tmp-{step}")
        final = os.path.join(self.dir, f"step-{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "tensors.npz"),
                 **{k: v for k, v in flat.items()})
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(np.shape(v)),
                         "dtype": str(np.asarray(v).dtype),
                         "crc32": _crc32(v)} for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic commit
        self._prune()

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:09d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step-"):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None, shardings=None):
        """Restore into `template`'s structure; device_put with `shardings`
        (a matching tree of jax.sharding.Sharding) for elastic remesh."""
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        path = os.path.join(self.dir, f"step-{step:09d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(path, "tensors.npz")) as z:
                flat = {k: z[k] for k in z.files}
        except (OSError, ValueError, json.JSONDecodeError,
                zipfile.BadZipFile, zlib.error) as e:
            raise CheckpointCorruptError(
                f"checkpoint step {step} unreadable: {e}") from e
        for k, meta in manifest.get("keys", {}).items():
            if k not in flat:
                raise CheckpointCorruptError(
                    f"checkpoint step {step}: tensor {k!r} missing from "
                    "archive")
            v = flat[k]
            if (list(np.shape(v)) != list(meta["shape"])
                    or str(v.dtype) != meta["dtype"]):
                raise CheckpointCorruptError(
                    f"checkpoint step {step}: tensor {k!r} is "
                    f"{v.dtype}{np.shape(v)}, manifest says "
                    f"{meta['dtype']}{tuple(meta['shape'])}")
            # crc32 absent = checkpoint from an older writer: shape/dtype
            # checks still apply, content check is skipped
            if "crc32" in meta and _crc32(v) != meta["crc32"]:
                raise CheckpointCorruptError(
                    f"checkpoint step {step}: tensor {k!r} fails its crc32 "
                    "content check — the file was corrupted after save")
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
        return step, tree
