"""Typed buffers over UnifiedMemory allocations: the application front-end.

The runtime's native currency is the raw byte range ``(Allocation, lo, hi)``.
Applications should never hand-write those: a :class:`UMBuffer` knows its
shape/dtype/itemsize and maps numpy-style expressions to byte extents —

    buf[i:j]          leading-axis slice (elements for 1-D, rows for N-D)
    buf.rows(lo, hi)  explicit 2-D row band
    buf[:]            the whole buffer
    buf.byterange(lo, hi)  escape hatch for byte-granular extents

— each returning a :class:`BufferView` that ``UnifiedMemory.launch`` (and
``prefetch``/``prefetch_async``/``demote``) resolves to the exact byte math
the raw API used, so modeled charges are bit-identical.

A buffer created with ``um.from_host`` additionally carries a host *staging*
allocation under the explicit policy (the cudaMalloc + malloc pair): a
CPU-actor launch lands in the staging buffer, a GPU-actor launch in the
device buffer, and ``um.staged(...)`` charges the h2d/d2h copies at phase
boundaries. Under managed/system policies the staging allocation does not
exist and the same application code path exercises first-touch, fault, and
access-counter behavior — the paper's "one code path, three policies" story.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

from repro.core.pagetable import Actor

__all__ = ["UMBuffer", "BufferView", "as_view"]


class BufferView:
    """A byte extent [lo, hi) of a :class:`UMBuffer`.

    Views are what launch/prefetch/demote consume; ``resolve(actor)`` lowers
    the view to the runtime's raw ``(Allocation, lo, hi)`` range, picking the
    host staging allocation for CPU actors of staged (explicit) buffers."""

    __slots__ = ("buf", "lo", "hi")

    def __init__(self, buf: "UMBuffer", lo: int, hi: int):
        assert 0 <= lo <= hi <= buf.nbytes, (buf.name, lo, hi, buf.nbytes)
        self.buf = buf
        self.lo = lo
        self.hi = hi

    @property
    def nbytes(self) -> int:
        return self.hi - self.lo

    def resolve(self, actor: Actor = Actor.GPU):
        """Lower to the runtime Range: (Allocation, lo_byte, hi_byte).
        Routing is the policy's ``resolve_actor_side`` hook — the explicit
        backend sends CPU actors to the malloc'd staging side of the pair."""
        return self.buf.policy.resolve_actor_side(self, actor)

    def page_extent(self) -> Tuple[int, int]:
        """The [lo_page, hi_page) extent this view resolves to (paged
        allocations only) — what kernel() operates on."""
        table = self.buf.alloc.table
        assert table is not None, "explicit allocations have no page table"
        return table.page_range(self.lo, self.hi)

    def page_runs(self, tier=None):
        """The view's extent resolved against the run-compressed page table:
        (starts, ends, tiers) of the tier runs it overlaps, or just
        (starts, ends) of the sub-runs in `tier` when one is given. O(runs
        overlapping the view), never O(pages) — the introspection twin of
        what kernel() does internally."""
        table = self.buf.alloc.table
        assert table is not None, "explicit allocations have no page table"
        p0, p1 = table.page_range(self.lo, self.hi)
        if tier is None:
            return table.tier_runs(p0, p1)
        return table.runs_of(tier, p0, p1)

    def __repr__(self) -> str:
        return f"BufferView({self.buf.name!r}, [{self.lo}, {self.hi}))"


class UMBuffer:
    """A shaped, typed view over one UnifiedMemory allocation (plus an
    optional explicit-policy host staging allocation). Built via
    ``UnifiedMemory.array`` / ``UnifiedMemory.from_host``."""

    def __init__(self, um, alloc, shape, dtype, host=None):
        self.um = um
        self.alloc = alloc
        self.host = host  # explicit policy: the malloc'd staging buffer
        self.shape: Tuple[int, ...] = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        self.itemsize = self.dtype.itemsize
        self.nbytes = int(math.prod(self.shape)) * self.itemsize
        assert self.nbytes == alloc.nbytes, \
            f"{alloc.name}: shape {self.shape} x {self.dtype} != {alloc.nbytes}B"
        # bytes per leading-axis element (the slice unit): itemsize for 1-D,
        # a full row for N-D
        self.row_bytes = (int(math.prod(self.shape[1:])) * self.itemsize
                          if len(self.shape) > 1 else self.itemsize)

    # ----------------------------------------------------------- properties
    @property
    def name(self) -> str:
        return self.alloc.name

    @property
    def policy(self):
        return self.alloc.policy

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))

    def __len__(self) -> int:
        return self.shape[0]

    # -------------------------------------------------------------- slicing
    def __getitem__(self, key) -> BufferView:
        if key is Ellipsis:
            return BufferView(self, 0, self.nbytes)
        if isinstance(key, (int, np.integer)):
            i = int(key)
            if i < 0:
                i += self.shape[0]
            if not 0 <= i < self.shape[0]:
                raise IndexError(f"{self.name}[{key}]: axis-0 size {self.shape[0]}")
            return BufferView(self, i * self.row_bytes, (i + 1) * self.row_bytes)
        if isinstance(key, slice):
            if key.step not in (None, 1):
                raise ValueError(
                    f"{self.name}[{key}]: UMBuffer slices must be contiguous "
                    "(step 1) — strided extents are not a page-range")
            lo, hi, _ = key.indices(self.shape[0])
            hi = max(lo, hi)
            return BufferView(self, lo * self.row_bytes, hi * self.row_bytes)
        raise TypeError(f"{self.name}[{key!r}]: index with an int, a step-1 "
                        "slice, or ... (leading axis only)")

    def rows(self, lo: int, hi: int) -> BufferView:
        """Row band [lo, hi) of a 2-D (or N-D) buffer as one extent."""
        assert len(self.shape) >= 2, f"{self.name}: rows() needs an N-D buffer"
        assert 0 <= lo <= hi <= self.shape[0], (lo, hi, self.shape)
        return BufferView(self, lo * self.row_bytes, hi * self.row_bytes)

    def byterange(self, lo: int, hi: int) -> BufferView:
        """Raw byte extent [lo, hi) — for access patterns computed in byte
        space (e.g. page-aligned streaming windows). Prefer element slices."""
        return BufferView(self, lo, hi)

    # ------------------------------------------------------------ lifecycle
    def free(self) -> None:
        """Free the allocation (and its staging pair, in allocation order)."""
        self.um.free(self.alloc)
        if self.host is not None and not self.host.freed:
            self.um.free(self.host)

    @property
    def freed(self) -> bool:
        return self.alloc.freed

    def __repr__(self) -> str:
        kind = self.policy.kind + ("+staged" if self.host is not None else "")
        return f"UMBuffer({self.name!r}, {self.shape}, {self.dtype}, {kind})"


def as_view(obj, *, whole_ok: bool = True) -> BufferView:
    """Coerce launch/staged arguments: a BufferView passes through, a
    UMBuffer means its whole extent."""
    if isinstance(obj, BufferView):
        return obj
    if isinstance(obj, UMBuffer) and whole_ok:
        return BufferView(obj, 0, obj.nbytes)
    raise TypeError(f"expected UMBuffer or BufferView, got {type(obj).__name__}")
