"""Trace capture/replay for the unified-memory runtime.

Recording hooks the raw runtime surface — the post-resolution stream of
alloc/free/kernel/kernel_batch/sync/copy/prefetch/demote/phase events that
every app and benchmark ultimately lowers onto — and writes one compact
JSONL event per op (gzip when the path ends in ``.gz``). Replay re-drives
the stream through a fresh :class:`~repro.core.umem.UnifiedMemory`, so a
recorded application can be re-charged under any registered policy or
hardware backend *without re-running the application math* (the Khalilov
et al. trace-replay methodology): the modeled clock, phase times and
traffic counters come out of ``um.prof`` exactly as a live run's would.

Identity guarantees:

* ``replay(path)`` with no overrides reproduces the recorded run's charges
  bit-for-bit: the stream is recorded after buffer-view resolution, and
  every charge in the runtime is a pure function of (hardware, policy,
  op stream).
* ``replay(path, policy=...)`` rebuilds every application allocation under
  the named backend (harness-reserved ``__``-prefixed allocations keep
  their recorded policy) and matches a native run of the same app under
  that backend whenever the app's op stream is policy-independent — true
  for all directly-CPU-accessible backends (system/managed/mi300a_unified),
  which share one resolution path and skip ``um.staged()`` copies alike.

Recording starts at attach time: allocations already live when the
recorder attaches are re-emitted as alloc events (their tables must still
be untouched — ``record_app`` attaches inside ``make_um``, before the app
touches anything, so only the pristine oversubscription ballast predates
the stream).
"""
from __future__ import annotations

import contextlib
import dataclasses
import gzip
import json
from typing import Dict, List, Optional, Sequence

from repro.core.pagetable import Actor
from repro.core.policy import Allocation
from repro.core.registry import get_hardware, make_policy
from repro.core.umem import UnifiedMemory

# v2 adds the optional per-kernel node pin ("nd" on kernel events, a sixth
# element on batch items) for node-aware cluster backends; single-node
# streams serialize identically to v1 apart from the header version, and
# replay accepts both versions (missing node info defaults to node 0).
TRACE_VERSION = 2


def _open_w(path):
    path = str(path)
    if path.endswith(".gz"):
        return gzip.open(path, "wt", encoding="utf-8")
    return open(path, "w", encoding="utf-8")


def _open_r(path):
    path = str(path)
    if path.endswith(".gz"):
        return gzip.open(path, "rt", encoding="utf-8")
    return open(path, "r", encoding="utf-8")


class TraceRecorder:
    """Serializes runtime events; installed as ``um._trace`` by attach()."""

    def __init__(self, path, header: Dict[str, object]):
        self._f = _open_w(path)
        self._um: Optional[UnifiedMemory] = None
        self._write(dict({"t": "hdr", "v": TRACE_VERSION}, **header))

    def _write(self, ev: Dict[str, object]) -> None:
        self._f.write(json.dumps(ev, separators=(",", ":")) + "\n")

    @staticmethod
    def _ranges(ranges: Sequence) -> List[List]:
        return [[a.name, int(lo), int(hi)] for a, lo, hi in ranges]

    # ------------------------------------------------------- event callbacks
    def on_alloc(self, a: Allocation) -> None:
        self._write({"t": "a", "n": a.name, "b": int(a.nbytes),
                     "p": a.policy.kind, "c": dataclasses.asdict(a.policy)})

    def on_free(self, name: str) -> None:
        self._write({"t": "f", "n": name})

    def on_kernel(self, name, reads, writes, flops, actor, node=0) -> None:
        ev = {"t": "k", "n": name, "r": self._ranges(reads),
              "w": self._ranges(writes), "fl": float(flops),
              "ac": int(actor)}
        if node:
            ev["nd"] = int(node)
        self._write(ev)

    def on_batch(self, items: Sequence) -> None:
        self._write({"t": "kb", "it": [
            [nm, self._ranges(r), self._ranges(w), float(fl), int(ac)]
            if not nd else
            [nm, self._ranges(r), self._ranges(w), float(fl), int(ac),
             int(nd)]
            for nm, r, w, fl, ac, nd in items]})

    def on_sync(self) -> None:
        self._write({"t": "s"})

    def on_copy(self, name, lo, hi, direction) -> None:
        self._write({"t": "c", "n": name, "lo": int(lo), "hi": int(hi),
                     "d": direction})

    def on_prefetch(self, name, lo, hi, overlap) -> None:
        self._write({"t": "pf", "n": name, "lo": int(lo), "hi": int(hi),
                     "ov": bool(overlap)})

    def on_demote(self, name, lo, hi) -> None:
        self._write({"t": "dm", "n": name, "lo": int(lo), "hi": int(hi)})

    def on_phase(self, name: str) -> None:
        self._write({"t": "ph", "n": name})

    def close(self) -> None:
        if self._um is not None and self._um._trace is self:
            self._um._trace = None
        self._um = None
        self._f.close()


def attach(um: UnifiedMemory, path, **meta) -> TraceRecorder:
    """Start recording ``um``'s stream to ``path``. Pre-existing live
    allocations are re-emitted as alloc events so replay rebuilds them
    (they must not have been touched yet). Call ``close()`` (or use the
    :func:`record` context manager) to detach and flush."""
    assert um._trace is None, "a recorder is already attached"
    rec = TraceRecorder(path, {"hw": um.hw.name,
                               "sps": um.staging_page_size, **meta})
    for a in um.allocs.values():
        if not a.freed:
            rec.on_alloc(a)
    rec._um = um
    um._trace = rec
    return rec


@contextlib.contextmanager
def record(um: UnifiedMemory, path, **meta):
    """Record every runtime op issued inside the block to ``path``."""
    rec = attach(um, path, **meta)
    try:
        yield rec
    finally:
        rec.close()


def record_app(app: str, policy_kind: str, path, **kw):
    """Run the registered app under ``policy_kind`` with the runtime stream
    recorded to ``path``. Returns the app's AppResult; the trace replays to
    the same charges via :func:`replay`."""
    from repro.apps import APPS
    from repro.apps.common import add_um_hook, remove_um_hook

    recs: List[TraceRecorder] = []

    def hook(um):
        if not recs:  # first (and, for every current app, only) runtime
            recs.append(attach(um, path, app=app, policy=policy_kind))

    add_um_hook(hook)
    try:
        result = APPS[app].run(policy_kind, **kw)
    finally:
        remove_um_hook(hook)
        for rec in recs:
            rec.close()
    assert recs, f"app {app!r} never built a UnifiedMemory"
    return result


def _rebuild_policy(ev: Dict[str, object], override: Optional[str]):
    """The recorded policy (kind + full config), or the override backend
    built at the recorded paging/migration knobs. Harness-reserved ``__``
    allocations (e.g. the oversubscription ballast) always keep their
    recorded policy — the override targets application memory only."""
    cfg = dict(ev["c"])
    if override is None or str(ev["n"]).startswith("__"):
        return dataclasses.replace(make_policy(str(ev["p"])), **cfg)
    return make_policy(
        override,
        page_size=cfg["page_size"],
        threshold=cfg["counter_threshold"],
        auto_migrate=cfg["auto_migrate"],
        speculative_prefetch=cfg["speculative_prefetch"],
        max_migration_bytes_per_sync=cfg["max_migration_bytes_per_sync"])


def replay(path, *, policy: Optional[str] = None,
           hw=None) -> UnifiedMemory:
    """Re-drive a recorded stream through a fresh runtime.

    ``policy`` swaps every application allocation onto the named registered
    backend (built at the recorded page-size/threshold/migration knobs);
    ``hw`` swaps the hardware model (name or HardwareModel; default: the
    recorded one). Returns the replayed UnifiedMemory — ``um.prof`` holds
    the modeled phase times, traffic and timeline, and ``um.report()``
    the full report."""
    with _open_r(path) as f:
        events = (json.loads(line) for line in f if line.strip())
        hdr = next(events)
        assert hdr.get("t") == "hdr", "not a trace file (missing header)"
        assert hdr.get("v") in (1, TRACE_VERSION), \
            f"trace version {hdr.get('v')} not in (1, {TRACE_VERSION})"
        um = UnifiedMemory(
            hw=get_hardware(hw if hw is not None else hdr.get("hw")),
            staging_page_size=int(hdr.get("sps", 64 * 1024)))
        allocs: Dict[str, Allocation] = {}

        def rz(ranges):
            return [(allocs[n], lo, hi) for n, lo, hi in ranges]

        for ev in events:
            et = ev["t"]
            if et == "k":
                um.kernel(reads=rz(ev["r"]), writes=rz(ev["w"]),
                          flops=ev["fl"], actor=Actor(ev["ac"]),
                          name=ev["n"], node=int(ev.get("nd", 0)))
            elif et == "kb":
                um.kernel_batch([
                    (it[0], rz(it[1]), rz(it[2]), it[3], Actor(it[4]),
                     int(it[5]) if len(it) > 5 else 0)
                    for it in ev["it"]])
            elif et == "s":
                um.sync()
            elif et == "a":
                allocs[ev["n"]] = um.alloc(ev["n"], int(ev["b"]),
                                           _rebuild_policy(ev, policy))
            elif et == "f":
                um.free(allocs[ev["n"]])
            elif et == "c":
                um.copy(allocs[ev["n"]], ev["lo"], ev["hi"], ev["d"])
            elif et == "pf":
                um.prefetch(allocs[ev["n"]], ev["lo"], ev["hi"],
                            overlap=ev["ov"])
            elif et == "dm":
                um.demote(allocs[ev["n"]], ev["lo"], ev["hi"])
            elif et == "ph":
                um.prof.set_phase(ev["n"])
            else:
                raise ValueError(f"unknown trace event type {et!r}")
    return um
