"""UnifiedMemory: the Grace Hopper unified-memory system as a composable runtime.

Models (and on real TPU backends, drives — see serve/paged.py and
optim/offload) a two-tier HBM/host memory system with:

  * lazy PTE creation + first-touch placement (system & managed),
  * direct remote access at fine granularity over the interconnect (system),
  * fault-driven on-demand migration + speculative prefetch (managed),
  * access-counter-based delayed migration with threshold notifications
    (system, §2.2.1), applied batch-wise at sync points,
  * LRU eviction under device-capacity pressure (managed) vs graceful remote
    access (system), reproducing the paper's oversubscription behavior (§7).

Applications interact through alloc/free, phase(), kernel(), copy() and
prefetch(). Time is *modeled* via the HardwareModel (this container has no
GPU/TPU); correctness of the application math is real JAX executed on CPU.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import GRACE_HOPPER, HardwareModel
from repro.core.pagetable import Actor, BlockTable, Tier
from repro.core.policy import PolicyConfig, explicit_policy, managed_policy, system_policy
from repro.core.profiler import MemoryProfiler

Range = Tuple["Allocation", int, int]  # (alloc, lo, hi) byte range


@dataclass
class Allocation:
    name: str
    nbytes: int
    policy: PolicyConfig
    table: Optional[BlockTable]  # None for explicit (device-resident, no PTEs)
    device_bytes_explicit: int = 0
    pending: Optional[np.ndarray] = None  # system: notification-pending pages
    freed: bool = False


class OutOfDeviceMemory(RuntimeError):
    pass


class UnifiedMemory:
    def __init__(self, hw: HardwareModel = GRACE_HOPPER,
                 profiler: Optional[MemoryProfiler] = None):
        self.hw = hw
        self.prof = profiler or MemoryProfiler()
        self.clock = 0.0
        self.allocs: Dict[str, Allocation] = {}
        self.epoch = 0
        self._pending_overlap = 0.0  # async-prefetch seconds hidden under compute

    # ------------------------------------------------------------------ util
    def _charge(self, seconds: float) -> None:
        self.clock += seconds
        self.prof.charge(seconds)

    def _sample(self) -> None:
        self.prof.sample(self.clock, self.host_bytes(), self.device_bytes())

    def host_bytes(self) -> int:
        return sum(a.table.resident_bytes(Tier.HOST) for a in self.allocs.values()
                   if a.table is not None and not a.freed)

    def device_bytes(self) -> int:
        tot = 0
        for a in self.allocs.values():
            if a.freed:
                continue
            tot += a.device_bytes_explicit
            if a.table is not None:
                tot += a.table.resident_bytes(Tier.DEVICE)
        return tot

    def device_free(self) -> int:
        return self.hw.device_capacity - self.device_bytes()

    @contextlib.contextmanager
    def phase(self, name: str):
        prev = self.prof.phase
        self.prof.set_phase(name)
        try:
            yield
        finally:
            self.prof.set_phase(prev)

    # ----------------------------------------------------------------- alloc
    def alloc(self, name: str, nbytes: int, policy: PolicyConfig) -> Allocation:
        assert name not in self.allocs, f"duplicate alloc {name!r}"
        if policy.kind == "explicit":
            if nbytes > self.device_free():
                raise OutOfDeviceMemory(
                    f"cudaMalloc({name}): {nbytes} > free {self.device_free()}")
            a = Allocation(name, nbytes, policy, table=None, device_bytes_explicit=nbytes)
            self._charge(self.hw.alloc_per_page * -(-nbytes // policy.page_size))
        else:
            table = BlockTable(name, nbytes, policy.page_size)
            a = Allocation(name, nbytes, policy, table=table,
                           pending=np.zeros(table.num_pages, bool))
            # lazy PTEs: allocation itself only creates VMA bookkeeping
            self._charge(self.hw.alloc_per_page * min(table.num_pages, 64))
        self.allocs[name] = a
        self._sample()
        return a

    def free(self, a: Allocation) -> None:
        assert not a.freed
        if a.table is not None:
            mapped = int((a.table.tier != int(Tier.UNMAPPED)).sum())
            self._charge(self.hw.dealloc_per_page * mapped)
        else:
            self._charge(self.hw.dealloc_per_page *
                         -(-a.nbytes // a.policy.migration_granule))
        a.freed = True
        self._sample()

    # ------------------------------------------------------- page-level ops
    def _first_touch(self, a: Allocation, pages: np.ndarray, actor: Actor) -> None:
        t = a.table
        unmapped = pages[t.tier[pages] == int(Tier.UNMAPPED)]
        if len(unmapped) == 0:
            return
        tr = self.prof.traffic()
        if actor is Actor.GPU and a.policy.kind == "system":
            # GPU first-touch of system memory: SMMU fault -> OS on the CPU
            # creates the PTE (the §5.1.2 init bottleneck)
            self._charge(self.hw.pte_init_gpu * len(unmapped))
            tr.pte_inits_gpu += len(unmapped)
        elif actor is Actor.GPU:
            # managed: first-touch maps straight into the GPU page table
            granules = max(1, len(unmapped) * t.page_size // a.policy.migration_granule)
            self._charge(self.hw.pte_init_cpu * granules)
            tr.pte_inits_gpu += len(unmapped)
        else:
            self._charge(self.hw.pte_init_cpu * len(unmapped))
            tr.pte_inits_cpu += len(unmapped)
        tier = actor.home_tier
        if tier is Tier.DEVICE:
            need = int(t.page_bytes(unmapped).sum())
            if need > self.device_free():
                if a.policy.kind == "managed":
                    self._evict_lru(need - self.device_free(), exclude=a)
                    if need > self.device_free():
                        tier = Tier.HOST  # spill the remainder
                else:
                    tier = Tier.HOST  # system memory: map host-side instead
        t.map_pages(unmapped, tier)

    def _evict_lru(self, need_bytes: int, exclude: Optional[Allocation] = None) -> None:
        """Evict LRU managed device-resident granules until need_bytes freed."""
        victims: List[Tuple[float, Allocation, int]] = []
        for a in self.allocs.values():
            if a.freed or a.table is None or a.policy.kind != "managed":
                continue
            pages = a.table.pages_in(Tier.DEVICE)
            for p in pages:
                victims.append((a.table.last_access_epoch[p], a, p))
        victims.sort(key=lambda v: v[0])
        freed = 0
        tr = self.prof.traffic()
        by_alloc: Dict[str, List[int]] = {}
        for _, a, p in victims:
            if freed >= need_bytes:
                break
            by_alloc.setdefault(a.name, []).append(p)
            freed += int(a.table.page_bytes(np.array([p]))[0])
        for name, plist in by_alloc.items():
            a = self.allocs[name]
            pages = np.asarray(plist)
            # clean pages are just unmapped; only dirty pages copy back
            dirty = pages[a.table.dirty[pages]]
            nbytes = int(a.table.page_bytes(dirty).sum()) if len(dirty) else 0
            a.table.move_pages(pages, Tier.HOST)
            a.table.dirty[pages] = False
            self._charge(nbytes / self.hw.link_d2h + self.hw.migrate_per_page * len(pages))
            tr.migrated_out += nbytes
            tr.link_d2h += nbytes

    def _migrate_in(self, a: Allocation, pages: np.ndarray) -> int:
        """Move host-resident pages to device, evicting if managed. Returns bytes."""
        t = a.table
        pages = pages[t.tier[pages] == int(Tier.HOST)]
        if len(pages) == 0:
            return 0
        need = int(t.page_bytes(pages).sum())
        if need > self.device_free():
            if a.policy.kind == "managed":
                self._evict_lru(need - self.device_free(), exclude=a)
            if need > self.device_free():
                fit = np.cumsum(t.page_bytes(pages)) <= self.device_free()
                pages = pages[fit]
                need = int(t.page_bytes(pages).sum()) if len(pages) else 0
                if need == 0:
                    return 0
        t.move_pages(pages, Tier.DEVICE)
        tr = self.prof.traffic()
        tr.migrated_in += need
        tr.link_h2d += need
        self._charge(need / self.hw.link_h2d + self.hw.migrate_per_page * len(pages))
        return need

    # ---------------------------------------------------------------- kernel
    def kernel(self, *, reads: Sequence[Range] = (), writes: Sequence[Range] = (),
               flops: float = 0.0, actor: Actor = Actor.GPU,
               name: str = "kernel") -> float:
        """Model one kernel/loop-step. Returns modeled seconds."""
        self.epoch += 1
        t0 = self.clock
        tr = self.prof.traffic()
        local_bytes = 0.0
        remote_h2d = 0.0
        remote_d2h = 0.0
        remote_slow = 0.0  # managed thrash-mode remote reads (low bandwidth)

        for is_write, ranges in ((False, reads), (True, writes)):
            for a, lo, hi in ranges:
                assert not a.freed, a.name
                if a.table is None:  # explicit: device-local always
                    local_bytes += hi - lo
                    tr.device_local += hi - lo
                    continue
                t = a.table
                p0, p1 = t.page_range(lo, hi)
                pages = np.arange(p0, p1)
                if len(pages) == 0:
                    continue
                self._first_touch(a, pages, actor)
                t.last_access_epoch[pages] = self.epoch
                if is_write:
                    t.dirty[pages] = True

                thrashing = False
                if a.policy.kind == "managed" and actor is Actor.GPU:
                    # fault-driven on-demand migration (+ speculative prefetch);
                    # when the touched working set cannot fit even after
                    # evicting every other managed page, the driver stops
                    # migrating and serves remote reads (paper §7 Fig. 12)
                    host_pages = pages[t.tier[pages] == int(Tier.HOST)]
                    if len(host_pages):
                        ws = int(t.page_bytes(host_pages).sum())
                        evictable = sum(
                            o.table.resident_bytes(Tier.DEVICE)
                            for o in self.allocs.values()
                            if o is not a and not o.freed and o.table is not None
                            and o.policy.kind == "managed")
                        thrashing = ws > self.device_free() + evictable
                    if len(host_pages) and not thrashing:
                        gran_pages = max(1, a.policy.migration_granule // t.page_size)
                        granules = np.unique(host_pages // gran_pages)
                        nfaults = len(granules)
                        tr.faults += nfaults
                        self._charge(self.hw.page_fault_cost * nfaults)
                        pf = a.policy.speculative_prefetch
                        mig = set()
                        for g in granules:
                            for gg in range(g, min(g + pf, t.num_pages // gran_pages + 1)):
                                mig.update(range(gg * gran_pages,
                                                 min((gg + 1) * gran_pages, t.num_pages)))
                        self._migrate_in(a, np.asarray(sorted(mig)))
                elif a.policy.kind == "managed" and actor is Actor.CPU:
                    dev_pages = pages[t.tier[pages] == int(Tier.DEVICE)]
                    if len(dev_pages):
                        gran_pages = max(1, a.policy.migration_granule // t.page_size)
                        granules = np.unique(dev_pages // gran_pages)
                        tr.faults += len(granules)
                        self._charge(self.hw.page_fault_cost * len(granules))
                        nbytes = int(t.page_bytes(dev_pages).sum())
                        t.move_pages(dev_pages, Tier.HOST)
                        tr.migrated_out += nbytes
                        tr.link_d2h += nbytes
                        self._charge(nbytes / self.hw.link_d2h
                                     + self.hw.migrate_per_page * len(dev_pages))

                # account access traffic against current residency
                pb = t.page_bytes(pages).astype(np.float64)
                # clip to the actual [lo,hi) range on the boundary pages
                pb[0] -= lo - p0 * t.page_size
                if p1 * t.page_size > hi:
                    pb[-1] -= p1 * t.page_size - hi
                on_dev = t.tier[pages] == int(Tier.DEVICE)
                dev_b = float(pb[on_dev].sum())
                host_b = float(pb[~on_dev].sum())
                if actor is Actor.GPU:
                    local_bytes += dev_b
                    tr.device_local += int(dev_b)
                    if thrashing:
                        remote_slow += host_b
                        tr.link_h2d += int(host_b)
                    elif is_write:
                        remote_d2h += host_b
                        tr.link_d2h += int(host_b)
                    else:
                        remote_h2d += host_b
                        tr.link_h2d += int(host_b)
                    if a.policy.kind == "system" and a.policy.auto_migrate and host_b:
                        hp = pages[~on_dev]
                        txn = np.maximum(1, (t.page_bytes(hp) //
                                             self.hw.remote_access_grain))
                        before = t.gpu_counter[hp]
                        t.gpu_counter[hp] = before + txn.astype(np.int32)
                        crossed = (before < a.policy.counter_threshold) & (
                            t.gpu_counter[hp] >= a.policy.counter_threshold)
                        newly = hp[crossed]
                        if len(newly):
                            a.pending[newly] = True
                            tr.notifications += len(newly)
                else:
                    local_bytes += host_b
                    tr.host_local += int(host_b)
                    remote_d2h += dev_b
                    tr.link_d2h += int(dev_b)

        bw = self.hw.device_bw if actor is Actor.GPU else self.hw.host_bw
        t_local = local_bytes / bw
        eff = self.hw.remote_efficiency
        t_remote = (remote_h2d / (self.hw.link_h2d * eff)
                    + remote_d2h / (self.hw.link_d2h * eff)
                    + remote_slow / (self.hw.link_h2d
                                     * self.hw.managed_thrash_efficiency))
        t_compute = flops / self.hw.flops_rate
        # async prefetch issued before this kernel overlaps with it
        t_kernel = max(t_local, t_remote, t_compute, self._pending_overlap)
        self._pending_overlap = 0.0
        self._charge(t_kernel + self.hw.kernel_launch)
        self._sample()
        return self.clock - t0

    # ------------------------------------------------------------- sync/misc
    def sync(self) -> float:
        """cudaDeviceSynchronize analogue: apply pending delayed migrations."""
        t0 = self.clock
        if self._pending_overlap:  # flush un-overlapped async prefetches
            self._charge(self._pending_overlap)
            self._pending_overlap = 0.0
        for a in self.allocs.values():
            if a.freed or a.table is None or a.policy.kind != "system":
                continue
            if not a.policy.auto_migrate or a.pending is None:
                continue
            pages = np.nonzero(a.pending & (a.table.tier == int(Tier.HOST)))[0]
            if len(pages) == 0:
                a.pending[:] = False
                continue
            budget = a.policy.max_migration_bytes_per_sync
            sizes = a.table.page_bytes(pages)
            keep = np.cumsum(sizes) <= budget
            moved = self._migrate_in(a, pages[keep])
            a.pending[pages[keep]] = False
        self._sample()
        return self.clock - t0

    def copy(self, a: Allocation, lo: int, hi: int, direction: str) -> float:
        """Explicit cudaMemcpy. direction: 'h2d' | 'd2h'."""
        nbytes = hi - lo
        bw = self.hw.link_h2d if direction == "h2d" else self.hw.link_d2h
        self._charge(nbytes / bw)
        tr = self.prof.traffic()
        if direction == "h2d":
            tr.link_h2d += nbytes
        else:
            tr.link_d2h += nbytes
        self._sample()
        return nbytes / bw

    def prefetch(self, a: Allocation, lo: int, hi: int,
                 overlap: bool = False) -> float:
        """cudaMemPrefetchAsync analogue: migrate range to device.

        overlap=True models the async stream: the migration cost hides under
        the next kernel (charged as max(kernel, prefetch))."""
        t0 = self.clock
        assert a.table is not None, "prefetch needs a paged allocation"
        p0, p1 = a.table.page_range(lo, hi)
        pages = np.arange(p0, p1)
        self._first_touch(a, pages, Actor.CPU)
        if overlap:
            saved = self.clock
            self._migrate_in(a, pages)
            self._pending_overlap += self.clock - saved
            # roll the clock back: the cost is deferred to the next kernel
            dt = self.clock - saved
            self.clock = saved
            self.prof.charge(-dt)
        else:
            self._migrate_in(a, pages)
        self._sample()
        return self.clock - t0

    # ---------------------------------------------------------------- report
    def report(self) -> Dict[str, object]:
        rep = self.prof.report()
        rep["allocations"] = {
            name: {
                "nbytes": a.nbytes,
                "policy": a.policy.kind,
                "page_size": a.policy.page_size,
                "device_bytes": (a.device_bytes_explicit if a.table is None
                                 else a.table.resident_bytes(Tier.DEVICE)),
                "host_bytes": (0 if a.table is None
                               else a.table.resident_bytes(Tier.HOST)),
                "freed": a.freed,
            }
            for name, a in self.allocs.items()
        }
        return rep
