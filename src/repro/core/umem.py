"""UnifiedMemory: the Grace Hopper unified-memory system as a composable runtime.

Models (and on real TPU backends, drives — see serve/paged.py and
optim/offload) a two-tier HBM/host memory system with:

  * lazy PTE creation + first-touch placement (system & managed),
  * direct remote access at fine granularity over the interconnect (system),
  * fault-driven on-demand migration + speculative prefetch (managed),
  * access-counter-based delayed migration with threshold notifications
    (system, §2.2.1), applied batch-wise at sync points,
  * LRU eviction under device-capacity pressure (managed) vs graceful remote
    access (system), reproducing the paper's oversubscription behavior (§7).

Applications interact through the typed buffer front-end — array() /
from_host() return UMBuffers whose numpy-style slices feed launch(),
staged(), prefetch() and demote() (see core/buffer.py and docs/memspace.md)
— while alloc/free, phase(), kernel() and copy() remain the raw runtime
surface the front-end lowers onto. Time is *modeled* via the HardwareModel
(this container has no GPU/TPU); correctness of the application math is
real JAX executed on CPU.

The hot path is *run-compressed*: kernel() resolves each byte range to a
(lo_page, hi_page) extent once, and every page-table operation under it —
first-touch mapping, LRU-epoch touches, fault/granule counting, speculative
prefetch expansion, access-counter bumps, LRU victim selection, sync-point
notification draining — works on run intersections of the extent with the
table's interval metadata (see core/pagetable.py and core/runs.py). Cost is
O(runs overlapping the extent), never O(pages in extent): a uniform 16M-page
working set is one run. Residency totals are cached (updated incrementally
on every map/move), so profiler sampling is O(1) per op. The charge math is
unchanged from the dense per-page implementation — modeled times and
traffic are bit-identical (enforced by scripts/check_parity.py).

Policy behavior is *pluggable*: the runtime never branches on a policy
name. Every policy-dependent decision — allocation shape, first-touch
placement, pre-access migration, access-charge classification, eviction
participation, sync-point draining, staging routing — dispatches to the
allocation's :class:`~repro.core.policy.MemPolicy` hooks, so a new memory
system (see ``Mi300aUnifiedPolicy``) plugs in through
``repro.core.registry`` without touching this file."""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.buffer import BufferView, UMBuffer, as_view
from repro.core.hardware import GRACE_HOPPER, HardwareModel
from repro.core.pagetable import Actor, BlockTable, Tier
from repro.core.policy import (  # noqa: F401  (Allocation/OOM re-exported)
    Allocation,
    HostSpillError,
    MemPolicy,
    OutOfDeviceMemory,
    PolicyConfig,
)
from repro.core.profiler import MemoryProfiler

Range = Tuple[Allocation, int, int]  # (alloc, lo, hi) byte range


def _as_range(r, actor: Actor) -> Range:
    """Launch/prefetch argument -> raw Range: BufferViews and UMBuffers
    resolve against the actor (CPU actors hit a staged buffer's host side);
    raw (alloc, lo, hi) tuples pass through untouched."""
    if isinstance(r, (BufferView, UMBuffer)):
        return as_view(r).resolve(actor)
    return r


def _operand_names(items: Sequence) -> List[str]:
    """Unique buffer/allocation names of launch operands, in operand order."""
    names = []
    for r in items:
        name = (as_view(r).buf.name if isinstance(r, (BufferView, UMBuffer))
                else r[0].name)
        if name not in names:
            names.append(name)
    return names


def _derived_label(reads: Sequence, writes: Sequence) -> str:
    """Default launch label derived from the operand buffer names, so the
    profiler's per-kernel report distinguishes unnamed kernels by what they
    touch instead of collapsing them all into one "kernel" bucket."""
    rd, wr = _operand_names(reads), _operand_names(writes)
    if rd and wr:
        return "+".join(rd) + "->" + "+".join(wr)
    return "+".join(rd or wr) or "kernel"


@dataclass(slots=True)
class KernelLaunch:
    """One deferred launch inside a :class:`KernelBatch` — the same
    arguments :meth:`UnifiedMemory.launch` takes, held until the batch is
    submitted. reads/writes accept BufferViews, UMBuffers or raw Ranges.
    ``node`` pins the issuing superchip for node-aware backends (None:
    the runtime's ambient node at submission)."""
    name: Optional[str] = None
    reads: Sequence = ()
    writes: Sequence = ()
    flops: float = 0.0
    actor: Actor = Actor.GPU
    node: Optional[int] = None


class KernelBatch:
    """Builder for :meth:`UnifiedMemory.launch_batch`: accumulate launches,
    submit once. ``batch.launch(...)`` mirrors ``um.launch(...)`` and
    returns the builder for chaining."""

    __slots__ = ("items",)

    def __init__(self, items: Optional[List[KernelLaunch]] = None):
        self.items: List[KernelLaunch] = list(items) if items else []

    def launch(self, name: Optional[str] = None, *, reads: Sequence = (),
               writes: Sequence = (), flops: float = 0.0,
               actor: Actor = Actor.GPU,
               node: Optional[int] = None) -> "KernelBatch":
        self.items.append(KernelLaunch(name, reads, writes, flops, actor,
                                       node))
        return self

    def __len__(self) -> int:
        return len(self.items)


class UnifiedMemory:
    def __init__(self, hw: HardwareModel = GRACE_HOPPER,
                 profiler: Optional[MemoryProfiler] = None,
                 staging_page_size: int = 64 * 1024):
        self.hw = hw
        self.prof = profiler or MemoryProfiler()
        self.clock = 0.0
        self.allocs: Dict[str, Allocation] = {}
        self.epoch = 0
        self._pending_overlap = 0.0  # async-prefetch seconds hidden under compute
        # page size of from_host() staging buffers under the explicit policy
        # (the host side of the cudaMalloc+malloc pair uses the *application's*
        # system page size, not a hard-wired default)
        self.staging_page_size = staging_page_size
        # cached residency over live allocations (kept in lockstep with every
        # BlockTable mutation; makes _sample O(1) per op)
        self._host_bytes = 0
        self._device_bytes = 0
        # ambient superchip for node-aware backends: first-touch placement
        # and charge classification happen "as seen from" this node. Plain
        # single-node runs never move it off 0.
        self._node = 0
        # optional TraceRecorder (core/trace.py): every public runtime op
        # appends one event when set; None costs a single identity check
        self._trace = None
        # fault-injection state (runtime/fault.py FaultPlan delivers through
        # fail_node / set_lane_degradation / set_spill_failure). All of it
        # defaults to "no fault" at zero per-op cost: the hot paths test a
        # None/emptiness once, exactly like _trace, so fault-free runs stay
        # bit-identical (the parity fixture pins this)
        self._dead_nodes: set = set()
        self._capacity_lost = 0  # device bytes gone with dead nodes
        self._lane_degrade: Optional[Tuple[float, float]] = None
        self._spill_fail = False

    # ------------------------------------------------------------------ util
    def _charge(self, seconds: float) -> None:
        self.clock += seconds
        self.prof.charge(seconds)

    def _sample(self) -> None:
        self.prof.sample(self.clock, self._host_bytes, self._device_bytes)

    def _apply_delta(self, delta: Tuple[int, int]) -> None:
        self._host_bytes += delta[0]
        self._device_bytes += delta[1]

    def host_bytes(self) -> int:
        return self._host_bytes

    def device_bytes(self) -> int:
        return self._device_bytes

    def device_free(self) -> int:
        return self.hw.device_capacity - self._capacity_lost \
            - self._device_bytes

    def _recompute_residency(self) -> Tuple[int, int]:
        """Slow-path recount (tests assert it matches the cached totals):
        re-derives each table's residency from its run structure."""
        host = dev = 0
        for a in self.allocs.values():
            if a.freed:
                continue
            dev += a.device_bytes_explicit
            if a.table is not None:
                _, nbytes = a.table.recount()
                # host slots sit at odd counter indices, device at even
                # (index = encoded location + 1); single-node tables reduce
                # to the classic HOST/DEVICE pair
                host += int(nbytes[1::2].sum())
                dev += int(nbytes[2::2].sum())
        return host, dev

    @contextlib.contextmanager
    def on_node(self, node: int):
        """Pin the ambient superchip: kernels, prefetches and first touches
        inside the block act as issued from ``node`` (node-aware backends
        place and charge accordingly; single-node backends ignore it)."""
        prev, self._node = self._node, int(node)
        try:
            yield self
        finally:
            self._node = prev

    # ---------------------------------------------------------------- faults
    def fail_node(self, node: int) -> Dict[str, List[Tuple[int, int]]]:
        """A superchip drops out of the pool: its device capacity is gone
        and every page resident on it — host or device side — is lost.
        Each live allocation's policy drains the dead location through the
        ``on_node_loss`` lifecycle hook (placement maps, residency counters
        and pending notifications all updated); the poisoned page runs are
        returned per allocation so consumers (the serve engine) can map
        them back to sequences and replay. Idempotent per node."""
        node = int(node)
        if node in self._dead_nodes:
            return {}
        self._dead_nodes.add(node)
        self._capacity_lost += int(
            getattr(self.hw, "node_device_capacity", 0)
            or self.hw.device_capacity)
        lost: Dict[str, List[Tuple[int, int]]] = {}
        pages = nbytes = 0
        for a in self.allocs.values():
            if a.freed:
                continue
            runs = a.policy.on_node_loss(self, a, node)
            if runs:
                lost[a.name] = runs
                pages += sum(e - s for s, e in runs)
                if a.table is not None:
                    nbytes += sum(e - s for s, e in runs) * a.table.page_size
        self.prof.extra["node_losses"] += 1
        self.prof.extra["lost_pages"] += pages
        self.prof.extra["lost_bytes"] += nbytes
        self._sample()
        return lost

    def set_lane_degradation(
            self, factors: Optional[Tuple[float, float]]) -> None:
        """Enter/leave a degraded-lane window: ``(nvlink_factor,
        fabric_factor)`` multiply the nominal inter-node bandwidths (<1 =
        slower) until cleared with ``None``. Node-aware charge paths read
        :attr:`lane_degradation`; ``None`` keeps them bit-identical to a
        fault-free run."""
        self._lane_degrade = (
            None if factors is None
            else (float(factors[0]), float(factors[1])))

    @property
    def lane_degradation(self) -> Optional[Tuple[float, float]]:
        return self._lane_degrade

    def set_spill_failure(self, flag: bool) -> None:
        """Enter/leave a host-spill failure window: while set, ``demote``
        of a migratable allocation raises :class:`HostSpillError` instead
        of spilling (the serve engine falls back to drop-and-recompute)."""
        self._spill_fail = bool(flag)

    def charge_transfer(self, nbytes: int, bw: float, *, latency: float = 0.0,
                        counter: Optional[str] = None) -> float:
        """Charge a modeled bulk transfer: ``nbytes`` at ``bw`` bytes/s plus
        a fixed ``latency``. Bytes are attributed to the open-ended
        ``prof.extra[counter]`` side counter (never TrafficCounters, whose
        field set the parity fixture pins). The cluster TP-serving layer
        charges per-token all-reduce traffic through this."""
        dt = nbytes / bw + latency
        self._charge(dt)
        if counter:
            self.prof.extra[counter] += int(nbytes)
        self._sample()
        return dt

    @contextlib.contextmanager
    def phase(self, name: str):
        prev = self.prof.phase
        self.prof.set_phase(name)
        if self._trace is not None:
            self._trace.on_phase(name)
        try:
            yield
        finally:
            self.prof.set_phase(prev)
            if self._trace is not None:
                self._trace.on_phase(prev)

    # ----------------------------------------------------------------- alloc
    def alloc(self, name: str, nbytes: int, policy: MemPolicy) -> Allocation:
        assert name not in self.allocs, f"duplicate alloc {name!r}"
        a = policy.on_alloc(self, name, nbytes)
        self.allocs[name] = a
        if self._trace is not None:
            self._trace.on_alloc(a)
        self._sample()
        return a

    def free(self, a: Allocation) -> None:
        assert not a.freed
        if self._trace is not None:
            self._trace.on_free(a.name)
        a.policy.on_free(self, a)
        a.freed = True
        self._sample()

    def free_live(self, *, keep_reserved: bool = True) -> None:
        """Free every live allocation in allocation order. Names starting
        with ``__`` (harness-reserved, e.g. the oversubscription ballast)
        are kept unless keep_reserved=False."""
        for a in list(self.allocs.values()):
            if a.freed:
                continue
            if keep_reserved and a.name.startswith("__"):
                continue
            self.free(a)

    # -------------------------------------------------------------- buffers
    def array(self, name: str, shape, dtype, policy: MemPolicy) -> UMBuffer:
        """Allocate a typed buffer: shape x dtype under `policy`.

        The buffer-centric analogue of alloc(): slices of the returned
        UMBuffer feed launch()/prefetch()/demote() instead of raw byte
        ranges. Device-only scratch and GPU-initialized data use this; data
        that originates host-side should use from_host()."""
        shape = (shape,) if isinstance(shape, (int, np.integer)) else tuple(shape)
        nbytes = int(np.prod(np.asarray(shape, np.int64))) * np.dtype(dtype).itemsize
        a = self.alloc(name, nbytes, policy)
        return UMBuffer(self, a, shape, dtype)

    def from_host(self, name: str, shape, dtype,
                  policy: MemPolicy) -> UMBuffer:
        """A buffer whose contents originate on the host (CPU init).

        Under policies whose memory is CPU-accessible (managed/system/
        mi300a_unified) this is exactly array(): first-touch placement
        follows the CPU writer. A policy with staged transfers (explicit)
        materializes the cudaMalloc + malloc pair via its ``make_staging``
        hook — a device buffer plus a ``<name>__host`` staging buffer (at
        ``staging_page_size``, the application's system page size) — and
        launch() routes CPU-actor accesses to the staging side through
        ``resolve_actor_side``. um.staged() charges the h2d/d2h copies at
        phase boundaries."""
        buf = self.array(name, shape, dtype, policy)
        buf.host = policy.make_staging(self, buf)
        return buf

    def launch(self, name: Optional[str] = None, *, reads: Sequence = (),
               writes: Sequence = (), flops: float = 0.0,
               actor: Actor = Actor.GPU, node: Optional[int] = None) -> float:
        """Buffer-level kernel launch: the tracked, policy-agnostic front
        door of kernel(). reads/writes take BufferViews (``buf[i:j]``,
        ``buf.rows(lo, hi)``) or whole UMBuffers; each resolves to exactly
        the byte extent the raw Range API would have used, so charges are
        bit-identical. CPU-actor accesses to from_host() buffers land in
        their staging allocation. When ``name`` is omitted, the label is
        derived from the operand buffer names (reads->writes order, e.g.
        ``"temp+power->temp_out"``), so per-kernel profiler reports stay
        unambiguous when an app launches many unnamed kernels."""
        if name is None:
            name = _derived_label(reads, writes)
        return self.kernel(
            reads=[_as_range(r, actor) for r in reads],
            writes=[_as_range(w, actor) for w in writes],
            flops=flops, actor=actor, name=name, node=node)

    def launch_batch(self, batch) -> List[float]:
        """Submit a whole batch of launches in one engine step.

        ``batch`` is a :class:`KernelBatch` or any iterable of
        :class:`KernelLaunch`. Charges are bit-identical to issuing the
        same launches through :meth:`launch` one by one — the batched
        engine (see :meth:`kernel_batch`) is a pure dispatch optimization,
        certified per policy and falling back to the sequential path
        whenever a launch could mutate placement mid-batch. Returns the
        per-launch modeled seconds, in submission order."""
        items = batch.items if isinstance(batch, KernelBatch) else list(batch)
        resolved = []
        ap = resolved.append
        amb = self._node
        for it in items:
            actor = it.actor
            name = it.name
            # raw-tuple fast path: _as_range passes tuples through, so only
            # buffer views pay the resolve call
            ap((name if name is not None
                else _derived_label(it.reads, it.writes),
                [r if type(r) is tuple else _as_range(r, actor)
                 for r in it.reads],
                [w if type(w) is tuple else _as_range(w, actor)
                 for w in it.writes],
                it.flops, actor,
                amb if it.node is None else it.node))
        return self.kernel_batch(resolved)

    @contextlib.contextmanager
    def staged(self, h2d: Sequence = (), d2h: Sequence = (), *,
               h2d_phase: str = "h2d", d2h_phase: str = "d2h"):
        """Staging boundary around a compute region.

        For every listed buffer/view whose policy declares
        ``staged_transfers`` (the explicit backend), charges the cudaMemcpy
        h2d copies on entry (phase `h2d_phase`) and the d2h copies on exit
        (phase `d2h_phase`), in list order. Buffers under directly-
        accessible policies pass through untouched — the same `with` block
        is the single code path for every memory-management version."""
        up = [as_view(v) for v in h2d]
        down = [as_view(v) for v in d2h]
        todo = [v for v in up if v.buf.policy.staged_transfers]
        if todo:
            with self.phase(h2d_phase):
                for v in todo:
                    self.copy(v.buf.alloc, v.lo, v.hi, "h2d")
        try:
            yield self
        finally:
            todo = [v for v in down if v.buf.policy.staged_transfers]
            if todo:
                with self.phase(d2h_phase):
                    for v in todo:
                        self.copy(v.buf.alloc, v.lo, v.hi, "d2h")

    # ------------------------------------------------------- page-level ops
    def _first_touch(self, a: Allocation, p0: int, p1: int, actor: Actor) -> None:
        """Lazily map the unmapped pages of extent [p0, p1): the policy
        charges PTE creation and picks the tier (spilling/evicting under
        device pressure as its memory system dictates)."""
        t = a.table
        if t.resident_pages(Tier.UNMAPPED) == 0:
            return  # O(1) steady-state exit: the whole table is mapped
        n_unmapped, need = t.unmapped_stats(p0, p1)
        if n_unmapped == 0:
            return
        tier = a.policy.on_first_touch(self, a, p0, p1, actor, n_unmapped, need)
        self._apply_delta(t.map_unmapped(p0, p1, tier))

    def _evict_lru(self, need_bytes: int, exclude: Optional[Allocation] = None) -> None:
        """Evict LRU managed device-resident pages until need_bytes freed.

        Victim selection is run-based: each candidate contributes its
        (device-tier run ∩ LRU-epoch run) pieces — O(runs), not O(pages) —
        and a stable sort of the pieces by epoch reproduces the dense
        per-page LRU order exactly (pages inside a piece are consecutive and
        share an epoch; ties keep (alloc, page) insertion order). The
        boundary piece is split at the page where the freed-bytes cumsum
        crosses `need_bytes`.

        `exclude` shields the faulting allocation's *current-step* working set
        (pages with last_access_epoch == the in-flight kernel's epoch) from
        eviction — the faulting allocation never self-evicts pages the same
        kernel step just touched. Colder pages of the same allocation stay
        evictable: real UVM evicts an oversubscribed allocation's own LRU
        pages (the paper's §7 streaming window), so excluding the whole
        allocation would be wrong. Known trade-off: a kernel touching several
        managed allocations under pressure may still evict *another*
        allocation's same-step pages (LRU order makes them last-resort
        victims); widening the epoch shield to every allocation is semantically
        attractive but shifts the reproduced fig11 oversubscription curves
        further from the paper baseline, so it is deliberately not done here.
        """
        cands: List[Allocation] = [
            a for a in self.allocs.values()
            if not a.freed and a.table is not None and a.policy.evictable]
        # cached-counter early-out: no evictable allocation has device-resident
        # pages -> nothing to evict, no run/array work at all
        if not any(a.table.resident_pages(Tier.DEVICE) for a in cands):
            return
        piece_s, piece_e, piece_ep, piece_ai = [], [], [], []
        for i, a in enumerate(cands):
            t = a.table
            if t.resident_pages(Tier.DEVICE) == 0:
                continue
            ds, de = t.runs_of(Tier.DEVICE)
            for s0, e0 in zip(ds, de):
                es, ee, ev = t.epoch_runs(int(s0), int(e0))
                if a is exclude:
                    m = ev < self.epoch
                    es, ee, ev = es[m], ee[m], ev[m]
                if len(es):
                    piece_s.append(es)
                    piece_e.append(ee)
                    piece_ep.append(ev)
                    piece_ai.append(np.full(len(es), i, np.int64))
        if not piece_s:
            return
        S = np.concatenate(piece_s)
        E = np.concatenate(piece_e)
        EP = np.concatenate(piece_ep)
        AI = np.concatenate(piece_ai)
        # stable sort of epoch-uniform pieces == the dense per-page stable
        # argsort (pieces were built in (alloc, page) insertion order)
        order = np.argsort(EP, kind="stable")
        S, E, AI = S[order], E[order], AI[order]
        ps_of = np.array([c.table.page_size for c in cands], np.int64)
        np_of = np.array([c.table.num_pages for c in cands], np.int64)
        tb_of = np.array([c.table.tail_bytes for c in cands], np.int64)
        sizes = (E - S) * ps_of[AI]
        tailm = E == np_of[AI]
        sizes[tailm] += tb_of[AI[tailm]] - ps_of[AI[tailm]]
        csum = np.cumsum(sizes)
        before = csum - sizes
        take = before < need_bytes
        S, E, AI = S[take], E[take], AI[take]
        if len(S) == 0:
            return
        # boundary piece: victims are taken while the bytes freed *before*
        # each page is < need — a page-count prefix of the piece
        room = need_bytes - int(before[np.flatnonzero(take)[-1]])
        psz = int(ps_of[AI[-1]])
        k = min(int(E[-1] - S[-1]), -(-room // psz))
        E[-1] = S[-1] + k
        tr = self.prof.traffic()
        uniq, first = np.unique(AI, return_index=True)
        for ai in uniq[np.argsort(first)]:  # first-appearance (charge) order
            a = cands[int(ai)]
            m = AI == ai
            s_list, e_list = S[m], E[m]
            npages = int((e_list - s_list).sum())
            # clean pages are just unmapped; only dirty pages copy back
            nbytes = a.table.dirty_bytes(s_list, e_list)
            self._apply_delta(a.table.move_runs(s_list, e_list, Tier.HOST))
            a.table.clear_dirty(s_list, e_list)
            self._charge(nbytes / self.hw.link_d2h + self.hw.migrate_per_page * npages)
            tr.migrated_out += nbytes
            tr.link_d2h += nbytes

    def _prefix_fit_runs(self, t: BlockTable, starts: np.ndarray,
                         ends: np.ndarray, budget: int):
        """Largest page-prefix of the runs whose per-page byte cumsum stays
        <= budget (the run analogue of ``pages[cumsum(sizes) <= budget]``)."""
        sizes = t.span_bytes(starts, ends)
        csum = np.cumsum(sizes)
        nfull = int(np.searchsorted(csum, budget, "right"))
        if nfull == len(starts):
            return starts, ends
        cb = int(csum[nfull - 1]) if nfull else 0
        k = max(0, (budget - cb) // t.page_size)
        if k == 0:
            return starts[:nfull], ends[:nfull]
        s = starts[:nfull + 1].copy()
        e = ends[:nfull + 1].copy()
        e[-1] = s[-1] + k
        return s, e

    def _migrate_in_runs(self, a: Allocation, starts, ends) -> int:
        """Move the host-resident pages of the given ascending [s, e) spans
        to the device, evicting if the policy reclaims under pressure.
        Returns bytes migrated. Placement no-op for policies whose memory
        system has no migration (a single physical pool)."""
        if not a.policy.migratable:
            return 0
        handled = a.policy.on_migrate_in(self, a, starts, ends)
        if handled is not None:  # node-aware backends promote node-locally
            return handled
        t = a.table
        hs, he = [], []
        for s0, e0 in zip(starts, ends):
            rs, re_ = t.runs_of(Tier.HOST, int(s0), int(e0))
            hs.append(rs)
            he.append(re_)
        if not hs:
            return 0
        hs = np.concatenate(hs)
        he = np.concatenate(he)
        if len(hs) == 0:
            return 0
        need = int(t.span_bytes(hs, he).sum())
        if need > self.device_free():
            a.policy.on_pressure(self, a, need)
            if need > self.device_free():
                hs, he = self._prefix_fit_runs(t, hs, he, self.device_free())
                if len(hs) == 0:
                    return 0
                need = int(t.span_bytes(hs, he).sum())
                if need == 0:
                    return 0
        self._apply_delta(t.move_runs(hs, he, Tier.DEVICE))
        tr = self.prof.traffic()
        tr.migrated_in += need
        tr.link_h2d += need
        npages = int((he - hs).sum())
        self._charge(need / self.hw.link_h2d + self.hw.migrate_per_page * npages)
        return need

    def _counter_bump(self, a: Allocation, p0: int, p1: int, txn: int) -> None:
        """Bump the GPU access counter by `txn` for every page of [p0, p1);
        pages crossing the policy threshold go notification-pending."""
        thr = a.policy.counter_threshold
        cs, ce, cv = a.table.bump_counter(p0, p1, txn)
        crossed = (cv < thr) & (cv + txn >= thr)
        if crossed.any():
            n_newly = int((ce[crossed] - cs[crossed]).sum())
            for s0, e0 in zip(cs[crossed], ce[crossed]):
                a.pending.set_range(int(s0), int(e0), 1)
            a.pending_count += n_newly
            self.prof.traffic().notifications += n_newly

    # ---------------------------------------------------------------- kernel
    def kernel(self, *, reads: Sequence[Range] = (), writes: Sequence[Range] = (),
               flops: float = 0.0, actor: Actor = Actor.GPU,
               name: str = "kernel", node: Optional[int] = None) -> float:
        """Model one kernel/loop-step. Returns modeled seconds. ``node``
        pins the issuing superchip for node-aware backends; None uses the
        ambient :meth:`on_node` node (0 outside any block)."""
        nd = self._node if node is None else int(node)
        if self._trace is not None:
            self._trace.on_kernel(name, reads, writes, flops, actor, nd)
        if nd != self._node:
            prev, self._node = self._node, nd
            try:
                return self._kernel_seq(reads, writes, flops, actor, name)
            finally:
                self._node = prev
        return self._kernel_seq(reads, writes, flops, actor, name)

    def _kernel_seq(self, reads, writes, flops, actor, name) -> float:
        self.epoch += 1
        t0 = self.clock
        tr = self.prof.traffic()
        local_bytes = 0.0
        remote_h2d = 0.0
        remote_d2h = 0.0
        remote_slow = 0.0  # managed thrash-mode remote reads (low bandwidth)
        # inter-node lanes (node-aware backends): exact integer byte/run
        # accumulators, converted to seconds once at the end of the launch
        lane_nvl_b = lane_nvl_n = lane_fab_b = lane_fab_n = 0
        lane_pol = None

        for is_write, ranges in ((False, reads), (True, writes)):
            for a, lo, hi in ranges:
                assert not a.freed, a.name
                if a.table is None:  # explicit: device-local always
                    local_bytes += hi - lo
                    tr.device_local += hi - lo
                    continue
                t = a.table
                p0, p1 = t.page_range(lo, hi)
                if p1 <= p0:
                    continue
                # stamp the access BEFORE first-touch: an eviction triggered
                # while mapping this extent's unmapped tail must see the
                # already-resident head as part of the current step's working
                # set (else a single coalesced range can self-evict its head)
                t.touch_range(p0, p1, self.epoch, is_write)
                self._first_touch(a, p0, p1, actor)

                # pre-access migration (fault-driven paths); the returned
                # context (e.g. managed's thrash-mode flag) feeds the charge
                # classification below
                ctx = a.policy.on_access(self, a, p0, p1, actor)

                # account access traffic against current residency: per-run
                # clipped bytes (boundary pages clip to [lo, hi); exact ints,
                # so the float sum is order-independent and bit-identical to
                # the dense per-page path)
                rs, re_, rv = t.tier_runs(p0, p1)
                if a.policy.node_aware:
                    # (node, tier)-encoded locations: hand the policy the
                    # exact per-run clipped integer bytes and let it route
                    # local / C2C / inter-node lanes through the topology
                    rb = t.span_bytes(rs, re_)
                    rb[0] = t.clipped_extent_bytes(
                        int(rs[0]), int(re_[0]), lo, hi)
                    rb[-1] = t.clipped_extent_bytes(
                        int(rs[-1]), int(re_[-1]), lo, hi)
                    l_b, h2d_b, d2h_b, slow_b, lanes = \
                        a.policy.charge_access_runs(
                            self, a, actor, is_write, ctx, rs, re_, rv, rb,
                            self._node)
                    lane_nvl_b += lanes[0]
                    lane_nvl_n += lanes[1]
                    lane_fab_b += lanes[2]
                    lane_fab_n += lanes[3]
                    lane_pol = a.policy
                else:
                    dm = rv == int(Tier.DEVICE)
                    if len(rs) == 1:  # extent fully resident on one tier
                        tot = float(t.clipped_extent_bytes(p0, p1, lo, hi))
                        dev_b, host_b = (tot, 0.0) if dm[0] else (0.0, tot)
                    else:
                        rb = t.span_bytes(rs, re_).astype(np.float64)
                        rb[0] = t.clipped_extent_bytes(int(rs[0]), int(re_[0]), lo, hi)
                        rb[-1] = t.clipped_extent_bytes(int(rs[-1]), int(re_[-1]), lo, hi)
                        dev_b = float(rb[dm].sum())
                        host_b = float(rb[~dm].sum())
                    l_b, h2d_b, d2h_b, slow_b = a.policy.charge_access(
                        self, a, actor, is_write, ctx, rs, re_, dm, dev_b, host_b)
                local_bytes += l_b
                remote_h2d += h2d_b
                remote_d2h += d2h_b
                remote_slow += slow_b

        bw = self.hw.device_bw if actor is Actor.GPU else self.hw.host_bw
        t_local = local_bytes / bw
        eff = self.hw.remote_efficiency
        t_remote = (remote_h2d / (self.hw.link_h2d * eff)
                    + remote_d2h / (self.hw.link_d2h * eff)
                    + remote_slow / (self.hw.link_h2d
                                     * self.hw.managed_thrash_efficiency))
        if lane_pol is not None:
            # one conversion per launch over the exact integer lane totals
            # — the batched engine applies the identical expression per item
            t_remote += lane_pol.lanes_time(
                self, (lane_nvl_b, lane_nvl_n, lane_fab_b, lane_fab_n))
        t_compute = flops / self.hw.flops_rate
        # async prefetch issued before this kernel overlaps with it
        t_kernel = max(t_local, t_remote, t_compute, self._pending_overlap)
        self._pending_overlap = 0.0
        self._charge(t_kernel + self.hw.kernel_launch)
        self._sample()
        dt = self.clock - t0
        self.prof.record_kernel(name, dt)
        return dt

    # --------------------------------------------------------- batched kernel
    def kernel_batch(self, items: Sequence) -> List[float]:
        """Model a batch of kernel steps in one engine pass.

        ``items`` are ``(name, reads, writes, flops, actor[, node])`` tuples
        with raw Ranges (launch_batch resolves buffer views down to this;
        a missing node defaults to the ambient on_node() node). The
        batch is charged in one vectorized sweep over run intersections —
        per-launch Python dispatch (range walks, per-extent tier_runs,
        profiler calls) is hoisted into array math over all extents at
        once. Semantics are bit-identical to looping :meth:`kernel`:

        * every touched (allocation, actor) hull must be certified by the
          policy's ``batch_ready`` hook — placement provably frozen for the
          whole batch (no first touch, no faults/migrations/evictions, no
          counter-threshold *drains* — bumps still accrue) — else the whole
          batch falls back to the sequential loop, which is identical by
          construction;
        * byte math reproduces the boundary-page clip quirks of
          ``clipped_extent_bytes`` exactly (all values exact integers, so
          float accumulation order cannot diverge);
        * LRU epochs land as max-over-covering-extents (== last writer),
          counter bumps collapse k identical bumps into one k-fold bump
          (same crossings, same pending set, same notifications);
        * the profiler finalization loop replays _charge/_sample/
          record_kernel float-op for float-op per item.
        """
        amb = self._node
        items = [it if len(it) == 6 else (*it, amb) for it in items]
        if self._trace is not None:
            # one batch event; suppress inner recording (the fallback loops
            # kernel(), which would otherwise double-record every launch)
            self._trace.on_batch(items)
            saved, self._trace = self._trace, None
            try:
                return self._kernel_batch(items)
            finally:
                self._trace = saved
        return self._kernel_batch(items)

    @staticmethod
    def _batch_loc_bytes(t: BlockTable, rs, re_, rv, p0s, p1s, los, his, h1):
        """Per-(extent, location) clipped bytes + overlapping-run counts over
        the frozen tier runs — the node-aware generalization of the two-tier
        device-prefix math in _kernel_batch. Columns are keyed by the sorted
        distinct location values ``uloc``. Every entry is an exact integer
        with span_bytes/clipped_extent_bytes semantics (tail-page and
        boundary-clip quirks included), so downstream accumulation order
        cannot diverge from the sequential engine."""
        uloc = np.unique(rv)
        col = np.searchsorted(uloc, rv)
        K = len(uloc)
        E = len(p0s)
        ps = t.page_size
        ar = np.arange(E)
        # per-location prefix sums of full-run bytes; two searchsorteds per
        # extent + boundary partials give bytes per (extent, location)
        M1 = np.zeros((len(rs), K), np.int64)
        M1[np.arange(len(rs)), col] = (re_ - rs) * ps
        cum = np.vstack((np.zeros((1, K), np.int64),
                         np.cumsum(M1, axis=0)))
        ja = np.searchsorted(rs, p0s, "right") - 1
        jb = np.searchsorted(rs, p1s, "right") - 1
        nb = cum[jb] - cum[ja]
        np.add.at(nb, (ar, col[jb]), (p1s - rs[jb]) * ps)
        np.subtract.at(nb, (ar, col[ja]), (p0s - rs[ja]) * ps)
        j1 = np.searchsorted(rs, p1s - 1, "right") - 1  # run of last page
        if h1 == t.num_pages:
            tm = p1s == t.num_pages
            if tm.any():
                np.add.at(nb, (ar[tm], col[j1][tm]), t.tail_bytes - ps)
        # boundary clips charge against the location owning the boundary page
        np.subtract.at(nb, (ar, col[ja]), los - p0s * ps)
        np.subtract.at(nb, (ar, col[j1]), p1s * ps - his)
        # overlapping-run counts per (extent, location): inter-node lanes
        # pay a per-contiguous-transfer latency, so the policy needs counts
        nr = np.empty((E, K), np.int64)
        for c in range(K):
            m = col == c
            nr[:, c] = (np.searchsorted(rs[m], p1s, "left")
                        - np.searchsorted(re_[m], p0s, "right"))
        return nb, nr, uloc

    def _kernel_batch(self, items: Sequence) -> List[float]:
        n = len(items)
        if n == 0:
            return []
        # ---- pass 1: flatten to per-allocation extent rows ----------------
        # side-effect-free: the fallback below must start from clean state
        groups: Dict[int, Tuple[Allocation, list]] = {}
        explicit_loc = [0] * n
        explicit_tot = 0
        GPU = Actor.GPU
        item_gpu = np.empty(n, bool)
        flops_arr = np.empty(n, np.float64)
        for i, (name, reads, writes, flops, actor, nd) in enumerate(items):
            gpu = 1 if actor is GPU else 0
            item_gpu[i] = gpu
            flops_arr[i] = flops
            for is_write, ranges in ((0, reads), (1, writes)):
                for a, lo, hi in ranges:
                    assert not a.freed, a.name
                    t = a.table
                    if t is None:  # explicit: device-local always
                        explicit_loc[i] += hi - lo
                        explicit_tot += hi - lo
                        continue
                    # page_range inlined (hot): Actor.GPU == 1, so the gpu
                    # flag doubles as the actor id in the row
                    assert 0 <= lo <= hi <= t.nbytes, (lo, hi, t.nbytes)
                    if lo == hi:
                        continue
                    ps = t.page_size
                    g = groups.get(id(a))
                    if g is None:
                        groups[id(a)] = g = (a, [])
                    g[1].append((lo // ps, -(-hi // ps), lo, hi, i,
                                 is_write, gpu, nd))
        # ---- pass 2: certify every (allocation, actor) hull ---------------
        certified = True
        prepped = []
        for a, rows in groups.values():
            M = np.asarray(rows, np.int64)
            acs = M[:, 6]
            for ac in (1, 0):
                m = acs == ac
                if not m.any():
                    continue
                h0 = int(M[m, 0].min())
                h1 = int(M[m, 1].max())
                if not a.policy.batch_ready(self, a, h0, h1, Actor(ac)):
                    certified = False
                    break
            if not certified:
                break
            prepped.append((a, M))
        if not certified:  # conformance fallback: the sequential engine
            return [self.kernel(reads=r, writes=w, flops=f, actor=ac,
                                name=nm, node=nd)
                    for nm, r, w, f, ac, nd in items]
        # ---- fast path: one vectorized charge pass per allocation ---------
        E0 = self.epoch
        loc_item = np.zeros(n, np.float64)
        h2d_item = np.zeros(n, np.float64)
        d2h_item = np.zeros(n, np.float64)
        slow_item = np.zeros(n, np.float64)
        lane_item = None  # (n, 4) exact-int lane accumulators, on demand
        lane_pol = None
        for a, M in prepped:
            t = a.table
            p0s, p1s = M[:, 0], M[:, 1]
            los, his = M[:, 2], M[:, 3]
            idx = M[:, 4]
            wr = M[:, 5].astype(bool)
            gpu = M[:, 6].astype(bool)
            h0, h1 = int(p0s.min()), int(p1s.max())
            rs, re_, rv = t.tier_runs(h0, h1)
            ps = t.page_size
            if a.policy.node_aware:
                nb, nr, uloc = self._batch_loc_bytes(t, rs, re_, rv, p0s,
                                                     p1s, los, his, h1)
                l_b, h2d_b, d2h_b, slow_b, lanes = \
                    a.policy.charge_access_batch_runs(
                        self, a, gpu, wr, M[:, 7], uloc, nb, nr)
                if lane_item is None:
                    lane_item = np.zeros((n, 4), np.float64)
                lane_pol = a.policy
                for c in range(4):
                    lane_item[:, c] += np.bincount(idx, weights=lanes[:, c],
                                                   minlength=n)
                loc_item += np.bincount(idx, weights=l_b, minlength=n)
                h2d_item += np.bincount(idx, weights=h2d_b, minlength=n)
                d2h_item += np.bincount(idx, weights=d2h_b, minlength=n)
                slow_item += np.bincount(idx, weights=slow_b, minlength=n)
                t.touch_batch(p0s, p1s, E0 + 1 + idx, wr)
                continue
            dev = rv == int(Tier.DEVICE)
            # device-byte prefix over the frozen tier runs: two searchsorteds
            # per extent replace a per-extent tier_runs walk
            cum = np.concatenate(([0], np.cumsum(
                np.where(dev, (re_ - rs) * ps, 0))))
            ja = np.searchsorted(rs, p0s, "right") - 1
            jb = np.searchsorted(rs, p1s, "right") - 1
            devb = (cum[jb] + np.where(dev[jb], (p1s - rs[jb]) * ps, 0)
                    - cum[ja] - np.where(dev[ja], (p0s - rs[ja]) * ps, 0))
            totb = (p1s - p0s) * ps
            j1 = np.searchsorted(rs, p1s - 1, "right") - 1  # run of last page
            if h1 == t.num_pages:
                # span_bytes/range_bytes semantics: extents reaching the
                # final (possibly partial) page count tail_bytes for it
                tadj = t.tail_bytes - ps
                tm = p1s == t.num_pages
                totb = totb + np.where(tm, tadj, 0)
                devb = devb + np.where(tm & dev[j1], tadj, 0)
            # boundary-page clips charge against the tier that owns the
            # boundary page — including clipped_extent_bytes' pinned quirk
            # (the tail clip uses the full-page overhang even on a partial
            # final page, possibly driving that side negative)
            headclip = los - p0s * ps
            tailclip = p1s * ps - his
            d0, d1 = dev[ja], dev[j1]
            dev_b = (devb - np.where(d0, headclip, 0)
                     - np.where(d1, tailclip, 0))
            host_b = (totb - devb - np.where(~d0, headclip, 0)
                      - np.where(~d1, tailclip, 0))
            l_b, h2d_b, d2h_b, slow_b = a.policy.charge_access_batch(
                self, a, gpu, wr, p0s, p1s, dev_b, host_b)
            loc_item += np.bincount(idx, weights=l_b, minlength=n)
            h2d_item += np.bincount(idx, weights=h2d_b, minlength=n)
            d2h_item += np.bincount(idx, weights=d2h_b, minlength=n)
            slow_item += np.bincount(idx, weights=slow_b, minlength=n)
            t.touch_batch(p0s, p1s, E0 + 1 + idx, wr)
        if explicit_tot:
            self.prof.traffic().device_local += explicit_tot
            loc_item += np.asarray(explicit_loc, np.float64)
        self.epoch = E0 + n
        # ---- per-item times (same float expressions as kernel()) ----------
        hw = self.hw
        t_local = loc_item / np.where(item_gpu, hw.device_bw, hw.host_bw)
        eff = hw.remote_efficiency
        t_remote = (h2d_item / (hw.link_h2d * eff)
                    + d2h_item / (hw.link_d2h * eff)
                    + slow_item / (hw.link_h2d * hw.managed_thrash_efficiency))
        if lane_pol is not None:
            # same fixed-association expression as the sequential engine's
            # per-launch lanes_time, applied per item
            t_remote = t_remote + lane_pol.lanes_time_batch(self, lane_item)
        t_kern = np.maximum(np.maximum(t_local, t_remote),
                            flops_arr / hw.flops_rate)
        # ---- finalization: replay _charge/_sample/record_kernel exactly ---
        # residency is frozen across a certified batch, so every sample
        # carries the same totals and the peaks update once
        prof = self.prof
        hb = self._host_bytes
        devtot = self._device_bytes + prof.driver_baseline
        if hb > prof._peak_host:
            prof._peak_host = hb
        if devtot > prof._peak_device:
            prof._peak_device = devtot
        timeline = prof.timeline
        ktimes, kcounts = prof.kernel_times, prof.kernel_counts
        pt, phase = prof.phase_times, prof.phase
        acc = pt[phase]
        kl = hw.kernel_launch
        ov = self._pending_overlap
        self._pending_overlap = 0.0
        clock = self.clock
        tk = t_kern.tolist()
        dts = []
        for i, it in enumerate(items):
            tki = tk[i]
            if i == 0 and ov > tki:  # async prefetch overlaps the first item
                tki = ov
            s = tki + kl
            c1 = clock + s
            dt = c1 - clock
            clock = c1
            acc += s
            timeline.append((c1, hb, devtot))
            name = it[0]
            ktimes[name] += dt
            kcounts[name] += 1
            dts.append(dt)
        self.clock = clock
        pt[phase] = acc
        return dts

    def drain_dirty(self, ranges: Sequence) -> int:
        """Checkpoint-style writeback: charge a d2h drain of every *dirty*
        device-resident byte covered by ``ranges`` (BufferViews, UMBuffers
        or raw Ranges) WITHOUT moving pages or clearing dirty state — the
        snapshot reads the live copy, so placement and every subsequent
        charge are exactly what they would have been without the save
        (CheckpointManager.save of UM-backed state goes through this).
        Table-less explicit blobs are skipped: their authoritative copy is
        the host staging side. Returns the bytes charged."""
        total = 0
        for r in ranges:
            a, lo, hi = _as_range(r, Actor.GPU)
            assert not a.freed, a.name
            t = a.table
            if t is None or hi <= lo:
                continue
            p0, p1 = t.page_range(lo, hi)
            rs, re_, rv = t.tier_runs(p0, p1)
            # device side: odd (node, tier) location encodings; plain
            # tables reduce to Tier.DEVICE == 1
            m = (rv > 0) & (rv % 2 == 1)
            if not m.any():
                continue
            nb = t.dirty_bytes(rs[m], re_[m])
            if nb:
                self._charge(nb / self.hw.link_d2h)
                self.prof.traffic().link_d2h += nb
                total += nb
        self._sample()
        return total

    # ------------------------------------------------------------- sync/misc
    def sync(self) -> float:
        """cudaDeviceSynchronize analogue: each live paged allocation's
        policy drains whatever it batches to sync points (the system
        backend's notification-pending delayed migrations, under its
        per-sync budget — O(runs), never O(pages))."""
        if self._trace is not None:
            self._trace.on_sync()
        t0 = self.clock
        if self._pending_overlap:  # flush un-overlapped async prefetches
            self._charge(self._pending_overlap)
            self._pending_overlap = 0.0
        for a in self.allocs.values():
            if a.freed or a.table is None:
                continue
            a.policy.on_sync(self, a)
        self._sample()
        return self.clock - t0

    def copy(self, a: Allocation, lo: int, hi: int, direction: str) -> float:
        """Explicit cudaMemcpy. direction: 'h2d' | 'd2h'."""
        if self._trace is not None:
            self._trace.on_copy(a.name, lo, hi, direction)
        nbytes = hi - lo
        bw = self.hw.link_h2d if direction == "h2d" else self.hw.link_d2h
        self._charge(nbytes / bw)
        tr = self.prof.traffic()
        if direction == "h2d":
            tr.link_h2d += nbytes
        else:
            tr.link_d2h += nbytes
        self._sample()
        return nbytes / bw

    def prefetch(self, a, lo: Optional[int] = None, hi: Optional[int] = None,
                 overlap: bool = False) -> float:
        """cudaMemPrefetchAsync analogue: migrate range to device.

        `a` is an Allocation with byte bounds lo/hi, or a BufferView/UMBuffer
        (bounds taken from the view). overlap=True models the async stream:
        the migration cost hides under the next kernel (charged as
        max(kernel, prefetch))."""
        if lo is None:
            a, lo, hi = _as_range(a, Actor.GPU)
        if self._trace is not None:
            self._trace.on_prefetch(a.name, lo, hi, overlap)
        t0 = self.clock
        assert a.table is not None, "prefetch needs a paged allocation"
        p0, p1 = a.table.page_range(lo, hi)
        self._first_touch(a, p0, p1, Actor.CPU)
        if overlap:
            saved = self.clock
            self._migrate_in_runs(a, (p0,), (p1,))
            self._pending_overlap += self.clock - saved
            # roll the clock back: the cost is deferred to the next kernel
            dt = self.clock - saved
            self.clock = saved
            self.prof.charge(-dt)
        else:
            self._migrate_in_runs(a, (p0,), (p1,))
        self._sample()
        return self.clock - t0

    def prefetch_async(self, ranges: Sequence) -> float:
        """Async multi-extent prefetch: promote each item — a raw
        (alloc, lo, hi) range or a BufferView — to the device ahead of the
        kernel that will read it. The migration cost accrues to
        ``_pending_overlap`` and hides under the next kernel (serve/engine.py
        promotes a resumed sequence's extents ahead of its decode turn
        through this). Returns the hidden seconds."""
        before = self._pending_overlap
        for r in ranges:
            a, lo, hi = _as_range(r, Actor.GPU)
            self.prefetch(a, lo, hi, overlap=True)
        return self._pending_overlap - before

    def demote(self, a, lo: Optional[int] = None,
               hi: Optional[int] = None) -> float:
        """Demote a range host-side (cudaMemPrefetchAsync-to-cpuDeviceId
        analogue): device-resident pages of [lo, hi) move to host memory,
        charged at the d2h link. Unmapped pages stay unmapped. The serve
        scheduler uses this to push a preempted sequence's KV pages out of
        HBM before its pool pages are handed to another sequence. Accepts a
        BufferView in place of (Allocation, lo, hi)."""
        if lo is None:
            a, lo, hi = _as_range(a, Actor.GPU)
        if self._spill_fail and a.policy.migratable:
            # all-or-nothing: raise before any charge or table mutation so
            # the caller's fallback starts from an untouched range
            raise HostSpillError(
                f"host spill of '{a.name}' [{lo}, {hi}) rejected: "
                "spill-failure window active")
        if self._trace is not None:
            self._trace.on_demote(a.name, lo, hi)
        t0 = self.clock
        assert a.table is not None, "demote needs a paged allocation"
        t = a.table
        p0, p1 = t.page_range(lo, hi)
        if a.pending is not None:
            # the caller is explicitly cold-marking this range: drop any
            # pending migration notifications so the next sync() doesn't
            # promote the just-demoted pages straight back to the device
            a.pending_count -= a.pending.count_nonzero(p0, p1)
            a.pending.set_range(p0, p1, 0)
        if a.policy.migratable:
            handled = a.policy.on_demote(self, a, p0, p1)
            if handled is not None:  # node-aware spill (possibly cross-node)
                self._sample()
                return self.clock - t0
        ds_, de_ = t.runs_of(Tier.DEVICE, p0, p1)
        if len(ds_) and a.policy.migratable:
            nbytes = int(t.span_bytes(ds_, de_).sum())
            npages = int((de_ - ds_).sum())
            self._apply_delta(t.move_runs(ds_, de_, Tier.HOST))
            t.clear_dirty(ds_, de_)
            tr = self.prof.traffic()
            tr.migrated_out += nbytes
            tr.link_d2h += nbytes
            self._charge(nbytes / self.hw.link_d2h
                         + self.hw.migrate_per_page * npages)
        self._sample()
        return self.clock - t0

    # ---------------------------------------------------------------- report
    def report(self) -> Dict[str, object]:
        rep = self.prof.report()
        rep["allocations"] = {
            name: {
                "nbytes": a.nbytes,
                "policy": a.policy.kind,
                "page_size": a.policy.page_size,
                "device_bytes": (a.device_bytes_explicit if a.table is None
                                 else a.table.residency_by_side()[1]),
                "host_bytes": (0 if a.table is None
                               else a.table.residency_by_side()[0]),
                "extents": (0 if a.table is None
                            else len(a.table.tier_runs()[0])),
                "freed": a.freed,
            }
            for name, a in self.allocs.items()
        }
        return rep
