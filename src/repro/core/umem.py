"""UnifiedMemory: the Grace Hopper unified-memory system as a composable runtime.

Models (and on real TPU backends, drives — see serve/paged.py and
optim/offload) a two-tier HBM/host memory system with:

  * lazy PTE creation + first-touch placement (system & managed),
  * direct remote access at fine granularity over the interconnect (system),
  * fault-driven on-demand migration + speculative prefetch (managed),
  * access-counter-based delayed migration with threshold notifications
    (system, §2.2.1), applied batch-wise at sync points,
  * LRU eviction under device-capacity pressure (managed) vs graceful remote
    access (system), reproducing the paper's oversubscription behavior (§7).

Applications interact through the typed buffer front-end — array() /
from_host() return UMBuffers whose numpy-style slices feed launch(),
staged(), prefetch() and demote() (see core/buffer.py and docs/memspace.md)
— while alloc/free, phase(), kernel() and copy() remain the raw runtime
surface the front-end lowers onto. Time is *modeled* via the HardwareModel
(this container has no GPU/TPU); correctness of the application math is
real JAX executed on CPU.

The hot path is extent-based: kernel() resolves each byte range to a
(lo_page, hi_page) extent once and every page-table operation under it —
first-touch mapping, LRU-epoch touches, fault/granule counting, speculative
prefetch expansion, LRU victim selection — is vectorized numpy over slice
views of the extent. Residency totals are cached (updated incrementally on
every map/move), so profiler sampling is O(1) per op instead of re-scanning
every allocation's tier array. The charge math is unchanged from the dense
per-page implementation — modeled times and traffic are bit-identical.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.buffer import BufferView, UMBuffer, as_view
from repro.core.hardware import GRACE_HOPPER, HardwareModel
from repro.core.pagetable import Actor, BlockTable, Tier
from repro.core.policy import PolicyConfig, system_policy
from repro.core.profiler import MemoryProfiler

Range = Tuple["Allocation", int, int]  # (alloc, lo, hi) byte range


def _as_range(r, actor: Actor) -> Range:
    """Launch/prefetch argument -> raw Range: BufferViews and UMBuffers
    resolve against the actor (CPU actors hit a staged buffer's host side);
    raw (alloc, lo, hi) tuples pass through untouched."""
    if isinstance(r, (BufferView, UMBuffer)):
        return as_view(r).resolve(actor)
    return r


@dataclass
class Allocation:
    name: str
    nbytes: int
    policy: PolicyConfig
    table: Optional[BlockTable]  # None for explicit (device-resident, no PTEs)
    device_bytes_explicit: int = 0
    pending: Optional[np.ndarray] = None  # system: notification-pending pages
    pending_count: int = 0  # fast-path: #True entries ever set minus cleared
    freed: bool = False


class OutOfDeviceMemory(RuntimeError):
    pass


class UnifiedMemory:
    def __init__(self, hw: HardwareModel = GRACE_HOPPER,
                 profiler: Optional[MemoryProfiler] = None,
                 staging_page_size: int = 64 * 1024):
        self.hw = hw
        self.prof = profiler or MemoryProfiler()
        self.clock = 0.0
        self.allocs: Dict[str, Allocation] = {}
        self.epoch = 0
        self._pending_overlap = 0.0  # async-prefetch seconds hidden under compute
        # page size of from_host() staging buffers under the explicit policy
        # (the host side of the cudaMalloc+malloc pair uses the *application's*
        # system page size, not a hard-wired default)
        self.staging_page_size = staging_page_size
        # cached residency over live allocations (kept in lockstep with every
        # BlockTable mutation; makes _sample O(1) per op)
        self._host_bytes = 0
        self._device_bytes = 0

    # ------------------------------------------------------------------ util
    def _charge(self, seconds: float) -> None:
        self.clock += seconds
        self.prof.charge(seconds)

    def _sample(self) -> None:
        self.prof.sample(self.clock, self._host_bytes, self._device_bytes)

    def _apply_delta(self, delta: Tuple[int, int]) -> None:
        self._host_bytes += delta[0]
        self._device_bytes += delta[1]

    def host_bytes(self) -> int:
        return self._host_bytes

    def device_bytes(self) -> int:
        return self._device_bytes

    def device_free(self) -> int:
        return self.hw.device_capacity - self._device_bytes

    def _recompute_residency(self) -> Tuple[int, int]:
        """Slow-path recount (tests assert it matches the cached totals)."""
        host = dev = 0
        for a in self.allocs.values():
            if a.freed:
                continue
            dev += a.device_bytes_explicit
            if a.table is not None:
                host += a.table.resident_bytes(Tier.HOST)
                dev += a.table.resident_bytes(Tier.DEVICE)
        return host, dev

    @contextlib.contextmanager
    def phase(self, name: str):
        prev = self.prof.phase
        self.prof.set_phase(name)
        try:
            yield
        finally:
            self.prof.set_phase(prev)

    # ----------------------------------------------------------------- alloc
    def alloc(self, name: str, nbytes: int, policy: PolicyConfig) -> Allocation:
        assert name not in self.allocs, f"duplicate alloc {name!r}"
        if policy.kind == "explicit":
            if nbytes > self.device_free():
                raise OutOfDeviceMemory(
                    f"cudaMalloc({name}): {nbytes} > free {self.device_free()}")
            a = Allocation(name, nbytes, policy, table=None, device_bytes_explicit=nbytes)
            self._device_bytes += nbytes
            self._charge(self.hw.alloc_per_page * -(-nbytes // policy.page_size))
        else:
            table = BlockTable(name, nbytes, policy.page_size)
            a = Allocation(name, nbytes, policy, table=table,
                           pending=np.zeros(table.num_pages, bool))
            # lazy PTEs: allocation itself only creates VMA bookkeeping
            self._charge(self.hw.alloc_per_page * min(table.num_pages, 64))
        self.allocs[name] = a
        self._sample()
        return a

    def free(self, a: Allocation) -> None:
        assert not a.freed
        if a.table is not None:
            mapped = a.table.num_pages - a.table.resident_pages(Tier.UNMAPPED)
            self._host_bytes -= a.table.resident_bytes(Tier.HOST)
            self._device_bytes -= a.table.resident_bytes(Tier.DEVICE)
            self._charge(self.hw.dealloc_per_page * mapped)
        else:
            self._device_bytes -= a.device_bytes_explicit
            self._charge(self.hw.dealloc_per_page *
                         -(-a.nbytes // a.policy.migration_granule))
        a.freed = True
        self._sample()

    def free_live(self, *, keep_reserved: bool = True) -> None:
        """Free every live allocation in allocation order. Names starting
        with ``__`` (harness-reserved, e.g. the oversubscription ballast)
        are kept unless keep_reserved=False."""
        for a in list(self.allocs.values()):
            if a.freed:
                continue
            if keep_reserved and a.name.startswith("__"):
                continue
            self.free(a)

    # -------------------------------------------------------------- buffers
    def array(self, name: str, shape, dtype, policy: PolicyConfig) -> UMBuffer:
        """Allocate a typed buffer: shape x dtype under `policy`.

        The buffer-centric analogue of alloc(): slices of the returned
        UMBuffer feed launch()/prefetch()/demote() instead of raw byte
        ranges. Device-only scratch and GPU-initialized data use this; data
        that originates host-side should use from_host()."""
        shape = (shape,) if isinstance(shape, (int, np.integer)) else tuple(shape)
        nbytes = int(np.prod(np.asarray(shape, np.int64))) * np.dtype(dtype).itemsize
        a = self.alloc(name, nbytes, policy)
        return UMBuffer(self, a, shape, dtype)

    def from_host(self, name: str, shape, dtype,
                  policy: PolicyConfig) -> UMBuffer:
        """A buffer whose contents originate on the host (CPU init).

        Under managed/system policies this is exactly array(): first-touch
        placement follows the CPU writer. Under the explicit policy it
        materializes the cudaMalloc + malloc pair — a device buffer plus a
        ``<name>__host`` staging buffer (at ``staging_page_size``, the
        application's system page size) — and launch() routes CPU-actor
        accesses to the staging side. um.staged() charges the h2d/d2h copies
        at phase boundaries."""
        buf = self.array(name, shape, dtype, policy)
        if policy.kind == "explicit":
            buf.host = self.alloc(
                name + "__host", buf.nbytes,
                system_policy(self.staging_page_size, auto_migrate=False))
        return buf

    def launch(self, name: str = "kernel", *, reads: Sequence = (),
               writes: Sequence = (), flops: float = 0.0,
               actor: Actor = Actor.GPU) -> float:
        """Buffer-level kernel launch: the tracked, policy-agnostic front
        door of kernel(). reads/writes take BufferViews (``buf[i:j]``,
        ``buf.rows(lo, hi)``) or whole UMBuffers; each resolves to exactly
        the byte extent the raw Range API would have used, so charges are
        bit-identical. CPU-actor accesses to from_host() buffers land in
        their explicit-policy staging allocation."""
        return self.kernel(
            reads=[_as_range(r, actor) for r in reads],
            writes=[_as_range(w, actor) for w in writes],
            flops=flops, actor=actor, name=name)

    @contextlib.contextmanager
    def staged(self, h2d: Sequence = (), d2h: Sequence = (), *,
               h2d_phase: str = "h2d", d2h_phase: str = "d2h"):
        """Explicit-policy staging boundary around a compute region.

        For every listed buffer/view under the *explicit* policy, charges the
        cudaMemcpy h2d copies on entry (phase `h2d_phase`) and the d2h copies
        on exit (phase `d2h_phase`), in list order. Buffers under managed or
        system policies pass through untouched — the same `with` block is the
        single code path for all three memory-management versions."""
        up = [as_view(v) for v in h2d]
        down = [as_view(v) for v in d2h]
        todo = [v for v in up if v.buf.policy.kind == "explicit"]
        if todo:
            with self.phase(h2d_phase):
                for v in todo:
                    self.copy(v.buf.alloc, v.lo, v.hi, "h2d")
        try:
            yield self
        finally:
            todo = [v for v in down if v.buf.policy.kind == "explicit"]
            if todo:
                with self.phase(d2h_phase):
                    for v in todo:
                        self.copy(v.buf.alloc, v.lo, v.hi, "d2h")

    # ------------------------------------------------------- page-level ops
    def _first_touch(self, a: Allocation, p0: int, p1: int, actor: Actor) -> None:
        """Lazily map the unmapped pages of extent [p0, p1) to the toucher's tier."""
        t = a.table
        unmapped = t.tier[p0:p1] == int(Tier.UNMAPPED)
        n_unmapped = int(np.count_nonzero(unmapped))
        if n_unmapped == 0:
            return
        tr = self.prof.traffic()
        if actor is Actor.GPU and a.policy.kind == "system":
            # GPU first-touch of system memory: SMMU fault -> OS on the CPU
            # creates the PTE (the §5.1.2 init bottleneck)
            self._charge(self.hw.pte_init_gpu * n_unmapped)
            tr.pte_inits_gpu += n_unmapped
        elif actor is Actor.GPU:
            # managed: first-touch maps straight into the GPU page table
            granules = max(1, n_unmapped * t.page_size // a.policy.migration_granule)
            self._charge(self.hw.pte_init_cpu * granules)
            tr.pte_inits_gpu += n_unmapped
        else:
            self._charge(self.hw.pte_init_cpu * n_unmapped)
            tr.pte_inits_cpu += n_unmapped
        tier = actor.home_tier
        if tier is Tier.DEVICE:
            need = t._mask_bytes(p0, p1, unmapped)
            if need > self.device_free():
                if a.policy.kind == "managed":
                    self._evict_lru(need - self.device_free(), exclude=a)
                    if need > self.device_free():
                        tier = Tier.HOST  # spill the remainder
                else:
                    tier = Tier.HOST  # system memory: map host-side instead
        self._apply_delta(t.map_mask(p0, p1, unmapped, tier))

    def _evict_lru(self, need_bytes: int, exclude: Optional[Allocation] = None) -> None:
        """Evict LRU managed device-resident pages until need_bytes freed.

        `exclude` shields the faulting allocation's *current-step* working set
        (pages with last_access_epoch == the in-flight kernel's epoch) from
        eviction — the faulting allocation never self-evicts pages the same
        kernel step just touched. Colder pages of the same allocation stay
        evictable: real UVM evicts an oversubscribed allocation's own LRU
        pages (the paper's §7 streaming window), so excluding the whole
        allocation would be wrong. Known trade-off: a kernel touching several
        managed allocations under pressure may still evict *another*
        allocation's same-step pages (LRU order makes them last-resort
        victims); widening the epoch shield to every allocation is semantically
        attractive but shifts the reproduced fig11 oversubscription curves
        further from the paper baseline, so it is deliberately not done here.
        """
        cands: List[Allocation] = [
            a for a in self.allocs.values()
            if not a.freed and a.table is not None and a.policy.kind == "managed"]
        epochs, sizes, page_idx, alloc_idx = [], [], [], []
        for i, a in enumerate(cands):
            pages = a.table.pages_in(Tier.DEVICE)
            if a is exclude and len(pages):
                pages = pages[a.table.last_access_epoch[pages] < self.epoch]
            if len(pages) == 0:
                continue
            epochs.append(a.table.last_access_epoch[pages])
            sizes.append(a.table.page_bytes(pages))
            page_idx.append(pages)
            alloc_idx.append(np.full(len(pages), i, np.int32))
        if not epochs:
            return
        epochs = np.concatenate(epochs)
        sizes = np.concatenate(sizes)
        page_idx = np.concatenate(page_idx)
        alloc_idx = np.concatenate(alloc_idx)
        # stable sort == python sort of (epoch) with (alloc, page) insertion
        # order as tiebreak: the LRU victim order
        order = np.argsort(epochs, kind="stable")
        csum = np.cumsum(sizes[order])
        # take victims while bytes freed *before* each victim is < need
        chosen = order[(csum - sizes[order]) < need_bytes]
        tr = self.prof.traffic()
        chosen_alloc = alloc_idx[chosen]
        uniq, first = np.unique(chosen_alloc, return_index=True)
        for ai in uniq[np.argsort(first)]:  # first-appearance (charge) order
            a = cands[int(ai)]
            pages = page_idx[chosen[chosen_alloc == ai]]
            # clean pages are just unmapped; only dirty pages copy back
            dirty = pages[a.table.dirty[pages]]
            nbytes = int(a.table.page_bytes(dirty).sum()) if len(dirty) else 0
            self._apply_delta(a.table.move_pages(pages, Tier.HOST))
            a.table.dirty[pages] = False
            self._charge(nbytes / self.hw.link_d2h + self.hw.migrate_per_page * len(pages))
            tr.migrated_out += nbytes
            tr.link_d2h += nbytes

    def _migrate_in(self, a: Allocation, pages: np.ndarray) -> int:
        """Move host-resident pages to device, evicting if managed. Returns bytes."""
        t = a.table
        pages = pages[t.tier[pages] == int(Tier.HOST)]
        if len(pages) == 0:
            return 0
        need = int(t.page_bytes(pages).sum())
        if need > self.device_free():
            if a.policy.kind == "managed":
                self._evict_lru(need - self.device_free(), exclude=a)
            if need > self.device_free():
                fit = np.cumsum(t.page_bytes(pages)) <= self.device_free()
                pages = pages[fit]
                need = int(t.page_bytes(pages).sum()) if len(pages) else 0
                if need == 0:
                    return 0
        self._apply_delta(t.move_pages(pages, Tier.DEVICE))
        tr = self.prof.traffic()
        tr.migrated_in += need
        tr.link_h2d += need
        self._charge(need / self.hw.link_h2d + self.hw.migrate_per_page * len(pages))
        return need

    # ---------------------------------------------------------------- kernel
    def kernel(self, *, reads: Sequence[Range] = (), writes: Sequence[Range] = (),
               flops: float = 0.0, actor: Actor = Actor.GPU,
               name: str = "kernel") -> float:
        """Model one kernel/loop-step. Returns modeled seconds."""
        self.epoch += 1
        t0 = self.clock
        tr = self.prof.traffic()
        local_bytes = 0.0
        remote_h2d = 0.0
        remote_d2h = 0.0
        remote_slow = 0.0  # managed thrash-mode remote reads (low bandwidth)

        for is_write, ranges in ((False, reads), (True, writes)):
            for a, lo, hi in ranges:
                assert not a.freed, a.name
                if a.table is None:  # explicit: device-local always
                    local_bytes += hi - lo
                    tr.device_local += hi - lo
                    continue
                t = a.table
                p0, p1 = t.page_range(lo, hi)
                if p1 <= p0:
                    continue
                # stamp the access BEFORE first-touch: an eviction triggered
                # while mapping this extent's unmapped tail must see the
                # already-resident head as part of the current step's working
                # set (else a single coalesced range can self-evict its head)
                t.touch_range(p0, p1, self.epoch, is_write)
                self._first_touch(a, p0, p1, actor)

                thrashing = False
                if a.policy.kind == "managed" and actor is Actor.GPU:
                    # fault-driven on-demand migration (+ speculative prefetch);
                    # when the touched working set cannot fit even after
                    # evicting every other managed page, the driver stops
                    # migrating and serves remote reads (paper §7 Fig. 12)
                    host_mask = t.tier[p0:p1] == int(Tier.HOST)
                    n_host = int(np.count_nonzero(host_mask))
                    if n_host:
                        ws = t._mask_bytes(p0, p1, host_mask)
                        evictable = sum(
                            o.table.resident_bytes(Tier.DEVICE)
                            for o in self.allocs.values()
                            if o is not a and not o.freed and o.table is not None
                            and o.policy.kind == "managed")
                        thrashing = ws > self.device_free() + evictable
                    if n_host and not thrashing:
                        gran_pages = max(1, a.policy.migration_granule // t.page_size)
                        host_pages = p0 + np.flatnonzero(host_mask)
                        granules = np.unique(host_pages // gran_pages)
                        nfaults = len(granules)
                        tr.faults += nfaults
                        self._charge(self.hw.page_fault_cost * nfaults)
                        # speculative prefetch: each faulting granule drags in
                        # the next `pf` granules — expand the granule set and
                        # explode to pages fully vectorized
                        pf = a.policy.speculative_prefetch
                        gall = np.unique(
                            (granules[:, None] + np.arange(pf)).ravel())
                        gall = gall[gall <= t.num_pages // gran_pages]
                        mig = (gall[:, None] * gran_pages
                               + np.arange(gran_pages)).ravel()
                        self._migrate_in(a, mig[mig < t.num_pages])
                elif a.policy.kind == "managed" and actor is Actor.CPU:
                    dev_mask = t.tier[p0:p1] == int(Tier.DEVICE)
                    n_dev = int(np.count_nonzero(dev_mask))
                    if n_dev:
                        gran_pages = max(1, a.policy.migration_granule // t.page_size)
                        dev_pages = p0 + np.flatnonzero(dev_mask)
                        granules = np.unique(dev_pages // gran_pages)
                        tr.faults += len(granules)
                        self._charge(self.hw.page_fault_cost * len(granules))
                        nbytes = t._mask_bytes(p0, p1, dev_mask)
                        self._apply_delta(t.move_pages(dev_pages, Tier.HOST))
                        tr.migrated_out += nbytes
                        tr.link_d2h += nbytes
                        self._charge(nbytes / self.hw.link_d2h
                                     + self.hw.migrate_per_page * n_dev)

                # account access traffic against current residency
                on_dev = t.tier[p0:p1] == int(Tier.DEVICE)
                n_dev_pages = int(np.count_nonzero(on_dev))
                if n_dev_pages in (0, p1 - p0):
                    # extent fully resident on one tier: the clipped page-byte
                    # sum telescopes to hi - lo (minus the tail-page clip the
                    # dense path applies when the final partial page is hit)
                    tot = float(hi - lo)
                    if p1 == t.num_pages and p1 * t.page_size > hi:
                        tot -= t.page_size - t.tail_bytes
                    dev_b, host_b = ((tot, 0.0) if n_dev_pages else (0.0, tot))
                else:
                    pb = t.page_bytes_slice(p0, p1).astype(np.float64)
                    # clip to the actual [lo,hi) range on the boundary pages
                    pb[0] -= lo - p0 * t.page_size
                    if p1 * t.page_size > hi:
                        pb[-1] -= p1 * t.page_size - hi
                    dev_b = float(pb[on_dev].sum())
                    host_b = float(pb[~on_dev].sum())
                if actor is Actor.GPU:
                    local_bytes += dev_b
                    tr.device_local += int(dev_b)
                    if thrashing:
                        remote_slow += host_b
                        tr.link_h2d += int(host_b)
                        tr.remote_h2d += int(host_b)
                    elif is_write:
                        remote_d2h += host_b
                        tr.link_d2h += int(host_b)
                        tr.remote_d2h += int(host_b)
                    else:
                        remote_h2d += host_b
                        tr.link_h2d += int(host_b)
                        tr.remote_h2d += int(host_b)
                    if a.policy.kind == "system" and a.policy.auto_migrate and host_b:
                        host_mask = ~on_dev
                        sizes = t.page_bytes_slice(p0, p1)[host_mask]
                        txn = np.maximum(1, sizes // self.hw.remote_access_grain
                                         ).astype(np.int32)
                        gc = t.gpu_counter[p0:p1]
                        before = gc[host_mask]
                        gc[host_mask] = before + txn
                        crossed = (before < a.policy.counter_threshold) & (
                            before + txn >= a.policy.counter_threshold)
                        n_newly = int(np.count_nonzero(crossed))
                        if n_newly:
                            newly = p0 + np.flatnonzero(host_mask)[crossed]
                            a.pending[newly] = True
                            a.pending_count += n_newly
                            tr.notifications += n_newly
                else:
                    local_bytes += host_b
                    tr.host_local += int(host_b)
                    remote_d2h += dev_b
                    tr.link_d2h += int(dev_b)

        bw = self.hw.device_bw if actor is Actor.GPU else self.hw.host_bw
        t_local = local_bytes / bw
        eff = self.hw.remote_efficiency
        t_remote = (remote_h2d / (self.hw.link_h2d * eff)
                    + remote_d2h / (self.hw.link_d2h * eff)
                    + remote_slow / (self.hw.link_h2d
                                     * self.hw.managed_thrash_efficiency))
        t_compute = flops / self.hw.flops_rate
        # async prefetch issued before this kernel overlaps with it
        t_kernel = max(t_local, t_remote, t_compute, self._pending_overlap)
        self._pending_overlap = 0.0
        self._charge(t_kernel + self.hw.kernel_launch)
        self._sample()
        return self.clock - t0

    # ------------------------------------------------------------- sync/misc
    def sync(self) -> float:
        """cudaDeviceSynchronize analogue: apply pending delayed migrations."""
        t0 = self.clock
        if self._pending_overlap:  # flush un-overlapped async prefetches
            self._charge(self._pending_overlap)
            self._pending_overlap = 0.0
        for a in self.allocs.values():
            if a.freed or a.table is None or a.policy.kind != "system":
                continue
            if not a.policy.auto_migrate or a.pending is None:
                continue
            if a.pending_count == 0:  # invariant: count 0 <=> all False
                continue
            pages = np.nonzero(a.pending & (a.table.tier == int(Tier.HOST)))[0]
            if len(pages) == 0:
                a.pending[:] = False
                a.pending_count = 0
                continue
            budget = a.policy.max_migration_bytes_per_sync
            sizes = a.table.page_bytes(pages)
            keep = np.cumsum(sizes) <= budget
            self._migrate_in(a, pages[keep])
            a.pending[pages[keep]] = False
            a.pending_count -= int(np.count_nonzero(keep))
        self._sample()
        return self.clock - t0

    def copy(self, a: Allocation, lo: int, hi: int, direction: str) -> float:
        """Explicit cudaMemcpy. direction: 'h2d' | 'd2h'."""
        nbytes = hi - lo
        bw = self.hw.link_h2d if direction == "h2d" else self.hw.link_d2h
        self._charge(nbytes / bw)
        tr = self.prof.traffic()
        if direction == "h2d":
            tr.link_h2d += nbytes
        else:
            tr.link_d2h += nbytes
        self._sample()
        return nbytes / bw

    def prefetch(self, a, lo: Optional[int] = None, hi: Optional[int] = None,
                 overlap: bool = False) -> float:
        """cudaMemPrefetchAsync analogue: migrate range to device.

        `a` is an Allocation with byte bounds lo/hi, or a BufferView/UMBuffer
        (bounds taken from the view). overlap=True models the async stream:
        the migration cost hides under the next kernel (charged as
        max(kernel, prefetch))."""
        if lo is None:
            a, lo, hi = _as_range(a, Actor.GPU)
        t0 = self.clock
        assert a.table is not None, "prefetch needs a paged allocation"
        p0, p1 = a.table.page_range(lo, hi)
        self._first_touch(a, p0, p1, Actor.CPU)
        pages = np.arange(p0, p1)
        if overlap:
            saved = self.clock
            self._migrate_in(a, pages)
            self._pending_overlap += self.clock - saved
            # roll the clock back: the cost is deferred to the next kernel
            dt = self.clock - saved
            self.clock = saved
            self.prof.charge(-dt)
        else:
            self._migrate_in(a, pages)
        self._sample()
        return self.clock - t0

    def prefetch_async(self, ranges: Sequence) -> float:
        """Async multi-extent prefetch: promote each item — a raw
        (alloc, lo, hi) range or a BufferView — to the device ahead of the
        kernel that will read it. The migration cost accrues to
        ``_pending_overlap`` and hides under the next kernel (serve/engine.py
        promotes a resumed sequence's extents ahead of its decode turn
        through this). Returns the hidden seconds."""
        before = self._pending_overlap
        for r in ranges:
            a, lo, hi = _as_range(r, Actor.GPU)
            self.prefetch(a, lo, hi, overlap=True)
        return self._pending_overlap - before

    def demote(self, a, lo: Optional[int] = None,
               hi: Optional[int] = None) -> float:
        """Demote a range host-side (cudaMemPrefetchAsync-to-cpuDeviceId
        analogue): device-resident pages of [lo, hi) move to host memory,
        charged at the d2h link. Unmapped pages stay unmapped. The serve
        scheduler uses this to push a preempted sequence's KV pages out of
        HBM before its pool pages are handed to another sequence. Accepts a
        BufferView in place of (Allocation, lo, hi)."""
        if lo is None:
            a, lo, hi = _as_range(a, Actor.GPU)
        t0 = self.clock
        assert a.table is not None, "demote needs a paged allocation"
        t = a.table
        p0, p1 = t.page_range(lo, hi)
        if a.pending is not None:
            # the caller is explicitly cold-marking this range: drop any
            # pending migration notifications so the next sync() doesn't
            # promote the just-demoted pages straight back to the device
            a.pending_count -= int(np.count_nonzero(a.pending[p0:p1]))
            a.pending[p0:p1] = False
        pages = p0 + np.flatnonzero(t.tier[p0:p1] == int(Tier.DEVICE))
        if len(pages):
            nbytes = int(t.page_bytes(pages).sum())
            self._apply_delta(t.move_pages(pages, Tier.HOST))
            t.dirty[pages] = False
            tr = self.prof.traffic()
            tr.migrated_out += nbytes
            tr.link_d2h += nbytes
            self._charge(nbytes / self.hw.link_d2h
                         + self.hw.migrate_per_page * len(pages))
        self._sample()
        return self.clock - t0

    # ---------------------------------------------------------------- report
    def report(self) -> Dict[str, object]:
        rep = self.prof.report()
        rep["allocations"] = {
            name: {
                "nbytes": a.nbytes,
                "policy": a.policy.kind,
                "page_size": a.policy.page_size,
                "device_bytes": (a.device_bytes_explicit if a.table is None
                                 else a.table.resident_bytes(Tier.DEVICE)),
                "host_bytes": (0 if a.table is None
                               else a.table.resident_bytes(Tier.HOST)),
                "extents": (0 if a.table is None
                            else len(a.table.tier_runs()[0])),
                "freed": a.freed,
            }
            for name, a in self.allocs.items()
        }
        return rep
