"""UnifiedMemory: the Grace Hopper unified-memory system as a composable runtime.

Models (and on real TPU backends, drives — see serve/paged.py and
optim/offload) a two-tier HBM/host memory system with:

  * lazy PTE creation + first-touch placement (system & managed),
  * direct remote access at fine granularity over the interconnect (system),
  * fault-driven on-demand migration + speculative prefetch (managed),
  * access-counter-based delayed migration with threshold notifications
    (system, §2.2.1), applied batch-wise at sync points,
  * LRU eviction under device-capacity pressure (managed) vs graceful remote
    access (system), reproducing the paper's oversubscription behavior (§7).

Applications interact through the typed buffer front-end — array() /
from_host() return UMBuffers whose numpy-style slices feed launch(),
staged(), prefetch() and demote() (see core/buffer.py and docs/memspace.md)
— while alloc/free, phase(), kernel() and copy() remain the raw runtime
surface the front-end lowers onto. Time is *modeled* via the HardwareModel
(this container has no GPU/TPU); correctness of the application math is
real JAX executed on CPU.

The hot path is *run-compressed*: kernel() resolves each byte range to a
(lo_page, hi_page) extent once, and every page-table operation under it —
first-touch mapping, LRU-epoch touches, fault/granule counting, speculative
prefetch expansion, access-counter bumps, LRU victim selection, sync-point
notification draining — works on run intersections of the extent with the
table's interval metadata (see core/pagetable.py and core/runs.py). Cost is
O(runs overlapping the extent), never O(pages in extent): a uniform 16M-page
working set is one run. Residency totals are cached (updated incrementally
on every map/move), so profiler sampling is O(1) per op. The charge math is
unchanged from the dense per-page implementation — modeled times and
traffic are bit-identical (enforced by scripts/check_parity.py)."""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.buffer import BufferView, UMBuffer, as_view
from repro.core.hardware import GRACE_HOPPER, HardwareModel
from repro.core.pagetable import Actor, BlockTable, Tier
from repro.core.policy import PolicyConfig, system_policy
from repro.core.profiler import MemoryProfiler
from repro.core.runs import RunMap, union_runs

Range = Tuple["Allocation", int, int]  # (alloc, lo, hi) byte range


def _as_range(r, actor: Actor) -> Range:
    """Launch/prefetch argument -> raw Range: BufferViews and UMBuffers
    resolve against the actor (CPU actors hit a staged buffer's host side);
    raw (alloc, lo, hi) tuples pass through untouched."""
    if isinstance(r, (BufferView, UMBuffer)):
        return as_view(r).resolve(actor)
    return r


@dataclass
class Allocation:
    name: str
    nbytes: int
    policy: PolicyConfig
    table: Optional[BlockTable]  # None for explicit (device-resident, no PTEs)
    device_bytes_explicit: int = 0
    pending: Optional[RunMap] = None  # system: notification-pending page runs
    pending_count: int = 0  # fast-path: #pending pages ever set minus cleared
    freed: bool = False


class OutOfDeviceMemory(RuntimeError):
    pass


class UnifiedMemory:
    def __init__(self, hw: HardwareModel = GRACE_HOPPER,
                 profiler: Optional[MemoryProfiler] = None,
                 staging_page_size: int = 64 * 1024):
        self.hw = hw
        self.prof = profiler or MemoryProfiler()
        self.clock = 0.0
        self.allocs: Dict[str, Allocation] = {}
        self.epoch = 0
        self._pending_overlap = 0.0  # async-prefetch seconds hidden under compute
        # page size of from_host() staging buffers under the explicit policy
        # (the host side of the cudaMalloc+malloc pair uses the *application's*
        # system page size, not a hard-wired default)
        self.staging_page_size = staging_page_size
        # cached residency over live allocations (kept in lockstep with every
        # BlockTable mutation; makes _sample O(1) per op)
        self._host_bytes = 0
        self._device_bytes = 0

    # ------------------------------------------------------------------ util
    def _charge(self, seconds: float) -> None:
        self.clock += seconds
        self.prof.charge(seconds)

    def _sample(self) -> None:
        self.prof.sample(self.clock, self._host_bytes, self._device_bytes)

    def _apply_delta(self, delta: Tuple[int, int]) -> None:
        self._host_bytes += delta[0]
        self._device_bytes += delta[1]

    def host_bytes(self) -> int:
        return self._host_bytes

    def device_bytes(self) -> int:
        return self._device_bytes

    def device_free(self) -> int:
        return self.hw.device_capacity - self._device_bytes

    def _recompute_residency(self) -> Tuple[int, int]:
        """Slow-path recount (tests assert it matches the cached totals):
        re-derives each table's residency from its run structure."""
        host = dev = 0
        for a in self.allocs.values():
            if a.freed:
                continue
            dev += a.device_bytes_explicit
            if a.table is not None:
                _, nbytes = a.table.recount()
                host += int(nbytes[int(Tier.HOST) + 1])
                dev += int(nbytes[int(Tier.DEVICE) + 1])
        return host, dev

    @contextlib.contextmanager
    def phase(self, name: str):
        prev = self.prof.phase
        self.prof.set_phase(name)
        try:
            yield
        finally:
            self.prof.set_phase(prev)

    # ----------------------------------------------------------------- alloc
    def alloc(self, name: str, nbytes: int, policy: PolicyConfig) -> Allocation:
        assert name not in self.allocs, f"duplicate alloc {name!r}"
        if policy.kind == "explicit":
            if nbytes > self.device_free():
                raise OutOfDeviceMemory(
                    f"cudaMalloc({name}): {nbytes} > free {self.device_free()}")
            a = Allocation(name, nbytes, policy, table=None, device_bytes_explicit=nbytes)
            self._device_bytes += nbytes
            self._charge(self.hw.alloc_per_page * -(-nbytes // policy.page_size))
        else:
            table = BlockTable(name, nbytes, policy.page_size)
            a = Allocation(name, nbytes, policy, table=table,
                           pending=RunMap(table.num_pages, 0, np.int8))
            # lazy PTEs: allocation itself only creates VMA bookkeeping
            self._charge(self.hw.alloc_per_page * min(table.num_pages, 64))
        self.allocs[name] = a
        self._sample()
        return a

    def free(self, a: Allocation) -> None:
        assert not a.freed
        if a.table is not None:
            mapped = a.table.num_pages - a.table.resident_pages(Tier.UNMAPPED)
            self._host_bytes -= a.table.resident_bytes(Tier.HOST)
            self._device_bytes -= a.table.resident_bytes(Tier.DEVICE)
            self._charge(self.hw.dealloc_per_page * mapped)
        else:
            self._device_bytes -= a.device_bytes_explicit
            self._charge(self.hw.dealloc_per_page *
                         -(-a.nbytes // a.policy.migration_granule))
        a.freed = True
        self._sample()

    def free_live(self, *, keep_reserved: bool = True) -> None:
        """Free every live allocation in allocation order. Names starting
        with ``__`` (harness-reserved, e.g. the oversubscription ballast)
        are kept unless keep_reserved=False."""
        for a in list(self.allocs.values()):
            if a.freed:
                continue
            if keep_reserved and a.name.startswith("__"):
                continue
            self.free(a)

    # -------------------------------------------------------------- buffers
    def array(self, name: str, shape, dtype, policy: PolicyConfig) -> UMBuffer:
        """Allocate a typed buffer: shape x dtype under `policy`.

        The buffer-centric analogue of alloc(): slices of the returned
        UMBuffer feed launch()/prefetch()/demote() instead of raw byte
        ranges. Device-only scratch and GPU-initialized data use this; data
        that originates host-side should use from_host()."""
        shape = (shape,) if isinstance(shape, (int, np.integer)) else tuple(shape)
        nbytes = int(np.prod(np.asarray(shape, np.int64))) * np.dtype(dtype).itemsize
        a = self.alloc(name, nbytes, policy)
        return UMBuffer(self, a, shape, dtype)

    def from_host(self, name: str, shape, dtype,
                  policy: PolicyConfig) -> UMBuffer:
        """A buffer whose contents originate on the host (CPU init).

        Under managed/system policies this is exactly array(): first-touch
        placement follows the CPU writer. Under the explicit policy it
        materializes the cudaMalloc + malloc pair — a device buffer plus a
        ``<name>__host`` staging buffer (at ``staging_page_size``, the
        application's system page size) — and launch() routes CPU-actor
        accesses to the staging side. um.staged() charges the h2d/d2h copies
        at phase boundaries."""
        buf = self.array(name, shape, dtype, policy)
        if policy.kind == "explicit":
            buf.host = self.alloc(
                name + "__host", buf.nbytes,
                system_policy(self.staging_page_size, auto_migrate=False))
        return buf

    def launch(self, name: str = "kernel", *, reads: Sequence = (),
               writes: Sequence = (), flops: float = 0.0,
               actor: Actor = Actor.GPU) -> float:
        """Buffer-level kernel launch: the tracked, policy-agnostic front
        door of kernel(). reads/writes take BufferViews (``buf[i:j]``,
        ``buf.rows(lo, hi)``) or whole UMBuffers; each resolves to exactly
        the byte extent the raw Range API would have used, so charges are
        bit-identical. CPU-actor accesses to from_host() buffers land in
        their explicit-policy staging allocation."""
        return self.kernel(
            reads=[_as_range(r, actor) for r in reads],
            writes=[_as_range(w, actor) for w in writes],
            flops=flops, actor=actor, name=name)

    @contextlib.contextmanager
    def staged(self, h2d: Sequence = (), d2h: Sequence = (), *,
               h2d_phase: str = "h2d", d2h_phase: str = "d2h"):
        """Explicit-policy staging boundary around a compute region.

        For every listed buffer/view under the *explicit* policy, charges the
        cudaMemcpy h2d copies on entry (phase `h2d_phase`) and the d2h copies
        on exit (phase `d2h_phase`), in list order. Buffers under managed or
        system policies pass through untouched — the same `with` block is the
        single code path for all three memory-management versions."""
        up = [as_view(v) for v in h2d]
        down = [as_view(v) for v in d2h]
        todo = [v for v in up if v.buf.policy.kind == "explicit"]
        if todo:
            with self.phase(h2d_phase):
                for v in todo:
                    self.copy(v.buf.alloc, v.lo, v.hi, "h2d")
        try:
            yield self
        finally:
            todo = [v for v in down if v.buf.policy.kind == "explicit"]
            if todo:
                with self.phase(d2h_phase):
                    for v in todo:
                        self.copy(v.buf.alloc, v.lo, v.hi, "d2h")

    # ------------------------------------------------------- page-level ops
    def _first_touch(self, a: Allocation, p0: int, p1: int, actor: Actor) -> None:
        """Lazily map the unmapped pages of extent [p0, p1) to the toucher's tier."""
        t = a.table
        if t.resident_pages(Tier.UNMAPPED) == 0:
            return  # O(1) steady-state exit: the whole table is mapped
        n_unmapped, need = t.unmapped_stats(p0, p1)
        if n_unmapped == 0:
            return
        tr = self.prof.traffic()
        if actor is Actor.GPU and a.policy.kind == "system":
            # GPU first-touch of system memory: SMMU fault -> OS on the CPU
            # creates the PTE (the §5.1.2 init bottleneck)
            self._charge(self.hw.pte_init_gpu * n_unmapped)
            tr.pte_inits_gpu += n_unmapped
        elif actor is Actor.GPU:
            # managed: first-touch maps straight into the GPU page table
            granules = max(1, n_unmapped * t.page_size // a.policy.migration_granule)
            self._charge(self.hw.pte_init_cpu * granules)
            tr.pte_inits_gpu += n_unmapped
        else:
            self._charge(self.hw.pte_init_cpu * n_unmapped)
            tr.pte_inits_cpu += n_unmapped
        tier = actor.home_tier
        if tier is Tier.DEVICE:
            if need > self.device_free():
                if a.policy.kind == "managed":
                    self._evict_lru(need - self.device_free(), exclude=a)
                    if need > self.device_free():
                        tier = Tier.HOST  # spill the remainder
                else:
                    tier = Tier.HOST  # system memory: map host-side instead
        self._apply_delta(t.map_unmapped(p0, p1, tier))

    def _evict_lru(self, need_bytes: int, exclude: Optional[Allocation] = None) -> None:
        """Evict LRU managed device-resident pages until need_bytes freed.

        Victim selection is run-based: each candidate contributes its
        (device-tier run ∩ LRU-epoch run) pieces — O(runs), not O(pages) —
        and a stable sort of the pieces by epoch reproduces the dense
        per-page LRU order exactly (pages inside a piece are consecutive and
        share an epoch; ties keep (alloc, page) insertion order). The
        boundary piece is split at the page where the freed-bytes cumsum
        crosses `need_bytes`.

        `exclude` shields the faulting allocation's *current-step* working set
        (pages with last_access_epoch == the in-flight kernel's epoch) from
        eviction — the faulting allocation never self-evicts pages the same
        kernel step just touched. Colder pages of the same allocation stay
        evictable: real UVM evicts an oversubscribed allocation's own LRU
        pages (the paper's §7 streaming window), so excluding the whole
        allocation would be wrong. Known trade-off: a kernel touching several
        managed allocations under pressure may still evict *another*
        allocation's same-step pages (LRU order makes them last-resort
        victims); widening the epoch shield to every allocation is semantically
        attractive but shifts the reproduced fig11 oversubscription curves
        further from the paper baseline, so it is deliberately not done here.
        """
        cands: List[Allocation] = [
            a for a in self.allocs.values()
            if not a.freed and a.table is not None and a.policy.kind == "managed"]
        # cached-counter early-out: no managed allocation has device-resident
        # pages -> nothing to evict, no run/array work at all
        if not any(a.table.resident_pages(Tier.DEVICE) for a in cands):
            return
        piece_s, piece_e, piece_ep, piece_ai = [], [], [], []
        for i, a in enumerate(cands):
            t = a.table
            if t.resident_pages(Tier.DEVICE) == 0:
                continue
            ds, de = t.runs_of(Tier.DEVICE)
            for s0, e0 in zip(ds, de):
                es, ee, ev = t.epoch_runs(int(s0), int(e0))
                if a is exclude:
                    m = ev < self.epoch
                    es, ee, ev = es[m], ee[m], ev[m]
                if len(es):
                    piece_s.append(es)
                    piece_e.append(ee)
                    piece_ep.append(ev)
                    piece_ai.append(np.full(len(es), i, np.int64))
        if not piece_s:
            return
        S = np.concatenate(piece_s)
        E = np.concatenate(piece_e)
        EP = np.concatenate(piece_ep)
        AI = np.concatenate(piece_ai)
        # stable sort of epoch-uniform pieces == the dense per-page stable
        # argsort (pieces were built in (alloc, page) insertion order)
        order = np.argsort(EP, kind="stable")
        S, E, AI = S[order], E[order], AI[order]
        ps_of = np.array([c.table.page_size for c in cands], np.int64)
        np_of = np.array([c.table.num_pages for c in cands], np.int64)
        tb_of = np.array([c.table.tail_bytes for c in cands], np.int64)
        sizes = (E - S) * ps_of[AI]
        tailm = E == np_of[AI]
        sizes[tailm] += tb_of[AI[tailm]] - ps_of[AI[tailm]]
        csum = np.cumsum(sizes)
        before = csum - sizes
        take = before < need_bytes
        S, E, AI = S[take], E[take], AI[take]
        if len(S) == 0:
            return
        # boundary piece: victims are taken while the bytes freed *before*
        # each page is < need — a page-count prefix of the piece
        room = need_bytes - int(before[np.flatnonzero(take)[-1]])
        psz = int(ps_of[AI[-1]])
        k = min(int(E[-1] - S[-1]), -(-room // psz))
        E[-1] = S[-1] + k
        tr = self.prof.traffic()
        uniq, first = np.unique(AI, return_index=True)
        for ai in uniq[np.argsort(first)]:  # first-appearance (charge) order
            a = cands[int(ai)]
            m = AI == ai
            s_list, e_list = S[m], E[m]
            npages = int((e_list - s_list).sum())
            # clean pages are just unmapped; only dirty pages copy back
            nbytes = a.table.dirty_bytes(s_list, e_list)
            self._apply_delta(a.table.move_runs(s_list, e_list, Tier.HOST))
            a.table.clear_dirty(s_list, e_list)
            self._charge(nbytes / self.hw.link_d2h + self.hw.migrate_per_page * npages)
            tr.migrated_out += nbytes
            tr.link_d2h += nbytes

    def _prefix_fit_runs(self, t: BlockTable, starts: np.ndarray,
                         ends: np.ndarray, budget: int):
        """Largest page-prefix of the runs whose per-page byte cumsum stays
        <= budget (the run analogue of ``pages[cumsum(sizes) <= budget]``)."""
        sizes = t.span_bytes(starts, ends)
        csum = np.cumsum(sizes)
        nfull = int(np.searchsorted(csum, budget, "right"))
        if nfull == len(starts):
            return starts, ends
        cb = int(csum[nfull - 1]) if nfull else 0
        k = max(0, (budget - cb) // t.page_size)
        if k == 0:
            return starts[:nfull], ends[:nfull]
        s = starts[:nfull + 1].copy()
        e = ends[:nfull + 1].copy()
        e[-1] = s[-1] + k
        return s, e

    def _migrate_in_runs(self, a: Allocation, starts, ends) -> int:
        """Move the host-resident pages of the given ascending [s, e) spans
        to the device, evicting if managed. Returns bytes migrated."""
        t = a.table
        hs, he = [], []
        for s0, e0 in zip(starts, ends):
            rs, re_ = t.runs_of(Tier.HOST, int(s0), int(e0))
            hs.append(rs)
            he.append(re_)
        if not hs:
            return 0
        hs = np.concatenate(hs)
        he = np.concatenate(he)
        if len(hs) == 0:
            return 0
        need = int(t.span_bytes(hs, he).sum())
        if need > self.device_free():
            if a.policy.kind == "managed":
                self._evict_lru(need - self.device_free(), exclude=a)
            if need > self.device_free():
                hs, he = self._prefix_fit_runs(t, hs, he, self.device_free())
                if len(hs) == 0:
                    return 0
                need = int(t.span_bytes(hs, he).sum())
                if need == 0:
                    return 0
        self._apply_delta(t.move_runs(hs, he, Tier.DEVICE))
        tr = self.prof.traffic()
        tr.migrated_in += need
        tr.link_h2d += need
        npages = int((he - hs).sum())
        self._charge(need / self.hw.link_h2d + self.hw.migrate_per_page * npages)
        return need

    def _counter_bump(self, a: Allocation, p0: int, p1: int, txn: int) -> None:
        """Bump the GPU access counter by `txn` for every page of [p0, p1);
        pages crossing the policy threshold go notification-pending."""
        thr = a.policy.counter_threshold
        cs, ce, cv = a.table.bump_counter(p0, p1, txn)
        crossed = (cv < thr) & (cv + txn >= thr)
        if crossed.any():
            n_newly = int((ce[crossed] - cs[crossed]).sum())
            for s0, e0 in zip(cs[crossed], ce[crossed]):
                a.pending.set_range(int(s0), int(e0), 1)
            a.pending_count += n_newly
            self.prof.traffic().notifications += n_newly

    # ---------------------------------------------------------------- kernel
    def kernel(self, *, reads: Sequence[Range] = (), writes: Sequence[Range] = (),
               flops: float = 0.0, actor: Actor = Actor.GPU,
               name: str = "kernel") -> float:
        """Model one kernel/loop-step. Returns modeled seconds."""
        self.epoch += 1
        t0 = self.clock
        tr = self.prof.traffic()
        local_bytes = 0.0
        remote_h2d = 0.0
        remote_d2h = 0.0
        remote_slow = 0.0  # managed thrash-mode remote reads (low bandwidth)

        for is_write, ranges in ((False, reads), (True, writes)):
            for a, lo, hi in ranges:
                assert not a.freed, a.name
                if a.table is None:  # explicit: device-local always
                    local_bytes += hi - lo
                    tr.device_local += hi - lo
                    continue
                t = a.table
                p0, p1 = t.page_range(lo, hi)
                if p1 <= p0:
                    continue
                # stamp the access BEFORE first-touch: an eviction triggered
                # while mapping this extent's unmapped tail must see the
                # already-resident head as part of the current step's working
                # set (else a single coalesced range can self-evict its head)
                t.touch_range(p0, p1, self.epoch, is_write)
                self._first_touch(a, p0, p1, actor)

                thrashing = False
                if a.policy.kind == "managed" and actor is Actor.GPU:
                    # fault-driven on-demand migration (+ speculative prefetch);
                    # when the touched working set cannot fit even after
                    # evicting every other managed page, the driver stops
                    # migrating and serves remote reads (paper §7 Fig. 12)
                    hs, he = t.runs_of(Tier.HOST, p0, p1)
                    if len(hs):
                        ws = int(t.span_bytes(hs, he).sum())
                        evictable = sum(
                            o.table.resident_bytes(Tier.DEVICE)
                            for o in self.allocs.values()
                            if o is not a and not o.freed and o.table is not None
                            and o.policy.kind == "managed")
                        thrashing = ws > self.device_free() + evictable
                    if len(hs) and not thrashing:
                        gran_pages = max(1, a.policy.migration_granule // t.page_size)
                        # faulting granules: the host runs projected onto
                        # granule space (overlaps/adjacency merged)
                        gs, ge = union_runs(hs // gran_pages,
                                            (he - 1) // gran_pages + 1)
                        nfaults = int((ge - gs).sum())
                        tr.faults += nfaults
                        self._charge(self.hw.page_fault_cost * nfaults)
                        # speculative prefetch: each faulting granule drags in
                        # the next `pf` granules — expand the granule runs and
                        # clip to the table
                        pf = a.policy.speculative_prefetch
                        if pf > 0:
                            gs, ge = union_runs(gs, ge + pf - 1)
                            gmax = t.num_pages // gran_pages + 1
                            ge = np.minimum(ge, gmax)
                            keep = gs < ge
                            ms = gs[keep] * gran_pages
                            me = np.minimum(ge[keep] * gran_pages, t.num_pages)
                            self._migrate_in_runs(a, ms, me)
                elif a.policy.kind == "managed" and actor is Actor.CPU:
                    ds_, de_ = t.runs_of(Tier.DEVICE, p0, p1)
                    if len(ds_):
                        n_dev = int((de_ - ds_).sum())
                        gran_pages = max(1, a.policy.migration_granule // t.page_size)
                        gs, ge = union_runs(ds_ // gran_pages,
                                            (de_ - 1) // gran_pages + 1)
                        nfaults = int((ge - gs).sum())
                        tr.faults += nfaults
                        self._charge(self.hw.page_fault_cost * nfaults)
                        nbytes = int(t.span_bytes(ds_, de_).sum())
                        self._apply_delta(t.move_runs(ds_, de_, Tier.HOST))
                        tr.migrated_out += nbytes
                        tr.link_d2h += nbytes
                        self._charge(nbytes / self.hw.link_d2h
                                     + self.hw.migrate_per_page * n_dev)

                # account access traffic against current residency: per-run
                # clipped bytes (boundary pages clip to [lo, hi); exact ints,
                # so the float sum is order-independent and bit-identical to
                # the dense per-page path)
                rs, re_, rv = t.tier_runs(p0, p1)
                dm = rv == int(Tier.DEVICE)
                if len(rs) == 1:  # extent fully resident on one tier
                    tot = float(t.clipped_extent_bytes(p0, p1, lo, hi))
                    dev_b, host_b = (tot, 0.0) if dm[0] else (0.0, tot)
                else:
                    rb = t.span_bytes(rs, re_).astype(np.float64)
                    rb[0] = t.clipped_extent_bytes(int(rs[0]), int(re_[0]), lo, hi)
                    rb[-1] = t.clipped_extent_bytes(int(rs[-1]), int(re_[-1]), lo, hi)
                    dev_b = float(rb[dm].sum())
                    host_b = float(rb[~dm].sum())
                if actor is Actor.GPU:
                    local_bytes += dev_b
                    tr.device_local += int(dev_b)
                    if thrashing:
                        remote_slow += host_b
                        tr.link_h2d += int(host_b)
                        tr.remote_h2d += int(host_b)
                    elif is_write:
                        remote_d2h += host_b
                        tr.link_d2h += int(host_b)
                        tr.remote_d2h += int(host_b)
                    else:
                        remote_h2d += host_b
                        tr.link_h2d += int(host_b)
                        tr.remote_h2d += int(host_b)
                    if a.policy.kind == "system" and a.policy.auto_migrate and host_b:
                        # remote-access counters: one bump per host run; the
                        # (possibly partial) tail page has its own txn count
                        grain = self.hw.remote_access_grain
                        txn_full = max(1, t.page_size // grain)
                        txn_tail = max(1, t.tail_bytes // grain)
                        for s0, e0 in zip(rs[~dm], re_[~dm]):
                            s0, e0 = int(s0), int(e0)
                            if e0 == t.num_pages and txn_tail != txn_full:
                                if e0 - 1 > s0:
                                    self._counter_bump(a, s0, e0 - 1, txn_full)
                                self._counter_bump(a, e0 - 1, e0, txn_tail)
                            else:
                                self._counter_bump(a, s0, e0, txn_full)
                else:
                    local_bytes += host_b
                    tr.host_local += int(host_b)
                    remote_d2h += dev_b
                    tr.link_d2h += int(dev_b)

        bw = self.hw.device_bw if actor is Actor.GPU else self.hw.host_bw
        t_local = local_bytes / bw
        eff = self.hw.remote_efficiency
        t_remote = (remote_h2d / (self.hw.link_h2d * eff)
                    + remote_d2h / (self.hw.link_d2h * eff)
                    + remote_slow / (self.hw.link_h2d
                                     * self.hw.managed_thrash_efficiency))
        t_compute = flops / self.hw.flops_rate
        # async prefetch issued before this kernel overlaps with it
        t_kernel = max(t_local, t_remote, t_compute, self._pending_overlap)
        self._pending_overlap = 0.0
        self._charge(t_kernel + self.hw.kernel_launch)
        self._sample()
        return self.clock - t0

    # ------------------------------------------------------------- sync/misc
    def sync(self) -> float:
        """cudaDeviceSynchronize analogue: apply pending delayed migrations.

        The notification-pending state is drained as runs: pending runs are
        intersected with the host-tier runs, the per-sync migration budget
        takes a page-prefix of the result, and the migrated runs are cleared
        from the pending map — O(runs), never O(pages)."""
        t0 = self.clock
        if self._pending_overlap:  # flush un-overlapped async prefetches
            self._charge(self._pending_overlap)
            self._pending_overlap = 0.0
        for a in self.allocs.values():
            if a.freed or a.table is None or a.policy.kind != "system":
                continue
            if not a.policy.auto_migrate or a.pending is None:
                continue
            if a.pending_count == 0:  # invariant: count 0 <=> no pending runs
                continue
            t = a.table
            ps_, pe_ = a.pending.nonzero_runs()
            hs, he = [], []
            for s0, e0 in zip(ps_, pe_):
                rs, re_ = t.runs_of(Tier.HOST, int(s0), int(e0))
                hs.append(rs)
                he.append(re_)
            hs = np.concatenate(hs) if hs else np.empty(0, np.int64)
            he = np.concatenate(he) if he else np.empty(0, np.int64)
            if len(hs) == 0:
                a.pending.clear()
                a.pending_count = 0
                continue
            budget = a.policy.max_migration_bytes_per_sync
            ks, ke = self._prefix_fit_runs(t, hs, he, budget)
            self._migrate_in_runs(a, ks, ke)
            for s0, e0 in zip(ks, ke):
                a.pending.set_range(int(s0), int(e0), 0)
            a.pending_count -= int((ke - ks).sum())
        self._sample()
        return self.clock - t0

    def copy(self, a: Allocation, lo: int, hi: int, direction: str) -> float:
        """Explicit cudaMemcpy. direction: 'h2d' | 'd2h'."""
        nbytes = hi - lo
        bw = self.hw.link_h2d if direction == "h2d" else self.hw.link_d2h
        self._charge(nbytes / bw)
        tr = self.prof.traffic()
        if direction == "h2d":
            tr.link_h2d += nbytes
        else:
            tr.link_d2h += nbytes
        self._sample()
        return nbytes / bw

    def prefetch(self, a, lo: Optional[int] = None, hi: Optional[int] = None,
                 overlap: bool = False) -> float:
        """cudaMemPrefetchAsync analogue: migrate range to device.

        `a` is an Allocation with byte bounds lo/hi, or a BufferView/UMBuffer
        (bounds taken from the view). overlap=True models the async stream:
        the migration cost hides under the next kernel (charged as
        max(kernel, prefetch))."""
        if lo is None:
            a, lo, hi = _as_range(a, Actor.GPU)
        t0 = self.clock
        assert a.table is not None, "prefetch needs a paged allocation"
        p0, p1 = a.table.page_range(lo, hi)
        self._first_touch(a, p0, p1, Actor.CPU)
        if overlap:
            saved = self.clock
            self._migrate_in_runs(a, (p0,), (p1,))
            self._pending_overlap += self.clock - saved
            # roll the clock back: the cost is deferred to the next kernel
            dt = self.clock - saved
            self.clock = saved
            self.prof.charge(-dt)
        else:
            self._migrate_in_runs(a, (p0,), (p1,))
        self._sample()
        return self.clock - t0

    def prefetch_async(self, ranges: Sequence) -> float:
        """Async multi-extent prefetch: promote each item — a raw
        (alloc, lo, hi) range or a BufferView — to the device ahead of the
        kernel that will read it. The migration cost accrues to
        ``_pending_overlap`` and hides under the next kernel (serve/engine.py
        promotes a resumed sequence's extents ahead of its decode turn
        through this). Returns the hidden seconds."""
        before = self._pending_overlap
        for r in ranges:
            a, lo, hi = _as_range(r, Actor.GPU)
            self.prefetch(a, lo, hi, overlap=True)
        return self._pending_overlap - before

    def demote(self, a, lo: Optional[int] = None,
               hi: Optional[int] = None) -> float:
        """Demote a range host-side (cudaMemPrefetchAsync-to-cpuDeviceId
        analogue): device-resident pages of [lo, hi) move to host memory,
        charged at the d2h link. Unmapped pages stay unmapped. The serve
        scheduler uses this to push a preempted sequence's KV pages out of
        HBM before its pool pages are handed to another sequence. Accepts a
        BufferView in place of (Allocation, lo, hi)."""
        if lo is None:
            a, lo, hi = _as_range(a, Actor.GPU)
        t0 = self.clock
        assert a.table is not None, "demote needs a paged allocation"
        t = a.table
        p0, p1 = t.page_range(lo, hi)
        if a.pending is not None:
            # the caller is explicitly cold-marking this range: drop any
            # pending migration notifications so the next sync() doesn't
            # promote the just-demoted pages straight back to the device
            a.pending_count -= a.pending.count_nonzero(p0, p1)
            a.pending.set_range(p0, p1, 0)
        ds_, de_ = t.runs_of(Tier.DEVICE, p0, p1)
        if len(ds_):
            nbytes = int(t.span_bytes(ds_, de_).sum())
            npages = int((de_ - ds_).sum())
            self._apply_delta(t.move_runs(ds_, de_, Tier.HOST))
            t.clear_dirty(ds_, de_)
            tr = self.prof.traffic()
            tr.migrated_out += nbytes
            tr.link_d2h += nbytes
            self._charge(nbytes / self.hw.link_d2h
                         + self.hw.migrate_per_page * npages)
        self._sample()
        return self.clock - t0

    # ---------------------------------------------------------------- report
    def report(self) -> Dict[str, object]:
        rep = self.prof.report()
        rep["allocations"] = {
            name: {
                "nbytes": a.nbytes,
                "policy": a.policy.kind,
                "page_size": a.policy.page_size,
                "device_bytes": (a.device_bytes_explicit if a.table is None
                                 else a.table.resident_bytes(Tier.DEVICE)),
                "host_bytes": (0 if a.table is None
                               else a.table.resident_bytes(Tier.HOST)),
                "extents": (0 if a.table is None
                            else len(a.table.tier_runs()[0])),
                "freed": a.freed,
            }
            for name, a in self.allocs.items()
        }
        return rep
