"""Memory utilization profiler (paper §3.2).

Samples per-tier resident bytes over *modeled* time whenever the runtime
state changes — the RSS / nvidia-smi analogue — and aggregates per-phase
durations and traffic counters (the Fig. 4/5 timelines and Fig. 10/12
traffic plots are drawn from this)."""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class TrafficCounters:
    link_h2d: int = 0  # bytes over the interconnect, host->device
    link_d2h: int = 0
    remote_h2d: int = 0  # GPU kernel reads served remotely from host memory
    remote_d2h: int = 0  # GPU kernel writes landing remotely in host memory
    device_local: int = 0  # bytes served from device memory
    host_local: int = 0  # bytes served from host memory (CPU-side access)
    faults: int = 0
    notifications: int = 0
    migrated_in: int = 0  # bytes migrated host->device
    migrated_out: int = 0
    pte_inits_cpu: int = 0
    pte_inits_gpu: int = 0

    def merge(self, other: "TrafficCounters") -> None:
        for k, v in vars(other).items():
            setattr(self, k, getattr(self, k) + v)


@dataclass
class MemoryProfiler:
    driver_baseline: int = 600 * 1024 * 1024  # nvidia-smi baseline (§3.2)
    timeline: List[Tuple[float, int, int]] = field(default_factory=list)
    phase_times: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    phase_traffic: Dict[str, TrafficCounters] = field(
        default_factory=lambda: defaultdict(TrafficCounters))
    # per-kernel-label aggregation (modeled seconds + launch counts): labels
    # default to operand-derived names (see UnifiedMemory.launch), so two
    # different unnamed kernels never collapse into one ambiguous bucket
    kernel_times: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    kernel_counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    # open-ended integer side counters (e.g. the cluster layer's inter-node
    # byte lanes). Kept OUT of TrafficCounters on purpose: the golden parity
    # fixture snapshots vars(TrafficCounters), so new backends extend the
    # traffic vocabulary here without perturbing single-node snapshots.
    extra: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    _phase: str = "default"
    # running peaks: sample() is O(1) per op (the runtime hands it cached
    # residency totals, never re-scanning per-allocation tier arrays) and
    # report() no longer walks the whole timeline
    _peak_host: int = 0
    _peak_device: int = 0

    def set_phase(self, name: str) -> None:
        self._phase = name

    @property
    def phase(self) -> str:
        return self._phase

    def sample(self, t: float, host_bytes: int, device_bytes: int) -> None:
        dev = device_bytes + self.driver_baseline
        self.timeline.append((t, host_bytes, dev))
        if host_bytes > self._peak_host:
            self._peak_host = host_bytes
        if dev > self._peak_device:
            self._peak_device = dev

    def charge(self, seconds: float) -> None:
        self.phase_times[self._phase] += seconds

    def record_kernel(self, name: str, seconds: float) -> None:
        """Attribute one kernel's modeled step time to its label."""
        self.kernel_times[name] += seconds
        self.kernel_counts[name] += 1

    def traffic(self) -> TrafficCounters:
        return self.phase_traffic[self._phase]

    def total_time(self) -> float:
        return sum(self.phase_times.values())

    def report(self) -> Dict[str, object]:
        total = TrafficCounters()
        for t in self.phase_traffic.values():
            total.merge(t)
        return {
            "phase_times_s": dict(self.phase_times),
            "kernel_times_s": dict(self.kernel_times),
            "kernel_counts": dict(self.kernel_counts),
            "total_time_s": self.total_time(),
            "traffic": {k: vars(v) for k, v in self.phase_traffic.items()},
            "traffic_total": vars(total),
            "traffic_extra": dict(self.extra),
            # share of GPU kernel read bytes served remotely from host memory
            # — the oversubscription benchmarks' headline degradation metric
            # (counted at the kernel remote-access sites, so migrations and
            # explicit cudaMemcpy traffic never pollute it)
            "remote_access_share": total.remote_h2d / max(
                1, total.remote_h2d + total.device_local),
            "peak_device_bytes": self._peak_device,
            "peak_host_bytes": self._peak_host,
        }
