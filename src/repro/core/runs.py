"""Run-compressed interval maps: the metadata backbone of the page table.

A :class:`RunMap` stores one value per page index in ``[0, n)`` as maximal
constant *runs*: a sorted ``starts`` array (``starts[0] == 0``) plus one
value per run, with the invariant that adjacent runs always hold different
values. Every range operation — query, assignment, increment — costs
O(runs touched + log runs), never O(pages), so a 16M-page allocation whose
tier map is a single uniform run is exactly as cheap as a 16-page one.

The page table keeps tier state, LRU epochs, dirty bits, access counters
and notification-pending state in RunMaps; dense per-page arrays are only
ever *materialized* on demand (``to_dense``) for tests and debugging.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = ["RunMap", "union_runs"]

_EMPTY = np.empty(0, np.int64)


class RunMap:
    """A value per page in [0, n), run-length compressed.

    Invariants (checked by :meth:`check`): ``starts`` is strictly
    increasing int64 with ``starts[0] == 0``; ``vals`` has one entry per
    run; adjacent runs differ (the map is always maximally coalesced).
    """

    __slots__ = ("n", "starts", "vals")

    def __init__(self, n: int, fill=0, dtype=np.int64):
        self.n = int(n)
        self.starts = np.zeros(1, np.int64)
        self.vals = np.full(1, fill, dtype)

    @classmethod
    def from_dense(cls, arr: np.ndarray) -> "RunMap":
        arr = np.asarray(arr)
        m = cls(len(arr), 0, arr.dtype)
        breaks = np.flatnonzero(np.diff(arr)) + 1
        m.starts = np.concatenate(([0], breaks)).astype(np.int64)
        m.vals = arr[m.starts]
        return m

    # ----------------------------------------------------------------- views
    @property
    def num_runs(self) -> int:
        return len(self.starts)

    def runs(self, p0: int = 0, p1: Optional[int] = None):
        """Clipped run view of [p0, p1): (starts, ends, vals).

        ``starts``/``ends`` are fresh int64 arrays; ``vals`` is a read-only
        slice of the underlying value array (copy before mutating)."""
        if p1 is None:
            p1 = self.n
        if p1 <= p0:
            return _EMPTY, _EMPTY, self.vals[:0]
        st = self.starts
        i = int(np.searchsorted(st, p0, "right")) - 1
        j = int(np.searchsorted(st, p1, "left"))
        s = st[i:j].copy()
        s[0] = p0
        e = np.empty(j - i, np.int64)
        e[:-1] = st[i + 1:j]
        e[-1] = p1
        return s, e, self.vals[i:j]

    def value_at(self, p: int):
        i = int(np.searchsorted(self.starts, p, "right")) - 1
        return self.vals[i]

    def any(self) -> bool:
        """True if any page holds a nonzero value (bool-map convenience)."""
        return bool(self.vals.any())

    def nonzero_runs(self, p0: int = 0, p1: Optional[int] = None):
        """(starts, ends) of the sub-runs with a nonzero value in [p0, p1)."""
        s, e, v = self.runs(p0, p1)
        m = v != 0
        return s[m], e[m]

    def count_nonzero(self, p0: int = 0, p1: Optional[int] = None) -> int:
        """Number of pages with a nonzero value in [p0, p1)."""
        s, e = self.nonzero_runs(p0, p1)
        return int((e - s).sum())

    def to_dense(self) -> np.ndarray:
        """Materialize the dense per-page array (O(n) — tests/debug only)."""
        lengths = np.diff(np.append(self.starts, self.n))
        return np.repeat(self.vals, lengths)

    def bytes_used(self) -> int:
        """Metadata footprint: O(runs), independent of n."""
        return self.starts.nbytes + self.vals.nbytes

    def check(self) -> None:
        assert self.starts[0] == 0 and len(self.starts) == len(self.vals)
        assert (np.diff(self.starts) > 0).all(), "starts not increasing"
        assert self.starts[-1] < self.n, "run beyond the map"
        if len(self.vals) > 1:
            assert (self.vals[1:] != self.vals[:-1]).all(), "uncoalesced runs"

    # ------------------------------------------------------------- mutations
    def splice(self, p0: int, p1: int, new_starts, new_vals) -> None:
        """Replace [p0, p1) with the given runs (new_starts[0] must be p0);
        re-coalesces at the seams."""
        if p1 <= p0:
            return
        st, vl = self.starts, self.vals
        i = int(np.searchsorted(st, p0, "right")) - 1
        j = int(np.searchsorted(st, p1, "left"))
        # runs fully before p0, plus the clipped head of run i if it
        # begins before p0
        hk = i + 1 if st[i] < p0 else i
        if p1 >= self.n:
            tail_s, tail_v = st[:0], vl[:0]
        elif j < len(st) and st[j] == p1:
            tail_s, tail_v = st[j:], vl[j:]
        else:  # run j-1 spans across p1: it resumes at p1
            tail_s = np.concatenate(([p1], st[j:]))
            tail_v = np.concatenate((vl[j - 1:j], vl[j:]))
        starts = np.concatenate((st[:hk], new_starts, tail_s))
        vals = np.concatenate((vl[:hk], np.asarray(new_vals, vl.dtype), tail_v))
        if len(vals) > 1:
            keep = np.empty(len(vals), bool)
            keep[0] = True
            np.not_equal(vals[1:], vals[:-1], out=keep[1:])
            if not keep.all():
                starts, vals = starts[keep], vals[keep]
        self.starts, self.vals = starts, vals

    def set_range(self, p0: int, p1: int, val) -> None:
        if p1 <= p0:
            return
        self.splice(p0, p1, np.array([p0], np.int64),
                    np.array([val], self.vals.dtype))

    def add_range(self, p0: int, p1: int, delta) -> None:
        s, _, v = self.runs(p0, p1)
        if len(s):
            self.splice(p0, p1, s, v + delta)

    def clear(self) -> None:
        """Reset every page to 0."""
        self.starts = np.zeros(1, np.int64)
        self.vals = np.zeros(1, self.vals.dtype)


def union_runs(s, e):
    """Merge overlapping/adjacent intervals (sorted by start) into a
    disjoint sorted interval list."""
    if len(s) <= 1:
        return s, e
    cme = np.maximum.accumulate(e)
    new = np.ones(len(s), bool)
    new[1:] = s[1:] > cme[:-1]
    starts = s[new]
    ends = cme[np.append(np.flatnonzero(new)[1:] - 1, len(s) - 1)]
    return starts, ends
