"""Memory-policy backends: pluggable strategies for the unified-memory runtime.

The paper's three allocation strategies — and any new memory system — are
:class:`MemPolicy` objects. ``UnifiedMemory`` never branches on a policy
name: every policy-dependent decision goes through an explicit lifecycle
hook on the allocation's policy object.

Built-in backends (the paper's comparison axis):

system   -> :class:`SystemPolicy` (malloc): single system page table,
            direct remote access at fine granularity, access-counter-based
            *delayed* migration (threshold notifications, §2.2.1).
managed  -> :class:`ManagedPolicy` (cudaMallocManaged): fault-driven
            on-demand migration at 2 MB granularity + speculative prefetch,
            LRU eviction under device-capacity pressure (§2.3).
explicit -> :class:`ExplicitPolicy` (cudaMalloc + cudaMemcpy):
            device-resident, explicit staged copies, OOM on oversubscription.

plus one backend for a different memory system entirely:

mi300a_unified -> :class:`Mi300aUnifiedPolicy`: AMD MI300A's single
            *physical* pool (CPU and GPU share one HBM3 memory and one page
            table). First touch maps, nothing ever migrates, nothing is
            evicted, and access latency is uniform — oversubscribing the
            pool is a genuine OOM. Pair with the ``MI300A`` HardwareModel.

Hook reference (``um`` is the calling :class:`~repro.core.umem.UnifiedMemory`):

==========================  ==================================================
hook                        called when / must do
==========================  ==================================================
``on_alloc(um, name, n)``   build and charge the Allocation record
``on_free(um, a)``          release residency, charge deallocation
``make_staging(um, buf)``   from_host(): return a host staging Allocation
                            (or None) for the cudaMalloc+malloc pair
``on_first_touch(...)``     charge PTE creation for the unmapped pages of an
                            extent and return the Tier they map to
``on_access(...)``          pre-access migration (fault-driven paths); the
                            return value is handed to charge_access as ctx
``charge_access(...)``      classify the extent's resident bytes into
                            (local, remote_h2d, remote_d2h, remote_slow)
                            contributions and update traffic counters
``on_pressure(um, a, n)``   a migration into a full device: evict (or not)
``on_sync(um, a)``          sync point: drain batched/delayed migrations
``resolve_actor_side(...)`` route a BufferView to the allocation an actor
                            actually touches (explicit staging pairs)
==========================  ==================================================

Charge-accounting invariants every backend must keep (enforced for the
built-ins by scripts/check_parity.py, and for every registered backend by
tests/policy_contract.py):

* alloc/free symmetry — freeing returns host/device residency to its
  pre-alloc values;
* the runtime's cached residency totals equal a full recount after any
  op sequence (``UnifiedMemory._recompute_residency``);
* freed allocations are never charged (kernel access asserts).

``system_policy`` / ``managed_policy`` / ``explicit_policy`` remain as thin
compatibility constructors; new code should go through
``repro.core.registry`` (``register_policy`` / ``make_policy``).

The serving stack allocates its paged KV pool under the *system* policy
(one umem page per KV pool page): the scheduler in serve/engine.py moves
requests through pending -> prefill -> decoding -> preempted -> done,
admitting against device-memory pressure, demoting preempted sequences'
pages host-side, and relying on this policy's graceful remote access +
counter-based delayed migration when the pool exceeds device capacity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.pagetable import Actor, BlockTable, Tier
from repro.core.runs import RunMap, union_runs

KB = 1024
MB = 1024 * 1024


class OutOfDeviceMemory(RuntimeError):
    pass


class HostSpillError(RuntimeError):
    """An injected host-spill failure window is active: the demote target's
    memory cannot accept the spill (runtime/fault.py FaultPlan 'spill_fail'
    events). Callers fall back — the serve engine drops the KV instead of
    saving it and recomputes the sequence from its prompt."""


@dataclass
class Allocation:
    name: str
    nbytes: int
    policy: "MemPolicy"
    table: Optional[BlockTable]  # None for explicit (device-resident, no PTEs)
    device_bytes_explicit: int = 0
    pending: Optional[RunMap] = None  # system: notification-pending page runs
    pending_count: int = 0  # fast-path: #pending pages ever set minus cleared
    freed: bool = False


@dataclass(frozen=True)
class MemPolicy:
    """Abstract memory-policy backend: config knobs + lifecycle hooks.

    Subclasses set ``kind`` (the registry/reporting name) and override the
    hooks whose behavior differs from the paged defaults below. Config
    fields a backend does not use are simply ignored by its hooks.
    """

    page_size: int = 64 * KB  # PTE granularity
    migration_granule: int = 64 * KB  # bytes moved per migration decision
    counter_threshold: int = 256  # remote accesses before a notification
    auto_migrate: bool = True  # system: enable counter-based migration
    speculative_prefetch: int = 4  # managed: granules prefetched per fault
    max_migration_bytes_per_sync: int = 512 * MB  # driver batch per sync point

    # capability flags (class attributes, not config fields)
    kind = "abstract"  # registry / reporting name
    paged = True  # allocations carry a BlockTable (False: device-resident blob)
    migratable = True  # pages can move between tiers after first touch
    evictable = False  # pages are LRU-eviction victims under device pressure
    staged_transfers = False  # um.staged() charges h2d/d2h copies for this policy
    batched_charge = False  # opt-in: charge_access folds into kernel_batch's
    # array-wide pass (see batch_ready / charge_access_batch); backends that
    # never opt in are looped through single launches, bit-identically
    node_aware = False  # opt-in: page locations are (node, tier) encodings
    # (see pagetable.node_tier_loc) and the runtime charges through the
    # *_runs hooks + integer lane accounting below instead of the two-tier
    # charge_access hooks. Single-node backends never see any of it.

    # ------------------------------------------------------------ lifecycle
    def on_alloc(self, um, name: str, nbytes: int) -> Allocation:
        """Build the Allocation record and charge allocation bookkeeping.

        Paged default: a lazy page table — allocation itself only creates
        VMA bookkeeping (no PTEs, no residency)."""
        table = BlockTable(name, nbytes, self.page_size)
        a = Allocation(name, nbytes, self, table=table,
                       pending=RunMap(table.num_pages, 0, np.int8))
        um._charge(um.hw.alloc_per_page * min(table.num_pages, 64))
        return a

    def on_free(self, um, a: Allocation) -> None:
        """Release residency and charge per-page deallocation."""
        t = a.table
        mapped = t.num_pages - t.resident_pages(Tier.UNMAPPED)
        hb, db = t.residency_by_side()
        um._host_bytes -= hb
        um._device_bytes -= db
        um._charge(um.hw.dealloc_per_page * mapped)

    def make_staging(self, um, buf) -> Optional[Allocation]:
        """from_host(): the host staging Allocation for the cudaMalloc+malloc
        pair, or None when the policy's memory is CPU-accessible already."""
        return None

    # ------------------------------------------------------------ placement
    def on_first_touch(self, um, a: Allocation, p0: int, p1: int,
                       actor: Actor, n_unmapped: int, need_bytes: int) -> Tier:
        """Charge PTE creation for the ``n_unmapped`` unmapped pages of
        extent [p0, p1) and return the tier they map to. ``need_bytes`` is
        what device residency would grow by if they map device-side."""
        raise NotImplementedError(self.kind)

    # --------------------------------------------------------------- access
    def on_access(self, um, a: Allocation, p0: int, p1: int, actor: Actor):
        """Pre-access migration hook, called after first-touch mapping and
        before residency is read for charging. Returns an opaque context
        value handed to :meth:`charge_access` (the managed backend returns
        its thrash-mode flag). Default: no migration, falsy context."""
        return None

    def charge_access(self, um, a: Allocation, actor: Actor, is_write: bool,
                      ctx, rs: np.ndarray, re_: np.ndarray, dm: np.ndarray,
                      dev_b: float, host_b: float
                      ) -> Tuple[float, float, float, float]:
        """Classify one extent's resident bytes into charge-model buckets.

        ``rs/re_`` are the extent's tier-run spans, ``dm`` the device-tier
        mask, ``dev_b/host_b`` the (boundary-clipped) bytes per side. Must
        update the traffic counters and return the
        ``(local, remote_h2d, remote_d2h, remote_slow)`` contributions the
        kernel accumulates. The default models a generic two-tier system:
        the actor's home side is local, the far side crosses the link."""
        tr = um.prof.traffic()
        if actor is Actor.GPU:
            tr.device_local += int(dev_b)
            if ctx:  # thrash mode: remote reads at degraded link efficiency
                tr.link_h2d += int(host_b)
                tr.remote_h2d += int(host_b)
                return dev_b, 0.0, 0.0, host_b
            if is_write:
                tr.link_d2h += int(host_b)
                tr.remote_d2h += int(host_b)
                return dev_b, 0.0, host_b, 0.0
            tr.link_h2d += int(host_b)
            tr.remote_h2d += int(host_b)
            return dev_b, host_b, 0.0, 0.0
        tr.host_local += int(host_b)
        tr.link_d2h += int(dev_b)
        return host_b, 0.0, dev_b, 0.0

    # ------------------------------------------------------ batched access
    def batch_ready(self, um, a: Allocation, p0: int, p1: int,
                    actor: Actor) -> bool:
        """Certify extent [p0, p1) — the hull of one allocation's extents in
        a kernel batch — for the batched charge pass. Return True only when,
        with tier state frozen, this policy's sequential per-launch hooks
        reduce to :meth:`charge_access_batch`: no first-touch mapping and no
        in-kernel migration/fault/thrash work from :meth:`on_access`.
        ``kernel_batch`` falls back to looping single launches whenever any
        touched policy answers False (or never opted in via
        ``batched_charge``), so backends that don't implement batching stay
        bit-identical automatically. The default certifies any fully-mapped
        hull, which is exact for policies whose ``on_access`` is the
        inherited no-op."""
        if not self.batched_charge:
            return False
        t = a.table
        return (t.resident_pages(Tier.UNMAPPED) == 0
                or t.unmapped_stats(p0, p1)[0] == 0)

    def charge_access_batch(self, um, a: Allocation, gpu: np.ndarray,
                            wr: np.ndarray, p0s: np.ndarray, p1s: np.ndarray,
                            dev_b: np.ndarray, host_b: np.ndarray):
        """Array-wide :meth:`charge_access` over one allocation's certified
        batch extents. ``gpu``/``wr`` are per-extent actor/write masks,
        ``p0s``/``p1s`` the page extents, ``dev_b``/``host_b`` the
        boundary-clipped int64 bytes per side. Must update the traffic
        counters and return per-extent ``(local, remote_h2d, remote_d2h,
        remote_slow)`` int64 arrays for the batch engine to accumulate
        per item. Every value is an exact integer, so the downstream float
        conversions are order-independent and bit-identical to the
        sequential path. Only called on extents :meth:`batch_ready`
        certified (``ctx`` is falsy by construction — no thrash mode)."""
        tr = um.prof.traffic()
        zero = np.zeros_like(dev_b)
        loc = np.where(gpu, dev_b, host_b)
        h2d = np.where(gpu & ~wr, host_b, zero)
        d2h = np.where(gpu & wr, host_b, zero) + np.where(~gpu, dev_b, zero)
        tr.device_local += int(dev_b[gpu].sum())
        rem_h2d = int(h2d.sum())
        tr.link_h2d += rem_h2d
        tr.remote_h2d += rem_h2d
        rem_d2h = int(host_b[gpu & wr].sum())
        tr.remote_d2h += rem_d2h
        tr.link_d2h += rem_d2h + int(dev_b[~gpu].sum())
        tr.host_local += int(host_b[~gpu].sum())
        return loc, h2d, d2h, zero

    # --------------------------------------------------- node-aware access
    # Only consulted for policies with ``node_aware = True`` (the cluster
    # backends). Locations in the page table are (node, tier) encodings;
    # the runtime hands the *_runs hooks the run structure plus exact
    # per-run clipped integer bytes, and the hooks return the classic
    # (local, h2d, d2h, slow) buckets PLUS an integer lane tuple
    # ``(nvlink_bytes, nvlink_runs, fabric_bytes, fabric_runs)`` for
    # inter-node traffic. Lanes stay exact integers all the way through
    # accumulation — the float conversion happens exactly once per
    # launch/item via lanes_time / lanes_time_batch, so the sequential and
    # batched engines stay bit-identical.
    def charge_access_runs(self, um, a: Allocation, actor: Actor,
                           is_write: bool, ctx, rs: np.ndarray,
                           re_: np.ndarray, rv: np.ndarray, rb: np.ndarray,
                           node: int):
        """Classify one extent's per-run clipped bytes (``rb``, exact ints)
        against the (node, tier) locations ``rv``, as seen from ``node``.
        Returns ``(local, h2d, d2h, slow, lanes)``."""
        raise NotImplementedError(self.kind)

    def charge_access_batch_runs(self, um, a: Allocation, gpu: np.ndarray,
                                 wr: np.ndarray, nodes: np.ndarray,
                                 uloc: np.ndarray, nb: np.ndarray,
                                 nr: np.ndarray):
        """Array-wide charge_access_runs: ``nb``/``nr`` are per-(extent,
        location) clipped bytes / overlapping-run counts, columns keyed by
        ``uloc``. Returns per-extent ``(local, h2d, d2h, slow, lanes)``
        int64 arrays, ``lanes`` of shape (extents, 4)."""
        raise NotImplementedError(self.kind)

    def lanes_time(self, um, lanes) -> float:
        """Seconds for one launch's accumulated integer lane tuple."""
        return 0.0

    def lanes_time_batch(self, um, lanes):
        """Per-item seconds for the batch's accumulated lane matrix."""
        return 0.0

    # -------------------------------------------------- placement dispatch
    def on_demote(self, um, a: Allocation, p0: int, p1: int):
        """Demotion dispatch: return None to use the runtime's built-in
        device->host demotion; node-aware backends retier/charge here
        (e.g. spilling to a remote node's host memory) and return the
        modeled seconds they charged."""
        return None

    def on_migrate_in(self, um, a: Allocation, starts, ends):
        """Promotion dispatch for _migrate_in_runs: return None to use the
        built-in host->device path; node-aware backends promote toward the
        accessing node here and return the bytes they migrated."""
        return None

    def on_node_loss(self, um, a: Allocation, node: int):
        """Superchip ``node``'s physical memory vanished (um.fail_node):
        poison — unmap — every page of this allocation resident on its
        host or device side and return the lost [p0, p1) page runs. The
        data is unrecoverable; callers re-materialize contents (the serve
        engine replays affected sequences from their prompts, mirroring
        the trainer's checkpoint-restore). The default covers any paged
        backend through the (node, tier) encoding — a single-node table
        simply has no locations for ``node > 0``; table-less backends
        lose nothing here (their device blobs are modeled node-0-pinned
        and a node-0 loss of an explicit blob is not modeled)."""
        t = a.table
        if t is None:
            return []
        out = []
        for loc in (2 * node, 2 * node + 1):  # (node, HOST), (node, DEVICE)
            s, e = t.runs_of(loc)
            if len(s) == 0:
                continue
            if a.pending is not None:
                # pending migration notifications over lost pages are
                # meaningless — the next sync must not promote ghosts
                for r0, r1 in zip(s, e):
                    a.pending_count -= a.pending.count_nonzero(int(r0),
                                                               int(r1))
                    a.pending.set_range(int(r0), int(r1), 0)
            um._apply_delta(t.move_runs(s, e, Tier.UNMAPPED))
            t.clear_dirty(s, e)
            out.extend((int(r0), int(r1)) for r0, r1 in zip(s, e))
        out.sort()
        return out

    # ------------------------------------------------------- pressure/sync
    def on_pressure(self, um, a: Allocation, need_bytes: int) -> None:
        """Device memory is short ``need_bytes`` for a migration into it.
        Backends that participate in eviction reclaim here; the default
        reclaims nothing (the migration prefix-fits what free space allows)."""

    def on_sync(self, um, a: Allocation) -> None:
        """Sync point (cudaDeviceSynchronize): drain any batched/delayed
        migration state. Default: nothing pending."""

    # -------------------------------------------------------------- routing
    def resolve_actor_side(self, view, actor: Actor):
        """Lower a BufferView to the raw Range the given actor touches.
        Default: CPU actors land in the staging side whenever
        ``make_staging`` created one, so a backend that stages does not
        also have to reimplement the routing."""
        if actor is Actor.CPU and view.buf.host is not None:
            return (view.buf.host, view.lo, view.hi)
        return (view.buf.alloc, view.lo, view.hi)


@dataclass(frozen=True)
class SystemPolicy(MemPolicy):
    """System-allocated memory (malloc): one OS page table for both actors.

    GPU first-touch pays the SMMU->OS round trip (§5.1.2); device-capacity
    pressure maps host-side instead of evicting (graceful oversubscription);
    remote GPU reads bump per-page access counters whose threshold
    crossings queue notifications that sync() drains as batched migrations
    (§2.2.1)."""

    kind = "system"
    batched_charge = True  # on_access is the inherited no-op; the counter
    # bumps fold into charge_access_batch below

    def on_first_touch(self, um, a, p0, p1, actor, n_unmapped, need_bytes):
        tr = um.prof.traffic()
        if actor is Actor.GPU:
            # GPU first-touch of system memory: SMMU fault -> OS on the CPU
            # creates the PTE (the §5.1.2 init bottleneck)
            um._charge(um.hw.pte_init_gpu * n_unmapped)
            tr.pte_inits_gpu += n_unmapped
        else:
            um._charge(um.hw.pte_init_cpu * n_unmapped)
            tr.pte_inits_cpu += n_unmapped
        tier = actor.home_tier
        if tier is Tier.DEVICE and need_bytes > um.device_free():
            tier = Tier.HOST  # system memory: map host-side instead
        return tier

    def charge_access(self, um, a, actor, is_write, ctx, rs, re_, dm,
                      dev_b, host_b):
        out = super().charge_access(um, a, actor, is_write, ctx, rs, re_, dm,
                                    dev_b, host_b)
        if actor is Actor.GPU and self.auto_migrate and host_b:
            # remote-access counters: one bump per host run; the (possibly
            # partial) tail page has its own txn count
            t = a.table
            grain = um.hw.remote_access_grain
            txn_full = max(1, t.page_size // grain)
            txn_tail = max(1, t.tail_bytes // grain)
            for s0, e0 in zip(rs[~dm], re_[~dm]):
                s0, e0 = int(s0), int(e0)
                if e0 == t.num_pages and txn_tail != txn_full:
                    if e0 - 1 > s0:
                        um._counter_bump(a, s0, e0 - 1, txn_full)
                    um._counter_bump(a, e0 - 1, e0, txn_tail)
                else:
                    um._counter_bump(a, s0, e0, txn_full)
        return out

    def charge_access_batch(self, um, a, gpu, wr, p0s, p1s, dev_b, host_b):
        out = super().charge_access_batch(um, a, gpu, wr, p0s, p1s,
                                          dev_b, host_b)
        m = gpu & (host_b != 0)
        if self.auto_migrate and m.any():
            # The sequential path bumps each page once per covering extent's
            # host run. With tier state frozen across the certified batch,
            # k bumps of txn collapse to one bump of k*txn: increments are
            # monotone, so the threshold crossing (old < thr <= old + total),
            # the final counter values and the pending/notification state
            # are all identical.
            t = a.table
            lo, hi = int(p0s[m].min()), int(p1s[m].max())
            hs, he = t.runs_of(Tier.HOST, lo, hi)
            if len(hs):
                # intersect every host-carrying extent with the host runs
                # (vectorized runs_of(HOST, p0, p1) over all extents at once)
                ia = np.searchsorted(he, p0s[m], "right")
                ib = np.searchsorted(hs, p1s[m], "left")
                cnt = ib - ia
                rep = np.repeat(np.arange(len(cnt)), cnt)
                ridx = (np.repeat(ia, cnt)
                        + np.arange(int(cnt.sum()))
                        - np.repeat(np.cumsum(cnt) - cnt, cnt))
                cs = np.maximum(hs[ridx], p0s[m][rep])
                ce = np.minimum(he[ridx], p1s[m][rep])
                if len(cs):
                    grain = um.hw.remote_access_grain
                    txn_full = max(1, t.page_size // grain)
                    txn_tail = max(1, t.tail_bytes // grain)
                    # coverage sweep: how many extents' host runs cover each
                    # elementary segment -> one collapsed bump per segment
                    bp = np.unique(np.concatenate((cs, ce)))
                    cov = np.zeros(len(bp), np.int64)
                    np.add.at(cov, np.searchsorted(bp, cs), 1)
                    np.add.at(cov, np.searchsorted(bp, ce), -1)
                    cov = np.cumsum(cov[:-1])
                    for s0, e0, k in zip(bp[:-1].tolist(), bp[1:].tolist(),
                                         cov.tolist()):
                        if k == 0:
                            continue
                        if e0 == t.num_pages and txn_tail != txn_full:
                            if e0 - 1 > s0:
                                um._counter_bump(a, s0, e0 - 1, txn_full * k)
                            um._counter_bump(a, e0 - 1, e0, txn_tail * k)
                        else:
                            um._counter_bump(a, s0, e0, txn_full * k)
        return out

    def on_sync(self, um, a):
        """Drain notification-pending pages as (pending ∩ host) runs under
        the per-sync migration budget — O(runs), never O(pages)."""
        if not self.auto_migrate or a.pending is None:
            return
        if a.pending_count == 0:  # invariant: count 0 <=> no pending runs
            return
        t = a.table
        ps_, pe_ = a.pending.nonzero_runs()
        hs, he = [], []
        for s0, e0 in zip(ps_, pe_):
            rs, re_ = t.runs_of(Tier.HOST, int(s0), int(e0))
            hs.append(rs)
            he.append(re_)
        hs = np.concatenate(hs) if hs else np.empty(0, np.int64)
        he = np.concatenate(he) if he else np.empty(0, np.int64)
        if len(hs) == 0:
            a.pending.clear()
            a.pending_count = 0
            return
        budget = self.max_migration_bytes_per_sync
        ks, ke = um._prefix_fit_runs(t, hs, he, budget)
        um._migrate_in_runs(a, ks, ke)
        for s0, e0 in zip(ks, ke):
            a.pending.set_range(int(s0), int(e0), 0)
        a.pending_count -= int((ke - ks).sum())


@dataclass(frozen=True)
class ManagedPolicy(MemPolicy):
    """CUDA managed memory (cudaMallocManaged): fault-driven on-demand
    migration at ``migration_granule`` + speculative prefetch, LRU eviction
    under device pressure, thrash-mode remote reads when the touched working
    set cannot fit even after evicting every other managed page (§7)."""

    kind = "managed"
    evictable = True
    batched_charge = True  # only for extents batch_ready below certifies

    def batch_ready(self, um, a, p0, p1, actor):
        # ready only when on_access would be a no-op over the hull: no
        # far-tier pages to fault/migrate (GPU: host pages; CPU: device
        # pages), hence no thrash-mode check and no speculative prefetch
        if not super().batch_ready(um, a, p0, p1, actor):
            return False
        t = a.table
        far = Tier.HOST if actor is Actor.GPU else Tier.DEVICE
        if t.resident_pages(far) == 0:
            return True
        s, _ = t.runs_of(far, p0, p1)
        return len(s) == 0

    def on_first_touch(self, um, a, p0, p1, actor, n_unmapped, need_bytes):
        tr = um.prof.traffic()
        if actor is Actor.GPU:
            # managed: first-touch maps straight into the GPU page table
            granules = max(1, n_unmapped * a.table.page_size
                           // self.migration_granule)
            um._charge(um.hw.pte_init_cpu * granules)
            tr.pte_inits_gpu += n_unmapped
        else:
            um._charge(um.hw.pte_init_cpu * n_unmapped)
            tr.pte_inits_cpu += n_unmapped
        tier = actor.home_tier
        if tier is Tier.DEVICE and need_bytes > um.device_free():
            um._evict_lru(need_bytes - um.device_free(), exclude=a)
            if need_bytes > um.device_free():
                tier = Tier.HOST  # spill the remainder
        return tier

    def on_access(self, um, a, p0, p1, actor):
        t = a.table
        if actor is Actor.GPU:
            # fault-driven on-demand migration (+ speculative prefetch);
            # when the touched working set cannot fit even after evicting
            # every other managed page, the driver stops migrating and
            # serves remote reads (paper §7 Fig. 12)
            thrashing = False
            hs, he = t.runs_of(Tier.HOST, p0, p1)
            if len(hs):
                ws = int(t.span_bytes(hs, he).sum())
                evictable = sum(
                    o.table.resident_bytes(Tier.DEVICE)
                    for o in um.allocs.values()
                    if o is not a and not o.freed and o.table is not None
                    and o.policy.evictable)
                thrashing = ws > um.device_free() + evictable
            if len(hs) and not thrashing:
                tr = um.prof.traffic()
                gran_pages = max(1, self.migration_granule // t.page_size)
                # faulting granules: the host runs projected onto granule
                # space (overlaps/adjacency merged)
                gs, ge = union_runs(hs // gran_pages,
                                    (he - 1) // gran_pages + 1)
                nfaults = int((ge - gs).sum())
                tr.faults += nfaults
                um._charge(um.hw.page_fault_cost * nfaults)
                # speculative prefetch: each faulting granule drags in the
                # next `pf` granules — expand the granule runs and clip
                pf = self.speculative_prefetch
                if pf > 0:
                    gs, ge = union_runs(gs, ge + pf - 1)
                    gmax = t.num_pages // gran_pages + 1
                    ge = np.minimum(ge, gmax)
                    keep = gs < ge
                    ms = gs[keep] * gran_pages
                    me = np.minimum(ge[keep] * gran_pages, t.num_pages)
                    um._migrate_in_runs(a, ms, me)
            return thrashing
        # CPU touch of device-resident managed pages faults them back host
        ds_, de_ = t.runs_of(Tier.DEVICE, p0, p1)
        if len(ds_):
            tr = um.prof.traffic()
            n_dev = int((de_ - ds_).sum())
            gran_pages = max(1, self.migration_granule // t.page_size)
            gs, ge = union_runs(ds_ // gran_pages,
                                (de_ - 1) // gran_pages + 1)
            nfaults = int((ge - gs).sum())
            tr.faults += nfaults
            um._charge(um.hw.page_fault_cost * nfaults)
            nbytes = int(t.span_bytes(ds_, de_).sum())
            um._apply_delta(t.move_runs(ds_, de_, Tier.HOST))
            tr.migrated_out += nbytes
            tr.link_d2h += nbytes
            um._charge(nbytes / um.hw.link_d2h
                       + um.hw.migrate_per_page * n_dev)
        return False

    def on_pressure(self, um, a, need_bytes):
        um._evict_lru(need_bytes - um.device_free(), exclude=a)


@dataclass(frozen=True)
class ExplicitPolicy(MemPolicy):
    """cudaMalloc + cudaMemcpy: device-resident, no page table, explicit
    staged copies through a malloc'd host pair, OOM on oversubscription."""

    kind = "explicit"
    paged = False
    staged_transfers = True

    def on_alloc(self, um, name, nbytes):
        if nbytes > um.device_free():
            raise OutOfDeviceMemory(
                f"cudaMalloc({name}): {nbytes} > free {um.device_free()}")
        a = Allocation(name, nbytes, self, table=None,
                       device_bytes_explicit=nbytes)
        um._device_bytes += nbytes
        um._charge(um.hw.alloc_per_page * -(-nbytes // self.page_size))
        return a

    def on_free(self, um, a):
        um._device_bytes -= a.device_bytes_explicit
        um._charge(um.hw.dealloc_per_page *
                   -(-a.nbytes // self.migration_granule))

    def make_staging(self, um, buf):
        # the malloc half of the pair: paged like the application's system-
        # memory version (um.staging_page_size), never counter-migrated;
        # the base resolve_actor_side routes CPU actors to it
        return um.alloc(buf.name + "__host", buf.nbytes,
                        system_policy(um.staging_page_size,
                                      auto_migrate=False))


@dataclass(frozen=True)
class Mi300aUnifiedPolicy(MemPolicy):
    """AMD MI300A unified physical memory: CPU and GPU share one HBM3 pool
    and one page table. First touch maps (cheaply — no SMMU->OS round trip),
    nothing migrates, nothing is evicted, and access latency is uniform;
    exceeding the pool is a genuine OOM rather than graceful remote access.
    Pair with the ``MI300A`` :class:`~repro.core.hardware.HardwareModel`,
    whose equal device/host/link bandwidths make the generic charge
    classification cost the same on either "side" of the single pool.
    ``migratable = False`` also turns the runtime's explicit migration
    APIs (prefetch/prefetch_async/demote) into placement no-ops: there is
    nowhere to move a page to."""

    kind = "mi300a_unified"
    migratable = False
    batched_charge = True  # on_access is the inherited no-op; batch_ready's
    # fully-mapped-hull check means the OOM-raising first touch cannot fire

    def on_first_touch(self, um, a, p0, p1, actor, n_unmapped, need_bytes):
        # OOM before any charge: a caller probing capacity must not record
        # PTE-init time/counters for pages that were never mapped
        if need_bytes > um.device_free():
            raise OutOfDeviceMemory(
                f"MI300A unified pool exhausted mapping {a.name}: "
                f"{need_bytes} > free {um.device_free()} "
                "(a single physical pool cannot oversubscribe)")
        # one shared OS page table: GPU and CPU first touch cost the same
        tr = um.prof.traffic()
        um._charge(um.hw.pte_init_cpu * n_unmapped)
        if actor is Actor.GPU:
            tr.pte_inits_gpu += n_unmapped
        else:
            tr.pte_inits_cpu += n_unmapped
        return Tier.DEVICE  # the one pool; tiers exist only as bookkeeping


def system_policy(page_size: int = 64 * KB, *, threshold: int = 256,
                  auto_migrate: bool = True,
                  max_migration_bytes_per_sync: int = 512 * MB) -> SystemPolicy:
    return SystemPolicy(
        page_size=page_size,
        migration_granule=max(page_size, 64 * KB),
        counter_threshold=threshold,
        auto_migrate=auto_migrate,
        max_migration_bytes_per_sync=max_migration_bytes_per_sync,
    )


def managed_policy(page_size: int = 64 * KB, *,
                   speculative_prefetch: int = 4) -> ManagedPolicy:
    # device-side pages are 2 MB (GPU-exclusive page table); host-side PTEs
    # use the system page size (alloc/dealloc/eviction costs)
    return ManagedPolicy(
        page_size=page_size,
        migration_granule=2 * MB,
        speculative_prefetch=speculative_prefetch,
    )


def explicit_policy() -> ExplicitPolicy:
    return ExplicitPolicy(page_size=2 * MB, migration_granule=2 * MB)


def mi300a_unified_policy(page_size: int = 64 * KB) -> Mi300aUnifiedPolicy:
    return Mi300aUnifiedPolicy(page_size=page_size,
                               migration_granule=page_size)


# legacy alias: PolicyConfig was the frozen config record the runtime
# branched on; the strategy base class subsumes it
PolicyConfig = MemPolicy
