"""Memory-management policies: the paper's three allocation strategies.

system   -> system-allocated memory (malloc): single system page table,
            direct remote access at fine granularity, access-counter-based
            *delayed* migration (threshold notifications, §2.2.1).
managed  -> CUDA managed memory (cudaMallocManaged): fault-driven on-demand
            migration at 2 MB granularity + speculative prefetch, LRU
            eviction under device-capacity pressure (§2.3).
explicit -> cudaMalloc + cudaMemcpy: device-resident, explicit copies, OOM on
            oversubscription.

The serving stack allocates its paged KV pool under the *system* policy
(one umem page per KV pool page): the scheduler in serve/engine.py moves
requests through pending -> prefill -> decoding -> preempted -> done,
admitting against device-memory pressure, demoting preempted sequences'
pages host-side, and relying on this policy's graceful remote access +
counter-based delayed migration when the pool exceeds device capacity.
"""
from __future__ import annotations

from dataclasses import dataclass

KB = 1024
MB = 1024 * 1024


@dataclass(frozen=True)
class PolicyConfig:
    kind: str  # system | managed | explicit
    page_size: int  # system page size (PTE granularity)
    migration_granule: int  # bytes moved per migration decision
    counter_threshold: int = 256  # remote accesses before a notification
    auto_migrate: bool = True  # system: enable counter-based migration
    speculative_prefetch: int = 4  # managed: granules prefetched per fault
    max_migration_bytes_per_sync: int = 512 * MB  # driver batch per sync point

    def __post_init__(self):
        assert self.kind in ("system", "managed", "explicit"), self.kind


def system_policy(page_size: int = 64 * KB, *, threshold: int = 256,
                  auto_migrate: bool = True,
                  max_migration_bytes_per_sync: int = 512 * MB) -> PolicyConfig:
    return PolicyConfig(
        kind="system",
        page_size=page_size,
        migration_granule=max(page_size, 64 * KB),
        counter_threshold=threshold,
        auto_migrate=auto_migrate,
        max_migration_bytes_per_sync=max_migration_bytes_per_sync,
    )


def managed_policy(page_size: int = 64 * KB, *, speculative_prefetch: int = 4) -> PolicyConfig:
    # device-side pages are 2 MB (GPU-exclusive page table); host-side PTEs
    # use the system page size (alloc/dealloc/eviction costs)
    return PolicyConfig(
        kind="managed",
        page_size=page_size,
        migration_granule=2 * MB,
        speculative_prefetch=speculative_prefetch,
    )


def explicit_policy() -> PolicyConfig:
    return PolicyConfig(kind="explicit", page_size=2 * MB, migration_granule=2 * MB)
