"""Hardware models for the unified-memory cost model.

GRACE_HOPPER is calibrated from the paper's own measurements (§2.1): STREAM
HBM3 3.4 TB/s, LPDDR5X 486 GB/s, Comm|Scope NVLink-C2C 375 GB/s H2D /
297 GB/s D2H. Page-fault and PTE-init constants are fitted to the paper's
observations (§5.1.2, §5.2: 64 KB pages cut GPU-first-touch init ~5x and
alloc/dealloc 4.6-38x; managed fault handling ~20 us per fault group).

TPU_V5E is the deployment target of the LM framework (roofline constants per
the assignment: 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link ICI; host link
is PCIe-class).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareModel:
    name: str
    # compute
    flops_rate: float  # peak FLOP/s for the app's compute dtype
    # memory system
    device_bw: float  # device-local memory bandwidth (bytes/s)
    host_bw: float  # host memory bandwidth (bytes/s)
    link_h2d: float  # host->device interconnect (bytes/s)
    link_d2h: float  # device->host interconnect (bytes/s)
    device_capacity: int  # bytes of device memory
    # granularity
    remote_access_grain: int  # bytes per remote transaction (cacheline / DMA block)
    remote_efficiency: float  # achieved fraction of link bw for fine-grain access
    # software costs (seconds)
    page_fault_cost: float  # fault handling on the migration path (managed)
    pte_init_cpu: float  # per-page PTE creation, CPU first-touch
    pte_init_gpu: float  # per-page PTE creation, GPU first-touch of system memory
    #   (SMMU -> OS round-trip; the paper's §5.1.2 bottleneck)
    alloc_per_page: float  # per-page allocation bookkeeping
    dealloc_per_page: float  # per-page deallocation (dominates at 4 KB, Fig. 6)
    migrate_per_page: float  # per-page migration overhead (driver + TLB shootdown)
    kernel_launch: float = 5e-6
    # managed memory under heavy oversubscription stops migrating and serves
    # faults remotely at low bandwidth (paper §7, 34-qubit case)
    managed_thrash_efficiency: float = 0.35

    def with_device_capacity(self, nbytes: int) -> "HardwareModel":
        """This model with a different device capacity — the one derived
        rebuild the oversubscription harnesses need. Multi-node models
        override it to keep their per-node split consistent, which is why
        callers must go through this instead of dataclasses.replace."""
        return dataclasses.replace(self, device_capacity=int(nbytes))


GRACE_HOPPER = HardwareModel(
    name="grace-hopper",
    flops_rate=67e12,  # H100 fp32 (apps are fp32/fp64 HPC kernels)
    device_bw=3.4e12,
    host_bw=486e9,
    link_h2d=375e9,
    link_d2h=297e9,
    device_capacity=96 * 1024**3,
    remote_access_grain=128,
    remote_efficiency=0.85,
    page_fault_cost=20e-6,
    pte_init_cpu=0.35e-6,
    pte_init_gpu=1.8e-6,
    alloc_per_page=0.05e-6,
    dealloc_per_page=0.30e-6,
    migrate_per_page=0.6e-6,
)

MI300A = HardwareModel(
    # AMD MI300A APU: CPU (24 Zen 4 cores) and GPU (CDNA3) share one 128 GB
    # HBM3 pool behind one page table ("Dissecting CPU-GPU Unified Physical
    # Memory on AMD MI300A APUs"). Device/host/link bandwidths are the SAME
    # physical memory — the Mi300aUnifiedPolicy never migrates, and the
    # equal rates below make access cost uniform no matter which "side" a
    # page's bookkeeping tier says it is on.
    name="mi300a",
    flops_rate=122.6e12,  # CDNA3 fp32 vector peak
    device_bw=3.7e12,  # achieved HBM3 STREAM-class bandwidth (5.3 TB/s peak)
    host_bw=3.7e12,  # CPU cores hit the same HBM3 pool
    link_h2d=3.7e12,  # "link" = on-package Infinity Fabric to the same pool
    link_d2h=3.7e12,
    device_capacity=128 * 1024**3,  # the whole unified pool
    remote_access_grain=128,
    remote_efficiency=1.0,  # no fine-grain penalty: one physical memory
    page_fault_cost=0.0,  # no fault-driven migration path exists
    pte_init_cpu=0.3e-6,
    pte_init_gpu=0.3e-6,  # shared page table: GPU first touch == CPU's
    alloc_per_page=0.05e-6,
    dealloc_per_page=0.3e-6,
    migrate_per_page=0.0,  # nothing ever migrates
)

TPU_V5E = HardwareModel(
    name="tpu-v5e",
    flops_rate=197e12,  # bf16
    device_bw=819e9,
    host_bw=200e9,
    link_h2d=32e9,  # PCIe-class host link
    link_d2h=32e9,
    device_capacity=16 * 1024**3,
    remote_access_grain=4096,  # DMA-efficient streaming block
    remote_efficiency=0.9,
    page_fault_cost=30e-6,  # runtime round-trip (no hardware faults on TPU)
    pte_init_cpu=0.2e-6,
    pte_init_gpu=1.0e-6,
    alloc_per_page=0.05e-6,
    dealloc_per_page=0.2e-6,
    migrate_per_page=0.5e-6,
)

# ICI / roofline constants (assignment-mandated)
V5E_PEAK_FLOPS = 197e12
V5E_HBM_BW = 819e9
V5E_ICI_BW = 50e9
