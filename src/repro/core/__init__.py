# The paper's primary contribution: the Grace Hopper unified-memory system
# (system page table, first-touch, access-counter delayed migration,
# fault-driven managed migration, oversubscription) as a composable runtime
# with pluggable memory-policy backends (see core/policy.py + core/registry.py).
from repro.core.buffer import BufferView, UMBuffer  # noqa: F401
from repro.core.hardware import GRACE_HOPPER, MI300A, TPU_V5E, HardwareModel  # noqa: F401
from repro.core.pagetable import Actor, BlockTable, Tier, coalesce_runs  # noqa: F401
from repro.core.runs import RunMap, union_runs  # noqa: F401
from repro.core.policy import (  # noqa: F401
    ExplicitPolicy,
    ManagedPolicy,
    MemPolicy,
    Mi300aUnifiedPolicy,
    PolicyConfig,
    SystemPolicy,
    explicit_policy,
    managed_policy,
    mi300a_unified_policy,
    system_policy,
)
from repro.core.registry import (  # noqa: F401
    available_hardware,
    available_policies,
    get_hardware,
    make_policy,
    register_hardware,
    register_policy,
)
from repro.core.profiler import MemoryProfiler, TrafficCounters  # noqa: F401
from repro.core.umem import (  # noqa: F401
    Allocation,
    HostSpillError,
    KernelBatch,
    KernelLaunch,
    OutOfDeviceMemory,
    UnifiedMemory,
)
