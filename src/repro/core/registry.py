"""Policy + hardware registry: name -> backend lookup for the simulator.

This is the extension seam the benchmarks and apps resolve through: a new
memory system is a :class:`~repro.core.policy.MemPolicy` subclass plus
(optionally) a :class:`~repro.core.hardware.HardwareModel`, registered once
and then selectable everywhere a policy name is accepted — ``run_app``,
``benchmarks/run.py --policy/--hw``, ``scripts/check_parity.py --policies``,
the serve stack's ``mem_policy`` knobs, and the policy-conformance contract
suite (tests/policy_contract.py), which runs against *every* registered
policy automatically.

    from repro.core.registry import register_policy, register_hardware

    register_policy("gpuvm", gpuvm_policy)      # factory: (**knobs) -> MemPolicy
    register_hardware("gpuvm-sim", GPUVM_HW)    # a HardwareModel instance

``make_policy(name, **knobs)`` filters the harness's uniform knob set
(page_size, threshold, auto_migrate, speculative_prefetch, ...) down to the
parameters the factory actually declares, so one call site can drive every
backend without each factory accepting every knob.
"""
from __future__ import annotations

import inspect
from typing import Callable, Dict, Tuple, Union

from repro.core.hardware import GRACE_HOPPER, MI300A, TPU_V5E, HardwareModel
from repro.core.policy import (
    MemPolicy,
    explicit_policy,
    managed_policy,
    mi300a_unified_policy,
    system_policy,
)

_POLICIES: Dict[str, Callable[..., MemPolicy]] = {}
_HARDWARE: Dict[str, HardwareModel] = {}


def register_policy(name: str, factory: Callable[..., MemPolicy]) -> None:
    """Register a policy factory under ``name``. The factory takes keyword
    knobs (any subset of the harness set — see :func:`make_policy`) and
    returns a MemPolicy instance. Re-registering a name overwrites it."""
    _POLICIES[name] = factory


def make_policy(name: str, **knobs) -> MemPolicy:
    """Build the named policy, passing through only the knobs its factory
    declares (a factory with ``**kwargs`` receives them all)."""
    try:
        factory = _POLICIES[name]
    except KeyError:
        raise KeyError(
            f"unknown memory policy {name!r}; registered: "
            f"{', '.join(available_policies())}") from None
    params = inspect.signature(factory).parameters
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return factory(**knobs)
    return factory(**{k: v for k, v in knobs.items() if k in params})


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_POLICIES))


def register_hardware(name: str, hw: HardwareModel) -> None:
    _HARDWARE[name] = hw


def get_hardware(hw: Union[str, HardwareModel, None]) -> HardwareModel:
    """Resolve a hardware model: an instance passes through, a name looks
    up the registry, None means the default (grace-hopper)."""
    if hw is None:
        return GRACE_HOPPER
    if isinstance(hw, HardwareModel):
        return hw
    try:
        return _HARDWARE[hw]
    except KeyError:
        raise KeyError(
            f"unknown hardware model {hw!r}; registered: "
            f"{', '.join(available_hardware())}") from None


def available_hardware() -> Tuple[str, ...]:
    return tuple(sorted(_HARDWARE))


# built-in backends
register_policy("system", system_policy)
register_policy("managed", managed_policy)
register_policy("explicit", explicit_policy)
register_policy("mi300a_unified", mi300a_unified_policy)

register_hardware(GRACE_HOPPER.name, GRACE_HOPPER)
register_hardware(MI300A.name, MI300A)
register_hardware(TPU_V5E.name, TPU_V5E)

# the cluster subsystem self-registers its hardware models and node-aware
# policies on import; imported last so register_policy/register_hardware
# above are already bound (cluster modules import repro.core submodules
# directly, never attributes of the repro.core package, avoiding a cycle)
import repro.cluster  # noqa: E402,F401
