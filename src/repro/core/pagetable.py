"""BlockTable: the software analogue of the Grace Hopper system page table.

One table per allocation. Pages start *unmapped* (PTEs exist only logically,
like malloc's lazy mapping); the first toucher maps each page to its tier
(first-touch policy) and pays the PTE-init cost. Access counters drive the
delayed migration strategy (threshold notifications, §2.2.1 of the paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import IntEnum
from typing import Tuple

import numpy as np


class Tier(IntEnum):
    UNMAPPED = -1
    HOST = 0
    DEVICE = 1


class Actor(IntEnum):
    CPU = 0
    GPU = 1  # "device": the GPU on GH, the TPU core in the adapted model

    @property
    def home_tier(self) -> Tier:
        return Tier.DEVICE if self is Actor.GPU else Tier.HOST


@dataclass
class BlockTable:
    name: str
    nbytes: int
    page_size: int

    def __post_init__(self):
        self.num_pages = max(1, -(-self.nbytes // self.page_size))
        self.tier = np.full(self.num_pages, int(Tier.UNMAPPED), np.int8)
        self.gpu_counter = np.zeros(self.num_pages, np.int32)
        self.cpu_counter = np.zeros(self.num_pages, np.int32)
        self.last_access_epoch = np.zeros(self.num_pages, np.int64)
        self.dirty = np.zeros(self.num_pages, bool)

    # -- ranges -------------------------------------------------------------
    def page_range(self, lo: int, hi: int) -> Tuple[int, int]:
        """[lo, hi) byte range -> [first_page, last_page) page range."""
        assert 0 <= lo <= hi <= self.nbytes, (lo, hi, self.nbytes)
        if lo == hi:
            return (0, 0)
        return lo // self.page_size, -(-hi // self.page_size)

    def page_bytes(self, idx: np.ndarray) -> np.ndarray:
        """Actual bytes covered by each page index (last page may be partial)."""
        full = np.full(len(idx), self.page_size, np.int64)
        tail = self.nbytes - (self.num_pages - 1) * self.page_size
        full[idx == self.num_pages - 1] = tail
        return full

    # -- views --------------------------------------------------------------
    def resident_bytes(self, tier: Tier) -> int:
        idx = np.nonzero(self.tier == int(tier))[0]
        return int(self.page_bytes(idx).sum()) if len(idx) else 0

    def mapped_fraction(self) -> float:
        return float((self.tier != int(Tier.UNMAPPED)).mean())

    def pages_in(self, tier: Tier) -> np.ndarray:
        return np.nonzero(self.tier == int(tier))[0]

    # -- mutations (called by UnifiedMemory) ---------------------------------
    def map_pages(self, pages: np.ndarray, tier: Tier) -> None:
        assert (self.tier[pages] == int(Tier.UNMAPPED)).all(), "double map"
        self.tier[pages] = int(tier)

    def move_pages(self, pages: np.ndarray, tier: Tier) -> None:
        assert (self.tier[pages] != int(Tier.UNMAPPED)).all(), "move of unmapped page"
        self.tier[pages] = int(tier)
        self.gpu_counter[pages] = 0
        self.cpu_counter[pages] = 0
