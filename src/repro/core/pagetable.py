"""BlockTable: the software analogue of the Grace Hopper system page table.

One table per allocation. Pages start *unmapped* (PTEs exist only logically,
like malloc's lazy mapping); the first toucher maps each page to its tier
(first-touch policy) and pays the PTE-init cost. Access counters drive the
delayed migration strategy (threshold notifications, §2.2.1 of the paper).

The table is extent-oriented: callers address pages as [lo_page, hi_page)
ranges, per-tier residency is tracked with O(1) cached byte/page counters
(updated incrementally by every mutation), and `tier_runs` exposes the
run-length (interval) view of the tier map. This keeps GB-scale allocations
at 4 KB pages tractable — no dense per-page index arrays on the hot path.
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import IntEnum
from typing import Tuple

import numpy as np

# tier-indexed counter slots: index = int(tier) + 1
_NTIERS = 3


def coalesce_runs(ids: np.ndarray):
    """Sorted unique integer ids -> maximal consecutive [lo, hi) runs.

    The shared run-coalescing primitive: BFS sparse-access extents and the
    serving pool's per-sequence page runs both reduce scattered page/node id
    sets to a handful of contiguous extents through this."""
    ids = np.asarray(ids, np.int64)
    if len(ids) == 0:
        return []
    splits = np.flatnonzero(np.diff(ids) != 1) + 1
    starts = ids[np.concatenate(([0], splits))]
    ends = ids[np.concatenate((splits - 1, [len(ids) - 1]))] + 1
    return [(int(s), int(e)) for s, e in zip(starts, ends)]


class Tier(IntEnum):
    UNMAPPED = -1
    HOST = 0
    DEVICE = 1


class Actor(IntEnum):
    CPU = 0
    GPU = 1  # "device": the GPU on GH, the TPU core in the adapted model

    @property
    def home_tier(self) -> Tier:
        return Tier.DEVICE if self is Actor.GPU else Tier.HOST


# byte deltas applied to the owner's cached residency totals
ResidencyDelta = Tuple[int, int]  # (host_bytes_delta, device_bytes_delta)


@dataclass
class BlockTable:
    name: str
    nbytes: int
    page_size: int

    def __post_init__(self):
        self.num_pages = max(1, -(-self.nbytes // self.page_size))
        self.tier = np.full(self.num_pages, int(Tier.UNMAPPED), np.int8)
        self.gpu_counter = np.zeros(self.num_pages, np.int32)
        self.cpu_counter = np.zeros(self.num_pages, np.int32)
        self.last_access_epoch = np.zeros(self.num_pages, np.int64)
        self.dirty = np.zeros(self.num_pages, bool)
        # bytes actually covered by the final (possibly partial) page
        self.tail_bytes = self.nbytes - (self.num_pages - 1) * self.page_size
        # cached per-tier residency: index int(tier)+1 -> pages / bytes
        self._tier_pages = np.zeros(_NTIERS, np.int64)
        self._tier_bytes = np.zeros(_NTIERS, np.int64)
        self._tier_pages[int(Tier.UNMAPPED) + 1] = self.num_pages
        self._tier_bytes[int(Tier.UNMAPPED) + 1] = self.nbytes

    # -- ranges -------------------------------------------------------------
    def page_range(self, lo: int, hi: int) -> Tuple[int, int]:
        """[lo, hi) byte range -> [first_page, last_page) page range."""
        assert 0 <= lo <= hi <= self.nbytes, (lo, hi, self.nbytes)
        if lo == hi:
            return (0, 0)
        return lo // self.page_size, -(-hi // self.page_size)

    def page_bytes(self, idx: np.ndarray) -> np.ndarray:
        """Actual bytes covered by each page index (last page may be partial)."""
        full = np.full(len(idx), self.page_size, np.int64)
        full[idx == self.num_pages - 1] = self.tail_bytes
        return full

    def page_bytes_slice(self, p0: int, p1: int) -> np.ndarray:
        """page_bytes for the contiguous extent [p0, p1) without an index array."""
        full = np.full(max(0, p1 - p0), self.page_size, np.int64)
        if p1 == self.num_pages and p1 > p0:
            full[-1] = self.tail_bytes
        return full

    def range_bytes(self, p0: int, p1: int) -> int:
        """O(1) bytes covered by the page extent [p0, p1)."""
        if p1 <= p0:
            return 0
        n = (p1 - p0) * self.page_size
        if p1 == self.num_pages:
            n += self.tail_bytes - self.page_size
        return n

    def _mask_bytes(self, p0: int, p1: int, mask: np.ndarray) -> int:
        """O(popcount) bytes covered by `mask` over the extent [p0, p1)."""
        n = int(np.count_nonzero(mask)) * self.page_size
        if n and p1 == self.num_pages and mask[-1]:
            n += self.tail_bytes - self.page_size
        return n

    # -- views --------------------------------------------------------------
    def resident_bytes(self, tier: Tier) -> int:
        return int(self._tier_bytes[int(tier) + 1])

    def resident_pages(self, tier: Tier) -> int:
        return int(self._tier_pages[int(tier) + 1])

    def mapped_fraction(self) -> float:
        unmapped = self._tier_pages[int(Tier.UNMAPPED) + 1]
        return float(1.0 - unmapped / self.num_pages)

    def pages_in(self, tier: Tier) -> np.ndarray:
        return np.nonzero(self.tier == int(tier))[0]

    def tier_runs(self, p0: int = 0, p1: int = -1):
        """Run-length view of the tier map over [p0, p1).

        Returns (starts, ends, tiers): maximal extents of constant tier —
        the interval representation of the page table."""
        if p1 < 0:
            p1 = self.num_pages
        t = self.tier[p0:p1]
        if len(t) == 0:
            return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                    np.zeros(0, np.int8))
        breaks = np.flatnonzero(np.diff(t)) + 1
        starts = np.concatenate(([0], breaks)) + p0
        ends = np.concatenate((breaks, [len(t)])) + p0
        return starts, ends, t[starts - p0]

    # -- mutations (called by UnifiedMemory) ---------------------------------
    def _account(self, old_tiers: np.ndarray, sizes: np.ndarray,
                 new_tier: Tier) -> ResidencyDelta:
        """Move cached counters for pages leaving `old_tiers` -> new_tier."""
        pages_out = np.bincount(old_tiers.astype(np.int64) + 1,
                                minlength=_NTIERS)
        bytes_out = np.bincount(old_tiers.astype(np.int64) + 1,
                                weights=sizes, minlength=_NTIERS).astype(np.int64)
        self._tier_pages -= pages_out
        self._tier_bytes -= bytes_out
        k = int(new_tier) + 1
        self._tier_pages[k] += int(pages_out.sum())
        self._tier_bytes[k] += int(bytes_out.sum())
        host = int(Tier.HOST) + 1
        dev = int(Tier.DEVICE) + 1
        dh = (int(bytes_out.sum()) if k == host else 0) - int(bytes_out[host])
        dd = (int(bytes_out.sum()) if k == dev else 0) - int(bytes_out[dev])
        return dh, dd

    def touch_range(self, p0: int, p1: int, epoch: int, write: bool) -> None:
        """Record an access over [p0, p1): LRU epoch + dirty on writes."""
        self.last_access_epoch[p0:p1] = epoch
        if write:
            self.dirty[p0:p1] = True

    def map_mask(self, p0: int, p1: int, mask: np.ndarray,
                 tier: Tier) -> ResidencyDelta:
        """Map the masked (unmapped) pages of extent [p0, p1) into `tier`."""
        view = self.tier[p0:p1]
        assert (view[mask] == int(Tier.UNMAPPED)).all(), "double map"
        view[mask] = int(tier)
        nbytes = self._mask_bytes(p0, p1, mask)
        npages = int(np.count_nonzero(mask))
        self._tier_pages[int(Tier.UNMAPPED) + 1] -= npages
        self._tier_bytes[int(Tier.UNMAPPED) + 1] -= nbytes
        self._tier_pages[int(tier) + 1] += npages
        self._tier_bytes[int(tier) + 1] += nbytes
        if tier is Tier.HOST:
            return nbytes, 0
        if tier is Tier.DEVICE:
            return 0, nbytes
        return 0, 0

    def map_pages(self, pages: np.ndarray, tier: Tier) -> ResidencyDelta:
        assert (self.tier[pages] == int(Tier.UNMAPPED)).all(), "double map"
        old = self.tier[pages]
        sizes = self.page_bytes(pages)
        self.tier[pages] = int(tier)
        return self._account(old, sizes, tier)

    def move_pages(self, pages: np.ndarray, tier: Tier) -> ResidencyDelta:
        """Retier mapped pages. `pages` MUST be unique indices: duplicates
        would double-count the cached residency deltas (and can defeat the
        contiguity detection below). Every runtime call site passes unique
        pages (nonzero/flatnonzero/unique products)."""
        n = len(pages)
        if n:
            mn, mx = int(pages.min()), int(pages.max())
            if mx - mn + 1 == n:  # unique pages => contiguous extent (typical:
                # streaming windows, LRU victim runs): slice ops, no fancy indexing
                return self.move_extent(mn, mx + 1, tier)
        assert (self.tier[pages] != int(Tier.UNMAPPED)).all(), "move of unmapped page"
        old = self.tier[pages]
        sizes = self.page_bytes(pages)
        self.tier[pages] = int(tier)
        self.gpu_counter[pages] = 0
        self.cpu_counter[pages] = 0
        return self._account(old, sizes, tier)

    def move_extent(self, p0: int, p1: int, tier: Tier) -> ResidencyDelta:
        """move_pages for the contiguous extent [p0, p1)."""
        view = self.tier[p0:p1]
        assert (view != int(Tier.UNMAPPED)).all(), "move of unmapped page"
        old = view.copy()
        sizes = self.page_bytes_slice(p0, p1)
        view[:] = int(tier)
        self.gpu_counter[p0:p1] = 0
        self.cpu_counter[p0:p1] = 0
        return self._account(old, sizes, tier)
