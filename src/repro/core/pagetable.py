"""BlockTable: the software analogue of the Grace Hopper system page table.

One table per allocation. Pages start *unmapped* (PTEs exist only logically,
like malloc's lazy mapping); the first toucher maps each page to its tier
(first-touch policy) and pays the PTE-init cost. Access counters drive the
delayed migration strategy (threshold notifications, §2.2.1 of the paper).

The table is *run-compressed*: tier state, LRU epochs, dirty bits and GPU
access counters are each a :class:`repro.core.runs.RunMap` — sorted
``(start, value)`` run boundaries — so every operation costs O(runs
overlapping the extent), never O(pages in extent), and metadata memory is
O(fragmentation), not O(allocation size). A 16 GiB allocation at 4 KB pages
(4M+ PTEs) whose residency is a handful of uniform extents carries a few
hundred bytes of metadata and mutates in microseconds. Per-tier residency
is tracked with O(1) cached byte/page counters (updated incrementally by
every mutation), and ``tier_runs`` exposes the interval view directly —
it *is* the primary structure, not a derived one. The dense per-page
arrays of the previous implementation survive only as materialized
read-only properties (``tier``, ``dirty``, ``last_access_epoch``,
``gpu_counter``) for tests and debugging.
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import IntEnum
from typing import Tuple

import numpy as np

from repro.core.runs import RunMap, union_runs

# tier-indexed counter slots: index = int(tier) + 1
_NTIERS = 3


def node_tier_loc(node: int, tier: "Tier") -> int:
    """Encode a (node, tier) placement as one small int: ``2*node + tier``.

    Host locations are even (0, 2, 4, ...), device locations odd (1, 3, ...),
    UNMAPPED stays -1 — so for ``node == 0`` the encoding *is* the plain
    Tier value, which is what makes the node dimension bit-identical at
    N=1. The int8 RunMap payload bounds the encoding at 63 nodes."""
    t = int(tier)
    assert t >= 0, "cannot place a page at (node, UNMAPPED)"
    return 2 * node + t


def loc_node(loc: int) -> int:
    """Node index of an encoded location (UNMAPPED -> node 0)."""
    return max(0, int(loc)) // 2


def loc_tier(loc: int) -> "Tier":
    """Tier of an encoded location (parity: even = HOST, odd = DEVICE)."""
    loc = int(loc)
    return Tier.UNMAPPED if loc < 0 else Tier(loc & 1)


def coalesce_runs(ids: np.ndarray):
    """Sorted unique integer ids -> maximal consecutive [lo, hi) runs.

    The shared run-coalescing primitive: BFS sparse-access extents and the
    serving pool's per-sequence page runs both reduce scattered page/node id
    sets to a handful of contiguous extents through this."""
    ids = np.asarray(ids, np.int64)
    if len(ids) == 0:
        return []
    splits = np.flatnonzero(np.diff(ids) != 1) + 1
    starts = ids[np.concatenate(([0], splits))]
    ends = ids[np.concatenate((splits - 1, [len(ids) - 1]))] + 1
    return [(int(s), int(e)) for s, e in zip(starts, ends)]


class Tier(IntEnum):
    UNMAPPED = -1
    HOST = 0
    DEVICE = 1


class Actor(IntEnum):
    CPU = 0
    GPU = 1  # "device": the GPU on GH, the TPU core in the adapted model

    @property
    def home_tier(self) -> Tier:
        return Tier.DEVICE if self is Actor.GPU else Tier.HOST


# byte deltas applied to the owner's cached residency totals
ResidencyDelta = Tuple[int, int]  # (host_bytes_delta, device_bytes_delta)


@dataclass
class BlockTable:
    name: str
    nbytes: int
    page_size: int
    # number of (node, tier) locations a page can occupy: a single-node
    # table (the default) has exactly the three classic tier slots, an
    # N-node table has 2N+1 (UNMAPPED + per-node HOST/DEVICE via
    # node_tier_loc). Every counter below is indexed loc+1.
    num_nodes: int = 1

    def __post_init__(self):
        self.num_pages = max(1, -(-self.nbytes // self.page_size))
        # bytes actually covered by the final (possibly partial) page
        self.tail_bytes = self.nbytes - (self.num_pages - 1) * self.page_size
        n = self.num_pages
        self._nlocs = 2 * self.num_nodes + 1
        # run-compressed per-page metadata: O(runs), never O(pages)
        self._tier = RunMap(n, int(Tier.UNMAPPED), np.int8)
        self._epoch = RunMap(n, 0, np.int64)
        self._dirty = RunMap(n, 0, np.int8)
        self._gpu_counter = RunMap(n, 0, np.int64)
        # cached per-location residency: index int(loc)+1 -> pages / bytes
        self._tier_pages = np.zeros(self._nlocs, np.int64)
        self._tier_bytes = np.zeros(self._nlocs, np.int64)
        self._tier_pages[int(Tier.UNMAPPED) + 1] = n
        self._tier_bytes[int(Tier.UNMAPPED) + 1] = self.nbytes

    # -- ranges -------------------------------------------------------------
    def page_range(self, lo: int, hi: int) -> Tuple[int, int]:
        """[lo, hi) byte range -> [first_page, last_page) page range."""
        assert 0 <= lo <= hi <= self.nbytes, (lo, hi, self.nbytes)
        if lo == hi:
            return (0, 0)
        return lo // self.page_size, -(-hi // self.page_size)

    def page_bytes(self, idx: np.ndarray) -> np.ndarray:
        """Actual bytes covered by each page index (last page may be partial)."""
        full = np.full(len(idx), self.page_size, np.int64)
        full[idx == self.num_pages - 1] = self.tail_bytes
        return full

    def page_bytes_slice(self, p0: int, p1: int) -> np.ndarray:
        """page_bytes for the contiguous extent [p0, p1) without an index array."""
        full = np.full(max(0, p1 - p0), self.page_size, np.int64)
        if p1 == self.num_pages and p1 > p0:
            full[-1] = self.tail_bytes
        return full

    def range_bytes(self, p0: int, p1: int) -> int:
        """O(1) bytes covered by the page extent [p0, p1)."""
        if p1 <= p0:
            return 0
        n = (p1 - p0) * self.page_size
        if p1 == self.num_pages:
            n += self.tail_bytes - self.page_size
        return n

    def span_bytes(self, starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
        """Vectorized range_bytes over disjoint ascending [s, e) runs."""
        b = (ends - starts) * self.page_size
        if len(b) and ends[-1] == self.num_pages:
            b[-1] += self.tail_bytes - self.page_size
        return b

    def clipped_extent_bytes(self, p0: int, p1: int, lo: int, hi: int) -> int:
        """Bytes of the page span [p0, p1) clipped to the kernel byte range
        [lo, hi) — the single boundary-page clip primitive of the charge
        model (hoisted out of kernel()'s duplicated head/tail math).

        Matches the historical dense per-page accounting bit-for-bit,
        including its quirk: when the span ends at the table's final
        *partial* page, the tail clip subtracts the full-page overhang
        ``p1*page_size - hi`` from a page that only holds ``tail_bytes``,
        under-counting by ``page_size - tail_bytes`` (and possibly going
        negative). The golden parity fixture pins this behavior; fixing it
        is a deliberate charge-model change, not a refactor."""
        b = self.range_bytes(p0, p1)
        b -= max(0, lo - p0 * self.page_size)
        b -= max(0, p1 * self.page_size - hi)
        return b

    # -- views --------------------------------------------------------------
    def resident_bytes(self, tier: Tier) -> int:
        return int(self._tier_bytes[int(tier) + 1])

    def resident_pages(self, tier: Tier) -> int:
        return int(self._tier_pages[int(tier) + 1])

    def residency_by_side(self) -> Tuple[int, int]:
        """(host_bytes, device_bytes) summed across nodes — the location
        encoding puts every host slot at an odd counter index and every
        device slot at an even one (index = loc + 1), so the sums reduce
        to the classic two-tier totals for a single-node table."""
        return (int(self._tier_bytes[1::2].sum()),
                int(self._tier_bytes[2::2].sum()))

    def mapped_fraction(self) -> float:
        unmapped = self._tier_pages[int(Tier.UNMAPPED) + 1]
        return float(1.0 - unmapped / self.num_pages)

    def pages_in(self, tier: Tier) -> np.ndarray:
        """Materialized page indices in `tier` (O(matching pages) — tests)."""
        s, e = self.runs_of(tier)
        if len(s) == 0:
            return np.empty(0, np.int64)
        return np.concatenate([np.arange(a, b) for a, b in zip(s, e)])

    def tier_runs(self, p0: int = 0, p1: int = -1):
        """Run-length view of the tier map over [p0, p1).

        Returns (starts, ends, tiers): maximal extents of constant tier —
        the primary interval representation of the page table. The tiers
        array is a read-only view: mutating tier state through it would
        bypass the cached residency counters."""
        if p1 < 0:
            p1 = self.num_pages
        s, e, v = self._tier.runs(p0, p1)
        v = v.view()
        v.setflags(write=False)
        return s, e, v

    def runs_of(self, tier: Tier, p0: int = 0, p1: int = -1):
        """(starts, ends) of the sub-runs holding `tier` within [p0, p1)."""
        if p1 < 0:
            p1 = self.num_pages
        s, e, v = self._tier.runs(p0, p1)
        m = v == int(tier)
        return s[m], e[m]

    def unmapped_stats(self, p0: int, p1: int) -> Tuple[int, int]:
        """(pages, bytes) still unmapped within [p0, p1)."""
        s, e = self.runs_of(Tier.UNMAPPED, p0, p1)
        if len(s) == 0:
            return 0, 0
        return int((e - s).sum()), int(self.span_bytes(s, e).sum())

    def epoch_runs(self, p0: int, p1: int):
        """(starts, ends, epochs) of the LRU-epoch runs within [p0, p1)."""
        return self._epoch.runs(p0, p1)

    def bump_counter(self, p0: int, p1: int, txn: int):
        """Add `txn` to the GPU access counter over every page of [p0, p1).
        Returns the (starts, ends, before) pieces so the caller can apply
        threshold-crossing logic against the pre-bump values."""
        cs, ce, cv = self._gpu_counter.runs(p0, p1)
        self._gpu_counter.splice(p0, p1, cs, cv + txn)
        return cs, ce, cv

    def dirty_bytes(self, starts, ends) -> int:
        """Bytes of the dirty pages inside the given [s, e) spans."""
        nbytes = 0
        for s0, e0 in zip(starts, ends):
            ds, de = self._dirty.nonzero_runs(int(s0), int(e0))
            if len(ds):
                nbytes += int(self.span_bytes(ds, de).sum())
        return nbytes

    def clear_dirty(self, starts, ends) -> None:
        """Drop the dirty bit over the given [s, e) spans (writeback done)."""
        for s0, e0 in zip(starts, ends):
            self._dirty.set_range(int(s0), int(e0), 0)

    def recount(self) -> Tuple[np.ndarray, np.ndarray]:
        """Recompute per-tier (pages, bytes) from the run structure — the
        slow-path reference the cached counters are tested against."""
        s, e, v = self._tier.runs()
        idx = v.astype(np.int64) + 1
        pages = np.bincount(idx, weights=(e - s),
                            minlength=self._nlocs).astype(np.int64)
        nbytes = np.bincount(idx, weights=self.span_bytes(s, e),
                             minlength=self._nlocs).astype(np.int64)
        return pages, nbytes

    def metadata_nbytes(self) -> int:
        """Bytes of run-compressed metadata — O(fragmentation), not O(pages)."""
        return sum(m.bytes_used() for m in
                   (self._tier, self._epoch, self._dirty, self._gpu_counter))

    # -- dense materializations (tests/debug only: O(num_pages)) -------------
    @property
    def tier(self) -> np.ndarray:
        return self._tier.to_dense()

    @property
    def last_access_epoch(self) -> np.ndarray:
        return self._epoch.to_dense()

    @property
    def dirty(self) -> np.ndarray:
        return self._dirty.to_dense().astype(bool)

    @property
    def gpu_counter(self) -> np.ndarray:
        return self._gpu_counter.to_dense()

    # -- mutations (called by UnifiedMemory) ---------------------------------
    def _shift_counters(self, pages_out: np.ndarray, bytes_out: np.ndarray,
                        new_tier: Tier) -> ResidencyDelta:
        """Move cached counters for pages leaving per-tier `..._out` slots."""
        k = int(new_tier) + 1
        tot_p, tot_b = int(pages_out.sum()), int(bytes_out.sum())
        self._tier_pages -= pages_out
        self._tier_bytes -= bytes_out
        self._tier_pages[k] += tot_p
        self._tier_bytes[k] += tot_b
        # side deltas by counter-index parity: host slots odd, device even
        dh = (tot_b if k % 2 == 1 else 0) - int(bytes_out[1::2].sum())
        dd = (tot_b if k % 2 == 0 and k != 0 else 0) - int(bytes_out[2::2].sum())
        return dh, dd

    def touch_range(self, p0: int, p1: int, epoch: int, write: bool) -> None:
        """Record an access over [p0, p1): LRU epoch + dirty on writes."""
        if p1 <= p0:
            return
        self._epoch.set_range(p0, p1, epoch)
        if write:
            self._dirty.set_range(p0, p1, 1)

    def touch_batch(self, starts, ends, epochs, writes) -> None:
        """touch_range for a whole batch of extents in one pass.

        Per page the recorded epoch is the max over covering extents; since
        the caller hands epochs that are positive and nondecreasing in
        submission order, max == the last writer, matching N sequential
        touch_range calls. Dirty is set over the union of write extents.
        Cost is O(batch·log batch + runs touched), never O(pages)."""
        starts = np.asarray(starts, np.int64)
        ends = np.asarray(ends, np.int64)
        epochs = np.asarray(epochs, np.int64)
        writes = np.asarray(writes, bool)
        m = ends > starts
        if not m.all():
            starts, ends, epochs, writes = (starts[m], ends[m],
                                            epochs[m], writes[m])
        if len(starts) == 0:
            return
        # segment sweep: breakpoints split [min, max) into atomic segments;
        # scatter each extent's epoch into its segment span with maximum.at
        bp = np.unique(np.concatenate((starts, ends)))
        segmax = np.zeros(len(bp) - 1, np.int64)
        i0 = np.searchsorted(bp, starts)
        cnt = np.searchsorted(bp, ends) - i0
        seg = (np.repeat(i0, cnt)
               + np.arange(int(cnt.sum())) - np.repeat(np.cumsum(cnt) - cnt, cnt))
        np.maximum.at(segmax, seg, np.repeat(epochs, cnt))
        for a, b in coalesce_runs(np.flatnonzero(segmax > 0)):
            self._epoch.splice(int(bp[a]), int(bp[b]), bp[a:b], segmax[a:b])
        if writes.any():
            ws, we = starts[writes], ends[writes]
            order = np.argsort(ws, kind="stable")
            for s0, e0 in zip(*union_runs(ws[order], we[order])):
                self._dirty.set_range(int(s0), int(e0), 1)

    def map_unmapped(self, p0: int, p1: int, tier: Tier) -> ResidencyDelta:
        """First-touch: map every unmapped page of [p0, p1) into `tier`."""
        s, e = self.runs_of(Tier.UNMAPPED, p0, p1)
        if len(s) == 0:
            return 0, 0
        npages = int((e - s).sum())
        nbytes = int(self.span_bytes(s, e).sum())
        for a, b in zip(s, e):
            self._tier.set_range(int(a), int(b), int(tier))
        self._tier_pages[int(Tier.UNMAPPED) + 1] -= npages
        self._tier_bytes[int(Tier.UNMAPPED) + 1] -= nbytes
        self._tier_pages[int(tier) + 1] += npages
        self._tier_bytes[int(tier) + 1] += nbytes
        if int(tier) % 2 == 0:  # even locations are host-side
            return nbytes, 0
        return 0, nbytes

    def move_runs(self, starts, ends, tier: Tier) -> ResidencyDelta:
        """Retier the mapped pages of disjoint ascending [s, e) spans;
        resets their access counters (migration semantics)."""
        pages_out = np.zeros(self._nlocs, np.int64)
        bytes_out = np.zeros(self._nlocs, np.float64)
        for a, b in zip(starts, ends):
            a, b = int(a), int(b)
            s, e, v = self._tier.runs(a, b)
            assert (v != int(Tier.UNMAPPED)).all(), "move of unmapped page"
            idx = v.astype(np.int64) + 1
            pages_out += np.bincount(idx, weights=(e - s),
                                     minlength=self._nlocs).astype(np.int64)
            bytes_out += np.bincount(idx, weights=self.span_bytes(s, e),
                                     minlength=self._nlocs)
            self._tier.set_range(a, b, int(tier))
            self._gpu_counter.set_range(a, b, 0)
        return self._shift_counters(pages_out, bytes_out.astype(np.int64), tier)

    def move_extent(self, p0: int, p1: int, tier: Tier) -> ResidencyDelta:
        """move_runs for one contiguous extent [p0, p1)."""
        return self.move_runs((p0,), (p1,), tier)

    # -- compat wrappers over scattered page-index arrays (tests) ------------
    def map_mask(self, p0: int, p1: int, mask: np.ndarray,
                 tier: Tier) -> ResidencyDelta:
        """Map the masked (unmapped) pages of extent [p0, p1) into `tier`."""
        return self.map_pages(p0 + np.flatnonzero(np.asarray(mask, bool)), tier)

    def map_pages(self, pages: np.ndarray, tier: Tier) -> ResidencyDelta:
        dh = dd = 0
        for a, b in coalesce_runs(np.unique(np.asarray(pages, np.int64))):
            _, _, v = self._tier.runs(a, b)
            assert (v == int(Tier.UNMAPPED)).all(), "double map"
            h, d = self.map_unmapped(a, b, tier)
            dh += h
            dd += d
        return dh, dd

    def move_pages(self, pages: np.ndarray, tier: Tier) -> ResidencyDelta:
        """Retier mapped pages. `pages` MUST be unique indices (duplicates
        would double-count the cached residency deltas)."""
        runs = coalesce_runs(np.unique(np.asarray(pages, np.int64)))
        return self.move_runs([r[0] for r in runs], [r[1] for r in runs], tier)
