"""UM-backed training: the oversubscribed training loop over the charge model.

The missing workload family of ROADMAP item 4: training has a phase
structure — forward over the layers, backward re-reading the activation
stash, an optimizer update over state that is cold the rest of the step —
that stresses a shared-memory design very differently from the inference
and HPC apps. :class:`UMTrainer` drives exactly that structure through
:class:`~repro.core.umem.UnifiedMemory`:

* the state tree (params, grad accumulators, AdamW m/v/master, per-layer
  activation stash) lives in UMBuffers mapped by a
  :class:`~repro.train.offload.TrainMemPlan` under any registered policy;
* every phase issues per-layer :class:`~repro.core.umem.KernelBatch`
  launches (the PR 6 batched engine charges a whole layer's train of
  kernels in one pass), with the plan's placement hints — prefetch the
  next layer's params, demote the cold moments — at the phase boundaries;
* checkpoint saves are UM pressure events (``CheckpointManager.save``
  syncs and charges the dirty-device d2h drain) and elastic resizes go
  through ``runtime.elastic.resize_um_capacity`` mid-run.

The *math* is real numpy fp32 with a fixed op order, entirely independent
of the memory model — so losses are bit-identical across every policy,
oversubscription ratio, checkpoint cadence and resize schedule, and the
tests assert exactly that. The *memory system* is modeled: step times come
from ``um.clock`` (the same modeled clock the serve stack reports), which
is what produces the fig11-style step-time-vs-ratio curves in
benchmarks/train_oversub.py.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Union

import numpy as np

from repro.core import Actor, UnifiedMemory, get_hardware, make_policy
from repro.core.policy import MemPolicy
from repro.core.umem import KernelBatch
from repro.train.offload import (
    TrainHints,
    TrainMemPlan,
    TrainModelSpec,
    capacity_for,
    get_train_model,
)

__all__ = ["UMTrainer"]

KB = 1024
F32 = np.float32


class UMTrainer:
    """Training driver over the charge model.

    ``policy`` is a registered backend name or a MemPolicy instance. With
    ``um=None`` the trainer builds its own runtime on ``hw`` with the
    device sized for ``ratio``-fold oversubscription of the working set
    (see :func:`~repro.train.offload.capacity_for`); passing ``um``
    (e.g. the contract suite's default-capacity runtime) uses it as-is.
    """

    def __init__(self, spec: Union[TrainModelSpec, str],
                 policy: Union[str, MemPolicy] = "system", *,
                 hw=None, ratio: float = 1.0, page_size: int = 64 * KB,
                 hints: Optional[TrainHints] = None,
                 um: Optional[UnifiedMemory] = None,
                 lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 seed: int = 0):
        self.spec = get_train_model(spec) if isinstance(spec, str) else spec
        pol = (make_policy(policy, page_size=page_size)
               if isinstance(policy, str) else policy)
        self.policy = pol
        self.ratio = float(ratio)
        if um is None:
            hwm = get_hardware(hw)
            self.capacity = capacity_for(self.spec, pol, self.ratio)
            um = UnifiedMemory(hw=hwm.with_device_capacity(self.capacity))
        else:
            self.capacity = um.hw.device_capacity
        self.um = um
        self.plan = TrainMemPlan(um, self.spec, pol, hints=hints)
        self.lr, self.b1, self.b2 = F32(lr), F32(b1), F32(b2)
        self.eps, self.wd = F32(eps), F32(weight_decay)
        self._seed = int(seed)
        self._step = 0  # completed steps (== AdamW bias-correction count)
        self.history: List[Dict[str, Any]] = []
        self.events: List[Dict[str, Any]] = []
        self._init_state()

    # -------------------------------------------------------------- numerics
    def _init_state(self) -> None:
        s = self.spec
        rng = np.random.default_rng([self._seed])
        s1, s2 = F32(1.0 / math.sqrt(s.d_model)), F32(1.0 / math.sqrt(s.d_ff))
        self.W1 = [rng.standard_normal((s.d_model, s.d_ff), F32) * s1
                   for _ in range(s.n_layers)]
        self.W2 = [rng.standard_normal((s.d_ff, s.d_model), F32) * s2
                   for _ in range(s.n_layers)]
        self.MW1 = [w.copy() for w in self.W1]  # fp32 master weights
        self.MW2 = [w.copy() for w in self.W2]
        zeros = lambda w: np.zeros_like(w)  # noqa: E731
        self.M1 = [zeros(w) for w in self.W1]
        self.V1 = [zeros(w) for w in self.W1]
        self.M2 = [zeros(w) for w in self.W2]
        self.V2 = [zeros(w) for w in self.W2]
        self.G1 = [None] * s.n_layers
        self.G2 = [None] * s.n_layers
        # charge the host-side first touch of the durable state tree
        with self.um.phase("init"):
            self.um.launch_batch(self.plan.init_launches())
            self.um.sync()

    def now(self) -> float:
        """The modeled clock (same convention as ``ServeEngine.now()``)."""
        return self.um.clock

    @property
    def losses(self) -> List[float]:
        return [h["loss"] for h in self.history]

    # ------------------------------------------------------------------ step
    def step(self) -> Dict[str, Any]:
        s, um, plan = self.spec, self.um, self.plan
        R, d, f, L = s.rows, s.d_model, s.d_ff, s.n_layers
        rng = np.random.default_rng([self._seed, 1000 + self._step])
        X = rng.standard_normal((R, d), F32)
        Y = rng.standard_normal((R, d), F32)
        t0 = um.clock

        with um.phase("load"):
            um.launch("load_batch", writes=[plan.x(), plan.y()],
                      actor=Actor.CPU)

        # x/y upload is a staging boundary: charged only under the
        # explicit port, pass-through everywhere else
        with um.staged(h2d=[plan.x(), plan.y()]):
            # ----------------------------------------------------- forward
            h = X
            hins, zs = [], []
            with um.phase("fwd"):
                um.launch("seed_h", reads=[plan.x()], writes=[plan.h_res()])
                for l in range(L):
                    plan.pre_fwd(l)
                    a = h @ self.W1[l]
                    z = np.tanh(a)
                    hins.append(h)
                    zs.append(z)
                    h = h + z @ self.W2[l]
                    nd = plan.node_of(l)
                    kb = KernelBatch()
                    kb.launch("fwd_w1", reads=[plan.w1(l), plan.h_res()],
                              writes=[plan.z(l), plan.h_in(l)],
                              flops=2.0 * R * d * f, node=nd)
                    kb.launch("fwd_w2",
                              reads=[plan.w2(l), plan.z(l), plan.h_res()],
                              writes=[plan.h_res()],
                              flops=2.0 * R * f * d, node=nd)
                    um.launch_batch(kb)
                    plan.post_fwd(l)
                diff = h - Y
                loss = float(np.mean(diff * diff))
                um.launch("loss", reads=[plan.h_res(), plan.y()],
                          writes=[plan.loss_out()], flops=3.0 * R * d)

            # ---------------------------------------------------- backward
            dh = (F32(2.0) / F32(R * d)) * diff
            with um.phase("bwd"):
                um.launch("bwd_seed", reads=[plan.h_res(), plan.y()],
                          writes=[plan.scratch()], flops=2.0 * R * d)
                for l in reversed(range(L)):
                    plan.pre_bwd(l)
                    z, hin = zs[l], hins[l]
                    dz = dh @ self.W2[l].T
                    da = dz * (F32(1.0) - z * z)
                    self.G1[l] = hin.T @ da
                    self.G2[l] = z.T @ dh
                    dh = dh + da @ self.W1[l].T
                    nd = plan.node_of(l)
                    kb = KernelBatch()
                    kb.launch("bwd_dz",
                              reads=[plan.w2(l), plan.z(l), plan.scratch()],
                              writes=[plan.scratch()],
                              flops=2.0 * R * d * f, node=nd)
                    kb.launch("bwd_grad",
                              reads=[plan.h_in(l), plan.z(l), plan.scratch()],
                              writes=[plan.grads(l)],
                              flops=4.0 * R * d * f, node=nd)
                    kb.launch("bwd_dh", reads=[plan.w1(l), plan.scratch()],
                              writes=[plan.scratch()],
                              flops=2.0 * R * d * f, node=nd)
                    um.launch_batch(kb)
                    plan.post_bwd(l)

        # ---------------------------------------------------------- update
        self._step += 1
        t = self._step
        bc1 = F32(1.0) - self.b1 ** t  # fp32 bias corrections, fixed order
        bc2 = F32(1.0) - self.b2 ** t
        one = F32(1.0)
        with um.phase("opt"):
            for l in range(L):
                for W, MW, M, V, G in (
                        (self.W1[l], self.MW1[l], self.M1[l], self.V1[l],
                         self.G1[l]),
                        (self.W2[l], self.MW2[l], self.M2[l], self.V2[l],
                         self.G2[l])):
                    M *= self.b1
                    M += (one - self.b1) * G
                    V *= self.b2
                    V += (one - self.b2) * (G * G)
                    upd = (M / bc1) / (np.sqrt(V / bc2) + self.eps)
                    MW -= self.lr * (upd + self.wd * MW)
                    W[:] = MW
                n = float(s.layer_params)
                nd = plan.node_of(l)
                kb = KernelBatch()
                kb.launch("adamw",
                          reads=[plan.grads_state(l), plan.m_state(l),
                                 plan.v_state(l), plan.master_state(l)],
                          writes=[plan.m_state(l), plan.v_state(l),
                                  plan.master_state(l)],
                          actor=Actor.CPU, flops=12.0 * n, node=nd)
                # push the fresh weights back into the compute copy: GPU
                # pulls them under resident backends, the staged port keeps
                # params host-side and re-uploads per layer next step
                kb.launch("refresh", reads=[plan.master_state(l)],
                          writes=[plan.params_state(l)],
                          actor=Actor.CPU if plan.staged else Actor.GPU,
                          flops=n, node=nd)
                um.launch_batch(kb)
                plan.post_opt(l)
            um.sync()

        rec = {"step": self._step, "loss": loss, "dt": um.clock - t0}
        self.history.append(rec)
        return rec

    # ------------------------------------------------------------------- run
    def run(self, num_steps: int, *, ckpt=None, ckpt_every: int = 0,
            resize_at: Optional[Dict[int, int]] = None) -> Dict[str, Any]:
        """Drive ``num_steps`` steps. ``ckpt``/``ckpt_every`` snapshot the
        durable state through :meth:`save_checkpoint` at the step
        boundary; ``resize_at`` maps *completed-step counts* to new device
        capacities applied before the next step (an elastic pressure
        event — never a math event)."""
        resize = dict(resize_at or {})
        for _ in range(num_steps):
            if self._step in resize:
                self.resize_device_capacity(resize.pop(self._step))
            self.step()
            if ckpt is not None and ckpt_every \
                    and self._step % ckpt_every == 0:
                self.save_checkpoint(ckpt)
        if ckpt is not None:
            ckpt.wait()
        dts = [h["dt"] for h in self.history]
        total = sum(dts)
        return {
            "history": self.history,
            "losses": self.losses,
            "modeled_s": total,
            "steps_per_s": (len(dts) / total) if total else 0.0,
            "capacity": self.capacity,
            "peak_bytes": self.plan.peak_bytes,
            "demand_bytes": self.plan.demand_bytes,
            "eff_ratio": self.plan.demand_bytes / self.capacity,
            "events": self.events,
        }

    # ----------------------------------------------------------- checkpoints
    def state_tree(self) -> Dict[str, Any]:
        """The durable state a checkpoint carries (params + optimizer tree
        + the AdamW step count), path-keyed per layer."""
        L = self.spec.n_layers
        return {
            "params": {f"l{l}": {"W1": self.W1[l], "W2": self.W2[l]}
                       for l in range(L)},
            "opt": {f"l{l}": {"m1": self.M1[l], "v1": self.V1[l],
                              "m2": self.M2[l], "v2": self.V2[l],
                              "w1": self.MW1[l], "w2": self.MW2[l]}
                    for l in range(L)},
            "step": np.int64(self._step),
        }

    def save_checkpoint(self, ckpt) -> None:
        """Snapshot through CheckpointManager as a UM pressure event: the
        runtime syncs and the dirty device-resident runs of the durable
        state charge their d2h drain before the host copy is taken."""
        ckpt.save(self._step, self.state_tree(), um=self.um,
                  drain=self.plan.checkpoint_ranges())
        self.events.append({"kind": "checkpoint", "step": self._step,
                            "clock": self.um.clock})

    def restore_checkpoint(self, ckpt, step: Optional[int] = None) -> int:
        """Load a snapshot back into the live arrays (in place, so the
        UMBuffer mapping is untouched) and charge the host-side rewrite of
        the restored state."""
        got, tree = ckpt.restore(self.state_tree(), step=step)
        for l in range(self.spec.n_layers):
            p, o = tree["params"][f"l{l}"], tree["opt"][f"l{l}"]
            self.W1[l][:] = p["W1"]
            self.W2[l][:] = p["W2"]
            self.M1[l][:] = o["m1"]
            self.V1[l][:] = o["v1"]
            self.M2[l][:] = o["m2"]
            self.V2[l][:] = o["v2"]
            self.MW1[l][:] = o["w1"]
            self.MW2[l][:] = o["w2"]
        self._step = int(tree["step"])
        with self.um.phase("restore"):
            self.um.launch_batch(self.plan.init_launches())
            self.um.sync()
        self.events.append({"kind": "restore", "step": self._step,
                            "clock": self.um.clock})
        return got

    # --------------------------------------------------------------- elastic
    def resize_device_capacity(self, nbytes: int) -> None:
        """Elastic resize mid-run: shrink/grow the modeled device through
        ``runtime.elastic.resize_um_capacity``. Purely a pressure event —
        the next launches see the new headroom and the policy evicts or
        spills; losses cannot change."""
        from repro.runtime import resize_um_capacity

        resize_um_capacity(self.um, nbytes)
        self.capacity = int(nbytes)
        self.events.append({"kind": "resize", "step": self._step,
                            "capacity": int(nbytes), "clock": self.um.clock})

    # ------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Free the plan's allocations (residency returns to the pre-plan
        baseline) and drop the numpy state."""
        self.plan.close()
        for attr in ("W1", "W2", "MW1", "MW2", "M1", "V1", "M2", "V2",
                     "G1", "G2"):
            setattr(self, attr, [])
