from repro.train.trainer import Trainer, TrainerConfig, make_train_step, make_train_state  # noqa: F401
from repro.train.offload import (  # noqa: F401
    TRAIN_MODELS,
    TrainHints,
    TrainMemPlan,
    TrainModelSpec,
    capacity_for,
    device_demand_bytes,
    get_train_model,
    state_bytes,
)
from repro.train.umtrain import UMTrainer  # noqa: F401
