from repro.train.trainer import Trainer, TrainerConfig, make_train_step, make_train_state  # noqa: F401
