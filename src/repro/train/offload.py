"""Training offload plan: the UM-backed state tree of an oversubscribed run.

A training step owns five long-lived tensor groups per layer — params, grad
accumulators, AdamW first/second moments, fp32 master weights — plus a
per-layer activation stash written by the forward pass and re-read by the
backward pass. :class:`TrainMemPlan` maps that tree onto
:class:`~repro.core.buffer.UMBuffer` allocations under one registered memory
policy and owns every *placement* decision, so the trainer
(train/umtrain.py) stays a single code path over all backends:

* **Resident mode** (every paged backend: system / managed / mi300a_unified /
  cluster_*): each group is a per-layer buffer under the training policy.
  Oversubscription comes from sizing the device via
  ``HardwareModel.with_device_capacity`` (see :func:`capacity_for`), and the
  policy's own pressure behavior — graceful host mapping, LRU eviction,
  single-pool OOM — produces the fig11-style degradation curve.
* **Staged mode** (the table-less explicit backend): the ZeRO-offload-style
  port. Full state lives in host buffers (the malloc side, a
  non-auto-migrating system-policy table); the device holds fixed slabs
  sized to ONE layer's params / grads / activations plus the residual
  stream, and the plan charges the per-layer h2d/d2h slab traffic that a
  hand-written double-buffered port would issue.

Placement hints (:class:`TrainHints`) are the paper's "practical
optimization strategies" applied to training: ``prefetch_async`` the next
layer's params ahead of its forward launch, ``demote`` the cold optimizer
moments right after the update consumed them. Hints are capability-gated —
a non-migratable pool (mi300a_unified) turns them into no-ops, exactly as
``cudaMemPrefetchAsync`` degenerates on a single physical pool.

Node-aware backends (``policy.node_aware``) get layers round-robined over
the superchips: layer ``l`` issues from node ``l % nodes`` via
``KernelLaunch(node=...)`` / ``um.on_node`` — no topology access outside
the cluster seam.
"""
from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core import Actor, UnifiedMemory, system_policy
from repro.core.policy import MemPolicy

__all__ = [
    "TRAIN_MODELS",
    "TrainHints",
    "TrainMemPlan",
    "TrainModelSpec",
    "capacity_for",
    "device_demand_bytes",
    "get_train_model",
    "state_bytes",
]

F32 = np.dtype(np.float32)


# ----------------------------------------------------------------- model spec
@dataclass(frozen=True)
class TrainModelSpec:
    """A residual-MLP training workload: ``n_layers`` blocks of
    ``h <- h + tanh(h @ W1) @ W2`` over a ``rows x d_model`` residual
    stream, trained with AdamW against a random regression target. The
    math is real (numpy fp32, fixed op order — losses cannot depend on the
    memory backend), the memory system is modeled."""
    name: str
    d_model: int
    d_ff: int
    n_layers: int
    rows: int  # residual-stream rows per step (kept small: state, not
    #           batch, is what oversubscribes)

    @property
    def layer_params(self) -> int:
        return 2 * self.d_model * self.d_ff  # W1 (d,f) + W2 (f,d)

    @property
    def n_params(self) -> int:
        return self.n_layers * self.layer_params

    @property
    def act_elems(self) -> int:
        # per-layer stash: z (rows, d_ff) + the layer's input h (rows, d_model)
        return self.rows * (self.d_ff + self.d_model)


TRAIN_MODELS: Dict[str, TrainModelSpec] = {
    # tier-1 test scale: whole state ~260 KB, runs in milliseconds
    "train_tiny": TrainModelSpec("train_tiny", d_model=32, d_ff=64,
                                 n_layers=3, rows=4),
    # CI smoke scale: ~25M params, ~500 MB of training state
    "train_25m": TrainModelSpec("train_25m", d_model=512, d_ff=2048,
                                n_layers=12, rows=8),
    # the paper-scale config: ~104M params -> ~2.1 GB of fp32 training state
    # (params + grads + m + v + master), the fig11-curve workload
    "train_100m": TrainModelSpec("train_100m", d_model=768, d_ff=3072,
                                 n_layers=22, rows=16),
}


def get_train_model(name: str) -> TrainModelSpec:
    try:
        return TRAIN_MODELS[name]
    except KeyError:
        raise KeyError(f"unknown training model {name!r}; available: "
                       f"{', '.join(sorted(TRAIN_MODELS))}") from None


def state_bytes(spec: TrainModelSpec) -> int:
    """Total training state: the five per-layer tensor groups, the
    activation stash, the residual stream, the io batch and the backward
    scratch — everything the plan allocates. A non-migratable single pool
    (mi300a_unified) must hold all of it."""
    trees = 5 * spec.n_params  # params, grads, m, v, master
    acts = spec.n_layers * spec.act_elems
    io = 3 * spec.rows * spec.d_model  # residual stream h, x, y
    scratch = spec.rows * (spec.d_model + spec.d_ff)  # dh / da
    return F32.itemsize * (trees + acts + io + scratch) + F32.itemsize  # +loss


def device_demand_bytes(spec: TrainModelSpec) -> int:
    """What the GPU actually touches every step — params, grad
    accumulators, the activation stash, the residual stream, io and
    scratch. This is the ``1.0x`` point of the oversubscription axis: the
    optimizer tree (m/v/master) is CPU-updated and host-resident by first
    touch, so it pressures the *pool*, not the device, and sizing the
    device against it would never make the forward/backward working set
    spill."""
    gpu = 2 * spec.n_params + spec.n_layers * spec.act_elems
    res = (3 * spec.rows * spec.d_model
           + spec.rows * (spec.d_model + spec.d_ff))
    return F32.itemsize * (gpu + res) + F32.itemsize  # +loss


def _staged_min_bytes(spec: TrainModelSpec) -> int:
    """Device-resident floor of the staged (explicit) port: one layer's
    param/grad/act slabs + the residual stream, io and scratch blobs."""
    slabs = 2 * spec.layer_params + spec.act_elems
    resident = (spec.rows * spec.d_model  # residual stream h
                + 2 * spec.rows * spec.d_model  # x, y
                + spec.rows * (spec.d_model + spec.d_ff))  # scratch
    return F32.itemsize * (slabs + resident) + F32.itemsize  # +loss


def capacity_for(spec: TrainModelSpec, policy: MemPolicy,
                 ratio: float) -> int:
    """Device capacity that puts ``spec``'s GPU working set
    (:func:`device_demand_bytes`) at ``ratio``-fold oversubscription,
    floored at what the backend can physically run with:

    * migratable paged backends shrink all the way (pressure spills host);
    * the staged explicit port needs its fixed slab set on device;
    * a non-migratable single pool (mi300a_unified) cannot hold less than
      the *whole* state tree — the floor keeps the run feasible and the
      recorded ``eff_ratio`` reports the capacity actually modeled."""
    cap = int(math.ceil(device_demand_bytes(spec) / float(ratio)))
    if not policy.paged:
        return max(cap, _staged_min_bytes(spec))
    if not policy.migratable:
        return max(cap, state_bytes(spec))
    return cap


# ------------------------------------------------------------ placement hints
@dataclass(frozen=True)
class TrainHints:
    """Per-group placement hints — the paper's practical optimization
    strategies, applied to the training state tree. Every hint is
    capability-gated on ``policy.migratable`` (a single physical pool has
    nowhere to move a page) and ignored by the staged port (whose
    placement is the explicit slab schedule itself)."""
    prefetch_params: bool = True  # prefetch_async layer l+1's params ahead
    #                               of layer l's forward launches
    demote_opt: bool = True  # demote m/v/master right after the update —
    #                          cold until the next step's opt phase
    demote_acts: bool = False  # demote a layer's stash once bwd consumed it


# ------------------------------------------------------------------- the plan
class TrainMemPlan:
    """Maps the training state tree of ``spec`` onto UMBuffers under
    ``policy`` and owns placement: views for every launch operand, the
    hint-driven prefetch/demote calls, the staged port's slab copies, and
    the node round-robin for node-aware backends."""

    def __init__(self, um: UnifiedMemory, spec: TrainModelSpec,
                 policy: MemPolicy, *,
                 hints: Optional[TrainHints] = None):
        self.um = um
        self.spec = spec
        self.policy = policy
        self.hints = hints or TrainHints()
        self.staged = not policy.paged
        self.nodes = int(getattr(um.hw, "nodes", 1)) if policy.node_aware else 1
        self.peak_bytes = state_bytes(spec)
        self.demand_bytes = device_demand_bytes(spec)
        self._bufs: List = []  # free() in allocation order

        d, f, R, L = spec.d_model, spec.d_ff, spec.rows, spec.n_layers
        isz = F32.itemsize
        self._w1_bytes = d * f * isz
        self._z_bytes = R * f * isz
        self._layer_bytes = spec.layer_params * isz
        self._act_bytes = spec.act_elems * isz

        def mk(name, elems, pol):
            buf = um.array(name, (int(elems),), F32, pol)
            self._bufs.append(buf)
            return buf

        if self.staged:
            # ZeRO-offload-style port: full state host-side (the malloc
            # half of the pair, a plain non-auto-migrating system table at
            # the app's system page size), fixed per-layer slabs device-side
            host = system_policy(page_size=um.staging_page_size,
                                 auto_migrate=False)
            self.host_policy = host
            self._slab_w = mk("slab_w", spec.layer_params, policy)
            self._slab_g = mk("slab_g", spec.layer_params, policy)
            self._slab_a = mk("slab_a", spec.act_elems, policy)
            self._params = [mk(f"p{l}", spec.layer_params, host)
                            for l in range(L)]
            self._grads = [mk(f"g{l}", spec.layer_params, host)
                           for l in range(L)]
            self._m = [mk(f"m{l}", spec.layer_params, host) for l in range(L)]
            self._v = [mk(f"v{l}", spec.layer_params, host) for l in range(L)]
            self._master = [mk(f"w{l}", spec.layer_params, host)
                            for l in range(L)]
            self._acts = [mk(f"a{l}", spec.act_elems, host) for l in range(L)]
        else:
            self.host_policy = policy
            self._params = [mk(f"p{l}", spec.layer_params, policy)
                            for l in range(L)]
            self._grads = [mk(f"g{l}", spec.layer_params, policy)
                           for l in range(L)]
            self._m = [mk(f"m{l}", spec.layer_params, policy)
                       for l in range(L)]
            self._v = [mk(f"v{l}", spec.layer_params, policy)
                       for l in range(L)]
            self._master = [mk(f"w{l}", spec.layer_params, policy)
                            for l in range(L)]
            self._acts = [mk(f"a{l}", spec.act_elems, policy)
                          for l in range(L)]
        # io + scratch + loss live under the training policy in both modes
        # (the staged port keeps them device-resident; they are part of the
        # explicit floor in _staged_min_bytes). x/y originate host-side
        # every step, so they go through from_host: under the explicit
        # policy that materializes the cudaMalloc+malloc staging pair and
        # um.staged() charges the upload; resident backends first-touch.
        self._h = mk("hres", R * d, policy)  # residual stream
        self._x = um.from_host("xin", (R * d,), F32, policy)
        self._bufs.append(self._x)
        self._y = um.from_host("ytgt", (R * d,), F32, policy)
        self._bufs.append(self._y)
        self._scratch = mk("scratch", R * (d + f), policy)
        self._loss = mk("lossv", 1, policy)

    # ------------------------------------------------------------- geometry
    def node_of(self, layer: int) -> Optional[int]:
        """Issuing superchip for layer ``layer`` (round-robin), or None on
        single-node / non-node-aware backends."""
        if self.nodes <= 1:
            return None
        return layer % self.nodes

    def on_layer_node(self, layer: int):
        """Context manager pinning the ambient node to ``node_of(layer)``
        (a no-op nullcontext off the cluster backends)."""
        nd = self.node_of(layer)
        if nd is None:
            return contextlib.nullcontext(self.um)
        return self.um.on_node(nd)

    # ------------------------------------------------- launch-operand views
    # compute views: what GPU launches read/write. In staged mode these
    # resolve to the device slabs; host-side state is reached through the
    # *_state views below.
    def _wbuf(self, l):
        return self._slab_w if self.staged else self._params[l]

    def _gbuf(self, l):
        return self._slab_g if self.staged else self._grads[l]

    def _abuf(self, l):
        return self._slab_a if self.staged else self._acts[l]

    def w1(self, l):
        return self._wbuf(l).byterange(0, self._w1_bytes)

    def w2(self, l):
        return self._wbuf(l).byterange(self._w1_bytes, self._layer_bytes)

    def params(self, l):
        return self._wbuf(l)[...]

    def grads(self, l):
        return self._gbuf(l)[...]

    def z(self, l):
        return self._abuf(l).byterange(0, self._z_bytes)

    def h_in(self, l):
        return self._abuf(l).byterange(self._z_bytes, self._act_bytes)

    def acts(self, l):
        return self._abuf(l)[...]

    # optimizer-state views: always the authoritative (host-side in staged
    # mode) buffers — the CPU-actor update touches these directly
    def m_state(self, l):
        return self._m[l][...]

    def v_state(self, l):
        return self._v[l][...]

    def master_state(self, l):
        return self._master[l][...]

    def grads_state(self, l):
        return self._grads[l][...] if self.staged else self.grads(l)

    def params_state(self, l):
        return self._params[l][...] if self.staged else self.params(l)

    def x(self):
        return self._x[...]

    def y(self):
        return self._y[...]

    def h_res(self):
        return self._h[...]

    def scratch(self):
        return self._scratch[...]

    def loss_out(self):
        return self._loss[...]

    # ------------------------------------------------------- phase placement
    # The trainer calls these at the phase boundaries; each one is a no-op
    # wherever the backend has no corresponding action, so the step loop in
    # umtrain.py is one code path for every registered policy.
    def _migratory(self) -> bool:
        return not self.staged and self.policy.migratable

    def pre_fwd(self, l: int) -> None:
        """Ahead of layer ``l``'s forward launches: staged mode uploads the
        layer's params slab; resident migratable backends prefetch the
        *next* layer's params so the migration hides under this layer's
        compute (the async-prefetch overlap model)."""
        if self.staged:
            self.um.copy(self._slab_w.alloc, 0, self._layer_bytes, "h2d")
            return
        if self.hints.prefetch_params and self._migratory() \
                and l + 1 < self.spec.n_layers:
            with self.on_layer_node(l + 1):
                self.um.prefetch_async([self.params(l + 1)])

    def post_fwd(self, l: int) -> None:
        """After layer ``l``'s forward: staged mode writes the activation
        stash back to its host buffer (device slab is reused next layer)."""
        if self.staged:
            self.um.copy(self._slab_a.alloc, 0, self._act_bytes, "d2h")

    def pre_bwd(self, l: int) -> None:
        """Ahead of layer ``l``'s backward: staged mode re-uploads the
        layer's params and its stashed activations."""
        if self.staged:
            self.um.copy(self._slab_w.alloc, 0, self._layer_bytes, "h2d")
            self.um.copy(self._slab_a.alloc, 0, self._act_bytes, "h2d")

    def post_bwd(self, l: int) -> None:
        """After layer ``l``'s backward: staged mode drains the grad slab to
        its host accumulator; resident backends optionally demote the
        consumed stash (it is cold until the next step's forward)."""
        if self.staged:
            self.um.copy(self._slab_g.alloc, 0, self._layer_bytes, "d2h")
            return
        if self.hints.demote_acts and self._migratory():
            with self.on_layer_node(l):
                self.um.demote(self.acts(l))

    def post_opt(self, l: int) -> None:
        """After layer ``l``'s optimizer update: demote the cold moments
        and master weights — nothing reads them again until the next step's
        opt phase (the paper's 'keep cold state out of HBM' strategy)."""
        if self.hints.demote_opt and self._migratory():
            with self.on_layer_node(l):
                self.um.demote(self.m_state(l))
                self.um.demote(self.v_state(l))
                self.um.demote(self.master_state(l))

    # ---------------------------------------------------------- checkpointing
    def checkpoint_ranges(self):
        """The durable state a checkpoint snapshots: params + optimizer
        tree. These are what CheckpointManager.save drains — dirty
        device-resident runs charge a d2h writeback; host-resident (and
        staged-port host) state drains nothing."""
        out = []
        for l in range(self.spec.n_layers):
            out.append(self.params_state(l))
            out.append(self.m_state(l))
            out.append(self.v_state(l))
            out.append(self.master_state(l))
        return out

    # -------------------------------------------------------------- lifecycle
    def close(self) -> None:
        """Free every plan allocation (staging pairs included), returning
        the runtime's residency to its pre-plan baseline."""
        for buf in self._bufs:
            if not buf.freed:
                buf.free()
        self._bufs.clear()

    def init_launches(self):
        """The cpu_init first-touch launches that place the state tree:
        CPU writes params/master/m/v (host-side first touch under paged
        backends, host buffers of the staged port)."""
        from repro.core.umem import KernelBatch

        kb = KernelBatch()
        for l in range(self.spec.n_layers):
            nd = self.node_of(l)
            kb.launch("init_state", writes=[
                self.params_state(l), self.master_state(l),
                self.m_state(l), self.v_state(l)],
                actor=Actor.CPU, node=nd)
        return kb
