"""Training loop: microbatched pjit train step + fault-tolerant driver.

make_train_step builds the jitted step:
  * gradient accumulation via lax.scan over microbatches (memory-bounded),
  * fp32 grad accumulators constrained to the ZeRO opt-state sharding
    (the per-microbatch psum lowers to reduce-scatter — ZeRO-2-style),
  * exactness hooks for the padded TP head layout (grad mask + KV-replica
    grad sync, models/transformer.py),
  * optional int8+error-feedback compression of the cross-pod gradient sync,
  * AdamW with fp32 master weights (ZeRO-1-sharded).

Trainer drives the loop: checkpoint cadence, failure recovery (restore +
deterministic data replay), straggler monitoring.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.models import grad_mask, loss_fn, sync_replica_grads
from repro.models.layers import RunPolicy
from repro.optim import adamw_init, adamw_update, ef_int8_roundtrip
from repro.optim.schedule import warmup_cosine
from repro.runtime import FailureInjector, SimulatedFailure, StragglerMonitor


@dataclass
class TrainerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_accum: int = 1
    ckpt_every: int = 50
    compress_grads: bool = False  # int8 + error feedback on the accumulated grads
    tp: int = 1


def make_train_state(cfg: ArchConfig, params) -> Dict[str, Any]:
    return {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ArchConfig, policy: RunPolicy, tc: TrainerConfig,
                    grad_spec_constrain: Optional[Callable] = None):
    """Returns step(state, batch, [err]) -> (state, metrics[, err]).

    grad_spec_constrain(tree) applies with_sharding_constraint with the
    ZeRO spec to the grad accumulators (None = no constraint, single host).
    """
    lr_fn = warmup_cosine(tc.lr, tc.warmup_steps, tc.total_steps)
    constrain = grad_spec_constrain or (lambda t: t)
    mask = None  # built lazily against the param tree

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, policy), has_aux=True)(params)
        return loss, metrics, grads

    def step(state, batch, err=None):
        params = state["params"]
        B = batch["labels"].shape[0]
        accum = tc.grad_accum
        assert B % accum == 0, (B, accum)

        if accum == 1:
            loss, metrics, grads = grads_of(params, batch)
            grads = constrain(jax.tree.map(lambda g: g.astype(jnp.float32), grads))
        else:
            def micro(carry, mb):
                gacc, lacc = carry
                l, m, g = grads_of(params, mb)
                gacc = constrain(jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), gacc, g))
                return (gacc, lacc + l), None

            mb_tree = jax.tree.map(
                lambda x: x.reshape((accum, B // accum) + x.shape[1:]), batch)
            gacc0 = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss_sum), _ = jax.lax.scan(micro, (gacc0, 0.0), mb_tree)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = {}

        # exact padded-TP hooks
        grads = sync_replica_grads(cfg, grads, tc.tp)
        m = grad_mask(cfg, params, tc.tp)
        grads = jax.tree.map(lambda g, mm: g * mm.astype(g.dtype), grads, m)

        new_err = err
        if tc.compress_grads and err is not None:
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = tdef.flatten_up_to(err)
            outs = [ef_int8_roundtrip(g, e) for g, e in zip(flat_g, flat_e)]
            grads = tdef.unflatten([o[0] for o in outs])
            new_err = tdef.unflatten([o[1] for o in outs])

        lr = lr_fn(state["step"])
        params, opt, gnorm = adamw_update(
            grads, state["opt"], params, lr=lr,
            weight_decay=tc.weight_decay, clip_norm=tc.clip_norm)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        if tc.compress_grads and err is not None:
            return new_state, out_metrics, new_err
        return new_state, out_metrics

    return step


class Trainer:
    """Fault-tolerant training driver (single-controller)."""

    def __init__(self, cfg: ArchConfig, state, step_fn, loader, *,
                 ckpt: Optional[CheckpointManager] = None,
                 injector: Optional[FailureInjector] = None,
                 monitor: Optional[StragglerMonitor] = None,
                 ckpt_every: int = 50,
                 clock: Optional[Callable[[], float]] = None):
        """``clock`` is the time source for per-step durations (history
        ``dt`` and the straggler monitor). Default is wall clock; a run
        whose memory system goes through UnifiedMemory should pass the
        modeled clock — ``clock=lambda: um.clock`` — so training metrics
        are directly comparable to the serve stack's ``ServeEngine.now()``
        timings instead of mixing modeled and wall seconds."""
        self.cfg = cfg
        self.state = state
        self.step_fn = step_fn
        self.loader = loader
        self.ckpt = ckpt
        self.injector = injector
        self.monitor = monitor or StragglerMonitor()
        self.ckpt_every = ckpt_every
        self.clock = clock or time.perf_counter
        self.history: list = []
        self.restarts = 0

    def run(self, num_steps: int) -> Dict[str, Any]:
        done = 0
        while done < num_steps:
            try:
                step_idx, batch = next(self.loader)
                t0 = self.clock()
                if self.injector is not None:
                    self.injector.maybe_fail(step_idx)
                self.state, metrics = self.step_fn(self.state, batch)
                loss = float(metrics["loss"])
                dt = self.clock() - t0
                self.monitor.record("worker0", dt)
                self.history.append({"step": step_idx, "loss": loss, "dt": dt})
                done += 1
                if self.ckpt is not None and (step_idx + 1) % self.ckpt_every == 0:
                    self.ckpt.save(step_idx + 1, self.state)
            except SimulatedFailure:
                # restore-and-replay: deterministic pipeline guarantees the
                # same batches stream again from the restored step
                self.restarts += 1
                assert self.ckpt is not None, "failure without checkpointing"
                self.ckpt.wait()
                step, self.state = self.ckpt.restore(self.state)
                self.loader.seek(step)
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"history": self.history, "restarts": self.restarts,
                "stragglers": self.monitor.stragglers()}
