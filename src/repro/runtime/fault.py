"""Failure injection + recovery policy, for training AND serving.

At 1000+ nodes, MTBF of the *job* is hours; every layer must treat
failure as a normal event. Two consumers share the machinery here:

* **Trainer** — :class:`FailureInjector` raises :class:`SimulatedFailure`
  at scheduled steps; the trainer catches, restores the last committed
  checkpoint, replays the data stream (deterministic pipeline) and
  continues. tests/test_fault_tolerance.py asserts bitwise-identical
  losses vs an uninterrupted run.
* **Serve/cluster stack** — :class:`FaultPlan` is an immutable, sorted
  schedule of :class:`FaultEvent`\\ s (node loss, transient lane
  degradation, host-spill failure) keyed by engine step.
  ``ServeEngine(fault_plan=...)`` drains the due events each step and
  delivers them to the UnifiedMemory runtime / cluster policy through
  the lifecycle-hook seam (``um.fail_node``, ``um.set_lane_degradation``,
  ``um.set_spill_failure``); tests/test_fault_serve.py asserts recovered
  token streams are bit-identical to a fault-free run.

Both schedules are seeded-deterministic: the fixed-step mode pins exact
steps, the Poisson (MTBF) mode samples exponential inter-failure gaps
from ``np.random.default_rng(seed)`` — same seed, same schedule.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, List, Sequence, Set, Tuple

import numpy as np


class SimulatedFailure(RuntimeError):
    """Stands in for a node loss / ICI timeout / preemption."""


def poisson_steps(rate: float, seed: int, horizon: int = 10_000) -> List[int]:
    """Integer failure steps of a seeded Poisson process: exponential
    inter-arrival gaps with mean ``1/rate`` steps (MTBF), cumulative-summed,
    floored and deduplicated — deterministic per seed. Shared by
    :meth:`FailureInjector.poisson` and :meth:`FaultPlan.poisson` so the
    trainer and the serve fault plan draw from the same schedule family."""
    assert rate > 0, "MTBF mode needs a positive failure rate"
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    while True:
        t += float(rng.exponential(1.0 / rate))
        s = int(t)
        if s >= horizon:
            return sorted(set(out))
        if s >= 1:
            out.append(s)


@dataclass
class FailureInjector:
    fail_at_steps: Set[int] = field(default_factory=set)
    fired: Set[int] = field(default_factory=set)

    @classmethod
    def at(cls, steps: Iterable[int]) -> "FailureInjector":
        return cls(fail_at_steps=set(steps))

    @classmethod
    def poisson(cls, rate: float, seed: int, *,
                horizon: int = 10_000) -> "FailureInjector":
        """Seeded MTBF mode: failures at the steps of a Poisson process
        with ``rate`` failures per step (MTBF = 1/rate), deterministic per
        seed — the same injector twice replays the same schedule."""
        return cls(fail_at_steps=set(poisson_steps(rate, seed, horizon)))

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


# --------------------------------------------------------------- fault plan
@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault, keyed by the consumer's step counter.

    kind='node_loss'    -> superchip ``node`` drops out: its resident pages
                           are poisoned (``um.fail_node``) and the serve
                           engine replays the affected sequences.
    kind='lane_degrade' -> for ``duration`` steps the inter-node links run
                           at ``nvlink_factor`` / ``fabric_factor`` of
                           nominal bandwidth (<1 = slower); the cluster
                           charge model measures the degraded-mode time.
    kind='spill_fail'   -> for ``duration`` steps host-spill (demote)
                           raises; preemption falls back to dropping the
                           KV and recomputing from the prompt.
    """
    step: int
    kind: str
    node: int = 0
    duration: int = 1
    nvlink_factor: float = 1.0
    fabric_factor: float = 1.0


@dataclass(frozen=True)
class FaultPlan:
    """An immutable, sorted schedule of :class:`FaultEvent`\\ s.

    The plan itself is shareable — consumers (one per engine) keep their
    own cursor into ``events``, so a single plan can drive every engine of
    a traffic simulation deterministically."""

    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events,
                         key=lambda e: (e.step, e.kind, e.node))))

    def __bool__(self) -> bool:
        return bool(self.events)

    def __add__(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(events=self.events + tuple(other.events))

    # ------------------------------------------------------------- builders
    @classmethod
    def node_loss(cls, losses: Sequence[Tuple[int, int]]) -> "FaultPlan":
        """Fixed-step node losses: ``[(step, node), ...]``."""
        return cls(events=tuple(FaultEvent(step=int(s), kind="node_loss",
                                           node=int(n)) for s, n in losses))

    @classmethod
    def lane_degrade(cls, step: int, duration: int, *,
                     nvlink_factor: float = 1.0,
                     fabric_factor: float = 1.0) -> "FaultPlan":
        """A transient lane-degradation window starting at ``step``."""
        return cls(events=(FaultEvent(step=int(step), kind="lane_degrade",
                                      duration=int(duration),
                                      nvlink_factor=float(nvlink_factor),
                                      fabric_factor=float(fabric_factor)),))

    @classmethod
    def spill_failure(cls, step: int, duration: int) -> "FaultPlan":
        """A window during which host-spill (demote) fails."""
        return cls(events=(FaultEvent(step=int(step), kind="spill_fail",
                                      duration=int(duration)),))

    @classmethod
    def poisson(cls, rate: float, seed: int, *, num_nodes: int,
                horizon: int = 10_000) -> "FaultPlan":
        """Seeded-MTBF node losses: steps from :func:`poisson_steps`, the
        dying node drawn (without replacement) from the same seed. Capped
        at ``num_nodes - 1`` losses — a cluster cannot lose its last
        serving node and still drain."""
        steps = poisson_steps(rate, seed, horizon)[:max(0, num_nodes - 1)]
        rng = np.random.default_rng([seed, 1])
        alive = list(range(num_nodes))
        events = []
        for s in steps:
            node = alive.pop(int(rng.integers(len(alive))))
            events.append(FaultEvent(step=s, kind="node_loss", node=node))
        return cls(events=tuple(events))
