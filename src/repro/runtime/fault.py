"""Failure injection + recovery policy for the training loop.

At 1000+ nodes, MTBF of the *job* is hours; the trainer must treat step
failure as a normal event: catch, restore from the last committed
checkpoint, replay the data stream (deterministic pipeline), continue.
tests/test_fault_tolerance.py asserts bitwise-identical losses vs an
uninterrupted run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Set


class SimulatedFailure(RuntimeError):
    """Stands in for a node loss / ICI timeout / preemption."""


@dataclass
class FailureInjector:
    fail_at_steps: Set[int] = field(default_factory=set)
    fired: Set[int] = field(default_factory=set)

    @classmethod
    def at(cls, steps: Iterable[int]) -> "FailureInjector":
        return cls(fail_at_steps=set(steps))

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")
