from repro.runtime.fault import FailureInjector, SimulatedFailure  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.elastic import reshard_tree  # noqa: F401
