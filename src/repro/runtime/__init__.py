from repro.runtime.fault import (  # noqa: F401
    FailureInjector,
    FaultEvent,
    FaultPlan,
    SimulatedFailure,
    poisson_steps,
)
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.elastic import reshard_tree, resize_um_capacity  # noqa: F401
