"""Elastic rescaling: move a logical state tree onto a different mesh.

Checkpoints are logically addressed (checkpoint/manager.py), so elastic
scale-up/down = restore + device_put with the new mesh's shardings. For
live rescale (no checkpoint round-trip) reshard_tree gathers to host and
re-places — acceptable at rescale frequency (rare).
"""
from __future__ import annotations

import jax
import numpy as np


def reshard_tree(tree, shardings):
    """tree of jax/np arrays -> device arrays placed per `shardings` tree."""
    host = jax.tree.map(lambda a: np.asarray(a), tree)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), host, shardings)
