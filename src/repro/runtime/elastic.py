"""Elastic rescaling: move a logical state tree onto a different mesh.

Checkpoints are logically addressed (checkpoint/manager.py), so elastic
scale-up/down = restore + device_put with the new mesh's shardings. For
live rescale (no checkpoint round-trip) reshard_tree gathers to host and
re-places — acceptable at rescale frequency (rare).
"""
from __future__ import annotations

import jax
import numpy as np


def reshard_tree(tree, shardings):
    """tree of jax/np arrays -> device arrays placed per `shardings` tree."""
    host = jax.tree.map(lambda a: np.asarray(a), tree)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), host, shardings)


def resize_um_capacity(um, nbytes: int):
    """Elastic resize as a UnifiedMemory pressure event: swap in a hardware
    model with the new device capacity (``with_device_capacity`` keeps a
    multi-node model's per-node split consistent — never
    dataclasses.replace here). Shrinking moves no pages eagerly: the next
    first-touch / migration simply sees the reduced headroom and the
    allocation's policy evicts or spills exactly as it would under any
    other pressure, so the application's math (and a training run's
    losses) are untouched. Returns the new hardware model."""
    um.hw = um.hw.with_device_capacity(int(nbytes))
    um._sample()
    return um.hw
