"""Straggler detection and mitigation.

Per-step wall times feed a rolling window; a step (or worker) is a straggler
when its time exceeds median + k*MAD. Mitigations (policy hooks):
  'flag'     -> report only
  'deadline' -> return a step deadline = median * slack for bounded-latency
                collectives (the caller skips/retries past it)
  'rebalance'-> suggest shrinking the microbatch count of the slow worker
At real scale the signals come per-host from the coordinator; here workers
are simulated (tests/test_straggler.py).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np


@dataclass
class StragglerMonitor:
    window: int = 32
    k_mad: float = 5.0
    deadline_slack: float = 2.0
    times: Dict[str, Deque[float]] = field(default_factory=dict)

    def record(self, worker: str, seconds: float) -> None:
        self.times.setdefault(worker, deque(maxlen=self.window)).append(seconds)

    def _stats(self) -> Tuple[float, float]:
        allt = np.concatenate([np.asarray(d) for d in self.times.values()]) \
            if self.times else np.array([0.0])
        med = float(np.median(allt))
        mad = float(np.median(np.abs(allt - med))) + 1e-12
        return med, mad

    def stragglers(self) -> List[str]:
        med, mad = self._stats()
        out = []
        for w, d in self.times.items():
            recent = float(np.median(np.asarray(d)[-4:]))
            if recent > med + self.k_mad * mad:
                out.append(w)
        return out

    def deadline(self) -> float:
        med, _ = self._stats()
        return med * self.deadline_slack

    def rebalance_hint(self) -> Dict[str, float]:
        """worker -> suggested relative microbatch share (1.0 = unchanged)."""
        med, _ = self._stats()
        hints = {}
        for w, d in self.times.items():
            recent = float(np.median(np.asarray(d)[-4:]))
            if recent > 0:
                hints[w] = float(np.clip(med / recent, 0.25, 1.0))
        return hints
