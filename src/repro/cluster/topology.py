"""Cluster hardware: N GH200 superchips presented as one logical pool.

A :class:`ClusterHardwareModel` is a plain :class:`HardwareModel` (so every
single-node code path — device_bw, link_h2d/d2h, PTE costs — keeps working
untouched) plus the multi-superchip dimension: the node count, the per-node
device capacity, and a :class:`ClusterTopology` describing the two
inter-node lanes. Intra-node CPU<->GPU stays the NVLink-C2C link the base
model already prices; between nodes, device<->device traffic rides NVLink
(NVSwitch-class) and host-side traffic rides the slower node fabric.

Bandwidth/latency defaults follow the quad-GH200 measurements in Khalilov
et al. (arXiv:2408.11556): ~100 GB/s effective per-pair NVLink between
Hopper GPUs, ~25 GB/s host-routed fabric, with microsecond-scale one-way
latencies. They are deliberately round numbers — the cluster model is a
first-order cost model, like the rest of the simulator.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.core.hardware import GRACE_HOPPER, HardwareModel
from repro.core.registry import register_hardware


@dataclass(frozen=True)
class ClusterTopology:
    """Inter-node link constants (intra-node C2C lives on HardwareModel)."""

    nvlink_bw: float = 100e9  # device<->device between nodes (bytes/s)
    fabric_bw: float = 25e9  # host<->host / host-routed between nodes
    nvlink_latency: float = 2.0e-6  # per contiguous transfer (run)
    fabric_latency: float = 5.0e-6


@dataclass(frozen=True)
class ClusterHardwareModel(HardwareModel):
    """N superchips as one pool. ``device_capacity`` is the cluster-wide
    total (``nodes * node_device_capacity``), so capacity-aware single-node
    code sees the logical pool; node-aware policies budget per node via
    ``node_device_capacity``."""

    nodes: int = 1
    node_device_capacity: int = 0
    topology: ClusterTopology = ClusterTopology()

    def with_device_capacity(self, nbytes: int) -> "ClusterHardwareModel":
        # keep the per-node split consistent: shrinking the pool (the
        # oversubscription harness does this) shrinks every node equally
        per = -(-int(nbytes) // self.nodes)
        return dataclasses.replace(self, device_capacity=per * self.nodes,
                                   node_device_capacity=per)


def gh200_cluster(nodes: int, *,
                  node_device_capacity: Optional[int] = None,
                  topology: Optional[ClusterTopology] = None,
                  base: HardwareModel = GRACE_HOPPER,
                  name: Optional[str] = None) -> ClusterHardwareModel:
    """An N-superchip cluster derived from a single-superchip base model."""
    assert nodes >= 1, nodes
    cap = (base.device_capacity if node_device_capacity is None
           else int(node_device_capacity))
    cfg = {f.name: getattr(base, f.name)
           for f in dataclasses.fields(HardwareModel)}
    cfg["name"] = name or f"gh200_x{nodes}"
    cfg["device_capacity"] = nodes * cap
    return ClusterHardwareModel(nodes=nodes, node_device_capacity=cap,
                                topology=topology or ClusterTopology(),
                                **cfg)


GH200_X2 = gh200_cluster(2)
GH200_X4 = gh200_cluster(4)

register_hardware(GH200_X2.name, GH200_X2)
register_hardware(GH200_X4.name, GH200_X4)
