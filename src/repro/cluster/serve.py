"""Tensor-parallel serving across superchips: the cluster serve plan.

A :class:`ClusterTPPlan` plugs into ``ServeEngine(tp_plan=...)`` and does
two things:

* **Sequence placement** — ``node_of_seq`` maps every KV-pool sequence
  slot to a serving superchip (round-robin over slots). The engine pins
  each sequence's tracked launches, demotes and resume-prefetches to that
  node, so a node-aware pool policy first-touches the sequence's KV pages
  on its serving node and spills/promotes as seen from it.
* **Collective traffic** — after every prefill chunk and decode batch it
  charges the per-token tensor-parallel all-reduce bytes over the
  inter-node NVLink lane (``um.charge_transfer``). Per transformer layer a
  TP-N forward pass all-reduces twice (attention out-proj + MLP down-proj),
  and a ring all-reduce moves ``2*(N-1)/N`` of the activation through
  every rank's links — the standard collective cost model.

The plan only ADDS modeled time and side-counter bytes: it never touches
the model math or the scheduler's decisions, so the generated tokens of a
TP-N run are bit-identical to the single-node engine driving the same
schedule. (Engine decisions read the pool and ``um.device_free()``, both
policy-governed — the acceptance test in tests/test_cluster.py pins token
identity against the single-node run.)

Fault tolerance: ``without_node`` produces the post-loss plan — the dead
rank leaves ``ranks()``, sequence placement re-pins round-robin over the
survivors, and the all-reduce ring shrinks to the surviving rank count.
The engine swaps plans when a fault-plan ``node_loss`` event fires.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

ACT_BYTES = 4  # fp32 activations, matching the app/serve compute dtype


@dataclass(frozen=True)
class ClusterTPPlan:
    """Tensor parallelism over ``nodes`` superchips, one TP rank per node.

    ``alive`` (None = everyone) lists the surviving ranks after node
    losses; placement and the collective cost model run over survivors.
    """

    nodes: int
    alive: Optional[Tuple[int, ...]] = None

    def ranks(self) -> Tuple[int, ...]:
        return self.alive if self.alive is not None \
            else tuple(range(self.nodes))

    def node_of_seq(self, sid: int) -> int:
        r = self.ranks()
        return int(r[int(sid) % len(r)])

    def without_node(self, node: int) -> "ClusterTPPlan":
        """The plan after ``node`` drops out of the serving group."""
        survivors = tuple(k for k in self.ranks() if k != int(node))
        assert survivors, "cannot lose the last serving node"
        return dataclasses.replace(self, alive=survivors)

    def allreduce_bytes_per_token(self, cfg) -> int:
        """Ring all-reduce bytes one token moves per rank: two all-reduces
        of the d_model activation per layer, 2*(N-1)/N of it on the wire."""
        n = len(self.ranks())
        if n <= 1:
            return 0
        ring = 2 * (n - 1) / n
        return int(2 * cfg.num_layers * ring * cfg.d_model * ACT_BYTES)

    # ------------------------------------------------------- engine hooks
    def on_prefill(self, engine, ntokens: int) -> None:
        self._charge(engine, ntokens)

    def on_decode(self, engine, ntokens: int) -> None:
        self._charge(engine, ntokens)

    def _charge(self, engine, ntokens: int) -> None:
        um = engine.um
        topo = getattr(um.hw, "topology", None) if um is not None else None
        if topo is None or len(self.ranks()) <= 1 or ntokens <= 0:
            return
        nbytes = ntokens * self.allreduce_bytes_per_token(engine.cfg)
        bw = topo.nvlink_bw
        deg = um.lane_degradation
        if deg is not None:  # all-reduce rides the degraded NVLink lane
            bw = bw * deg[0]
            um.prof.extra["degraded_nvlink_bytes"] += int(nbytes)
        # one latency per all-reduce (2 per layer), paid once per step
        um.charge_transfer(nbytes, bw,
                           latency=2 * engine.cfg.num_layers
                           * topo.nvlink_latency,
                           counter="tp_allreduce_bytes")
