# Distributed unified pool: N superchips as one logical memory system.
# Importing this package registers the cluster hardware models (gh200_x2,
# gh200_x4) and the node-aware policies (cluster_system, cluster_striped)
# with repro.core.registry — core/registry.py imports it at the bottom so
# every registry consumer sees the cluster backends without extra imports.
from repro.cluster.topology import (  # noqa: F401
    GH200_X2,
    GH200_X4,
    ClusterHardwareModel,
    ClusterTopology,
    gh200_cluster,
)
from repro.cluster.policy import (  # noqa: F401
    ClusterPolicy,
    ClusterStripedPolicy,
    ClusterSystemPolicy,
    cluster_striped_policy,
    cluster_system_policy,
    device_free_on,
    device_used_on,
    node_capacity,
)
from repro.cluster.serve import ClusterTPPlan  # noqa: F401
