"""Cluster memory policies: node-aware placement over the distributed pool.

Two backends, both :class:`~repro.core.policy.MemPolicy` strategy objects
registered through the standard registry (so ``--policy cluster_system``
works everywhere a policy name is accepted):

cluster_system  -> locality-first: first touch maps onto the toucher's own
                   superchip (device if that node has room, else its host
                   memory), like the single-node system backend seen from
                   each node. At N=1 this is placement-identical to a
                   single superchip.
cluster_striped -> capacity-first: GPU first touch stripes pages round-robin
                   across every node's device memory at ``stripe_pages``
                   granularity, trading inter-node NVLink traffic for an
                   N-times larger effective device pool (the "one logical
                   GPU" view of the cluster).

Page locations are ``(node, tier)`` encodings (pagetable.node_tier_loc).
Access charges classify every resident run as seen from the issuing node:

* same node, same side          -> local bytes (device_bw / host_bw)
* same node, far side           -> the NVLink-C2C link, exactly like the
                                   single-node remote path (h2d/d2h + the
                                   remote_* counters)
* other node's device memory    -> the inter-node NVLink lane
* other node's host memory      -> the inter-node fabric lane

Inter-node traffic is accumulated as exact integer ``(bytes, runs)`` lanes
and converted to seconds once per launch/item (lanes_time), so the
sequential and batched engines stay bit-identical; the byte totals land in
``prof.extra["internode_nvlink_bytes"/"internode_fabric_bytes"]`` — the
open-ended side-counter table — never in TrafficCounters, whose field set
the single-node parity fixture pins.

Neither backend uses access counters or fault-driven migration: placement
moves only through the explicit prefetch/demote APIs. ``on_demote`` spills
a node's device pages to the *next* node's host memory (the serve engine
preempts through this, keeping spilled KV pages one NVLink hop away), and
``on_migrate_in`` promotes toward the accessing node, paying the fabric
for cross-node sources. Both degenerate to the built-in single-node paths
when the table has one node, preserving N=1 bit-identity.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.pagetable import Actor, BlockTable, Tier, node_tier_loc
from repro.core.policy import KB, Allocation, MemPolicy
from repro.core.registry import register_policy
from repro.core.runs import RunMap


def node_capacity(um) -> int:
    """Device bytes one superchip holds (single-node models: the device)."""
    return getattr(um.hw, "node_device_capacity", 0) or um.hw.device_capacity


def device_used_on(um, node: int) -> int:
    """Device bytes resident on ``node`` across live allocations (explicit
    device-resident blobs are pinned to node 0)."""
    k = 2 * node + 2  # counter index of (node, DEVICE)
    used = 0
    for a in um.allocs.values():
        if a.freed:
            continue
        if node == 0:
            used += a.device_bytes_explicit
        t = a.table
        if t is not None and k < t._nlocs:
            used += int(t._tier_bytes[k])
    return used


def device_free_on(um, node: int) -> int:
    if node in um._dead_nodes:  # lost capacity: nothing places here again
        return 0
    return node_capacity(um) - device_used_on(um, node)


def _lane_bw(um, topo):
    """Effective (nvlink_bw, fabric_bw) for the inter-node lanes. A
    fault-plan lane-degradation window multiplies the nominal numbers;
    the ``None`` fast path leaves the charge expressions untouched so
    fault-free runs stay bit-identical."""
    deg = um._lane_degrade
    if deg is None:
        return topo.nvlink_bw, topo.fabric_bw
    return topo.nvlink_bw * deg[0], topo.fabric_bw * deg[1]


@dataclass(frozen=True)
class ClusterPolicy(MemPolicy):
    """Shared node-aware machinery; subclasses pick the placement rule."""

    stripe_pages: int = 16  # striped backend: pages per round-robin stripe

    kind = "cluster"
    node_aware = True
    batched_charge = True  # on_access is the inherited no-op, so the
    # default fully-mapped-hull certification is exact
    migratable = True
    evictable = False

    # ------------------------------------------------------------ lifecycle
    def on_alloc(self, um, name: str, nbytes: int) -> Allocation:
        table = BlockTable(name, nbytes, self.page_size,
                           num_nodes=getattr(um.hw, "nodes", 1))
        a = Allocation(name, nbytes, self, table=table,
                       pending=RunMap(table.num_pages, 0, np.int8))
        um._charge(um.hw.alloc_per_page * min(table.num_pages, 64))
        return a

    def _charge_pte(self, um, actor: Actor, n_unmapped: int) -> None:
        tr = um.prof.traffic()
        if actor is Actor.GPU:
            um._charge(um.hw.pte_init_gpu * n_unmapped)
            tr.pte_inits_gpu += n_unmapped
        else:
            um._charge(um.hw.pte_init_cpu * n_unmapped)
            tr.pte_inits_cpu += n_unmapped

    # --------------------------------------------------------------- access
    def charge_access_runs(self, um, a, actor, is_write, ctx, rs, re_, rv,
                           rb, node):
        nlocs = a.table._nlocs
        lv = rv.astype(np.int64)
        bl = np.bincount(lv, weights=rb, minlength=nlocs).astype(np.int64)
        cl = np.bincount(lv, minlength=nlocs)
        tr = um.prof.traffic()
        dloc = 2 * node + 1
        hloc = 2 * node
        gpu = actor is Actor.GPU
        local = h2d = d2h = 0
        nvl_b = nvl_n = fab_b = fab_n = 0
        for L in range(nlocs - 1):  # every mapped (node, tier) location
            b = int(bl[L])
            r = int(cl[L])
            if r == 0:
                continue
            if L & 1:  # device-side location
                if L == dloc:
                    if gpu:
                        local += b
                        tr.device_local += b
                    else:  # CPU pulling its own GPU's memory over C2C
                        d2h += b
                        tr.link_d2h += b
                else:  # another node's device memory: inter-node NVLink
                    nvl_b += b
                    nvl_n += r
            else:  # host-side location
                if L == hloc:
                    if not gpu:
                        local += b
                        tr.host_local += b
                    elif is_write:
                        d2h += b
                        tr.link_d2h += b
                        tr.remote_d2h += b
                    else:
                        h2d += b
                        tr.link_h2d += b
                        tr.remote_h2d += b
                else:  # another node's host memory: inter-node fabric
                    fab_b += b
                    fab_n += r
        um.prof.extra["internode_nvlink_bytes"] += nvl_b
        um.prof.extra["internode_fabric_bytes"] += fab_b
        return local, h2d, d2h, 0, (nvl_b, nvl_n, fab_b, fab_n)

    def charge_access_batch_runs(self, um, a, gpu, wr, nodes, uloc, nb, nr):
        E = len(gpu)
        local = np.zeros(E, np.int64)
        h2d = np.zeros(E, np.int64)
        d2h = np.zeros(E, np.int64)
        lanes = np.zeros((E, 4), np.int64)
        tr = um.prof.traffic()
        dloc = 2 * nodes + 1
        hloc = 2 * nodes
        for c, L in enumerate(uloc.tolist()):
            b = nb[:, c]
            r = nr[:, c]
            if L & 1:  # device-side location
                mine = dloc == L
                m = mine & gpu
                local += np.where(m, b, 0)
                tr.device_local += int(b[m].sum())
                mc = mine & ~gpu
                d2h += np.where(mc, b, 0)
                tr.link_d2h += int(b[mc].sum())
                far = ~mine
                lanes[:, 0] += np.where(far, b, 0)
                lanes[:, 1] += np.where(far, r, 0)
            else:  # host-side location
                mine = hloc == L
                m = mine & ~gpu
                local += np.where(m, b, 0)
                tr.host_local += int(b[m].sum())
                mw = mine & gpu & wr
                d2h += np.where(mw, b, 0)
                s = int(b[mw].sum())
                tr.link_d2h += s
                tr.remote_d2h += s
                mr = mine & gpu & ~wr
                h2d += np.where(mr, b, 0)
                s = int(b[mr].sum())
                tr.link_h2d += s
                tr.remote_h2d += s
                far = ~mine
                lanes[:, 2] += np.where(far, b, 0)
                lanes[:, 3] += np.where(far, r, 0)
        um.prof.extra["internode_nvlink_bytes"] += int(lanes[:, 0].sum())
        um.prof.extra["internode_fabric_bytes"] += int(lanes[:, 2].sum())
        return local, h2d, d2h, np.zeros(E, np.int64), lanes

    def lanes_time(self, um, lanes) -> float:
        nvl_b, nvl_n, fab_b, fab_n = lanes
        if not (nvl_b or nvl_n or fab_b or fab_n):
            return 0.0
        topo = um.hw.topology
        if um._lane_degrade is None:
            # fixed association; lanes_time_batch applies the same expression
            return (nvl_b / topo.nvlink_bw + topo.nvlink_latency * nvl_n
                    + fab_b / topo.fabric_bw + topo.fabric_latency * fab_n)
        nvl_bw, fab_bw = _lane_bw(um, topo)
        um.prof.extra["degraded_nvlink_bytes"] += int(nvl_b)
        um.prof.extra["degraded_fabric_bytes"] += int(fab_b)
        return (nvl_b / nvl_bw + topo.nvlink_latency * nvl_n
                + fab_b / fab_bw + topo.fabric_latency * fab_n)

    def lanes_time_batch(self, um, lanes):
        topo = getattr(um.hw, "topology", None)
        if topo is None:  # N=1 run on a single-node model: lanes are zero
            return 0.0
        if um._lane_degrade is None:
            return (lanes[:, 0] / topo.nvlink_bw
                    + topo.nvlink_latency * lanes[:, 1]
                    + lanes[:, 2] / topo.fabric_bw
                    + topo.fabric_latency * lanes[:, 3])
        nvl_bw, fab_bw = _lane_bw(um, topo)
        um.prof.extra["degraded_nvlink_bytes"] += int(lanes[:, 0].sum())
        um.prof.extra["degraded_fabric_bytes"] += int(lanes[:, 2].sum())
        return (lanes[:, 0] / nvl_bw
                + topo.nvlink_latency * lanes[:, 1]
                + lanes[:, 2] / fab_bw
                + topo.fabric_latency * lanes[:, 3])

    # -------------------------------------------------- placement dispatch
    def on_demote(self, um, a, p0, p1):
        """Spill device-resident pages of [p0, p1) to host memory. On one
        node the built-in path already does exactly that; on a cluster each
        node's pages spill to the *next* node's host memory (ring order),
        so a preempting node frees its HBM without loading its own LPDDR."""
        t = a.table
        if t.num_nodes == 1:
            return None
        topo = um.hw.topology
        tr = um.prof.traffic()
        for k in range(t.num_nodes):
            ds_, de_ = t.runs_of(2 * k + 1, p0, p1)
            if len(ds_) == 0:
                continue
            nbytes = int(t.span_bytes(ds_, de_).sum())
            npages = int((de_ - ds_).sum())
            dst = (k + 1) % t.num_nodes
            while dst != k and dst in um._dead_nodes:
                dst = (dst + 1) % t.num_nodes  # ring-skip lost nodes
            um._apply_delta(t.move_runs(ds_, de_, 2 * dst))
            t.clear_dirty(ds_, de_)
            tr.migrated_out += nbytes
            tr.link_d2h += nbytes
            um._charge(nbytes / um.hw.link_d2h
                       + um.hw.migrate_per_page * npages)
            # the cross-node hop rides the fabric on top of the C2C push
            fab_bw = _lane_bw(um, topo)[1]
            um._charge(nbytes / fab_bw
                       + topo.fabric_latency * len(ds_))
            um.prof.extra["internode_fabric_bytes"] += nbytes
            if um._lane_degrade is not None:
                um.prof.extra["degraded_fabric_bytes"] += nbytes
        return 0.0

    def on_migrate_in(self, um, a, starts, ends):
        """Promote host-resident pages of the spans toward the accessing
        node's device memory, paying the fabric for cross-node sources."""
        t = a.table
        if t.num_nodes == 1:
            return None
        d = int(um._node)
        topo = um.hw.topology
        tr = um.prof.traffic()
        migrated = 0
        free = device_free_on(um, d)
        for k in range(t.num_nodes):
            hs, he = [], []
            for s0, e0 in zip(starts, ends):
                rs, re_ = t.runs_of(2 * k, int(s0), int(e0))
                hs.append(rs)
                he.append(re_)
            hs = np.concatenate(hs) if hs else np.empty(0, np.int64)
            he = np.concatenate(he) if he else np.empty(0, np.int64)
            if len(hs) == 0:
                continue
            need = int(t.span_bytes(hs, he).sum())
            if need > free:  # no eviction: prefix-fit what the node holds
                hs, he = um._prefix_fit_runs(t, hs, he, free)
                if len(hs) == 0:
                    continue
                need = int(t.span_bytes(hs, he).sum())
                if need == 0:
                    continue
            um._apply_delta(t.move_runs(hs, he, 2 * d + 1))
            free -= need
            npages = int((he - hs).sum())
            tr.migrated_in += need
            tr.link_h2d += need
            um._charge(need / um.hw.link_h2d
                       + um.hw.migrate_per_page * npages)
            if k != d:  # source host memory sits on another node
                fab_bw = _lane_bw(um, topo)[1]
                um._charge(need / fab_bw
                           + topo.fabric_latency * len(hs))
                um.prof.extra["internode_fabric_bytes"] += need
                if um._lane_degrade is not None:
                    um.prof.extra["degraded_fabric_bytes"] += need
            migrated += need
        return migrated


@dataclass(frozen=True)
class ClusterSystemPolicy(ClusterPolicy):
    """Locality-first: each node first-touches into its own superchip."""

    kind = "cluster_system"

    def on_first_touch(self, um, a, p0, p1, actor, n_unmapped, need_bytes):
        self._charge_pte(um, actor, n_unmapped)
        d = um._node
        if actor is Actor.GPU and need_bytes <= device_free_on(um, d):
            return node_tier_loc(d, Tier.DEVICE)
        return node_tier_loc(d, Tier.HOST)


@dataclass(frozen=True)
class ClusterStripedPolicy(ClusterPolicy):
    """Capacity-first: GPU first touch stripes pages round-robin across
    every node's device memory (``stripe_pages`` pages per stripe), falling
    back per node to that node's host memory when its device is full. CPU
    first touch stays node-local host, like the locality backend."""

    kind = "cluster_striped"

    def on_first_touch(self, um, a, p0, p1, actor, n_unmapped, need_bytes):
        self._charge_pte(um, actor, n_unmapped)
        t = a.table
        d = um._node
        if actor is not Actor.GPU:
            return node_tier_loc(d, Tier.HOST)
        nn = t.num_nodes
        if nn == 1:
            if need_bytes <= device_free_on(um, d):
                return node_tier_loc(d, Tier.DEVICE)
            return node_tier_loc(d, Tier.HOST)
        sp = max(1, self.stripe_pages)
        free = {k: device_free_on(um, k) for k in range(nn)}
        # stripe only over surviving nodes; with none dead this reduces to
        # the original (b // sp) % nn round-robin
        alive = [k for k in range(nn) if k not in um._dead_nodes]
        us, ue = t.runs_of(Tier.UNMAPPED, p0, p1)
        for s0, e0 in zip(us, ue):
            b = int(s0)
            e0 = int(e0)
            while b < e0:
                nxt = min(e0, (b // sp + 1) * sp)
                k = alive[(b // sp) % len(alive)]
                nbytes = t.range_bytes(b, nxt)
                if nbytes <= free[k]:
                    um._apply_delta(
                        t.map_unmapped(b, nxt, node_tier_loc(k, Tier.DEVICE)))
                    free[k] -= nbytes
                else:
                    um._apply_delta(
                        t.map_unmapped(b, nxt, node_tier_loc(k, Tier.HOST)))
                b = nxt
        # everything in [p0, p1) is mapped now; the caller's map_unmapped
        # with this return value is a no-op
        return node_tier_loc(d, Tier.HOST)


def cluster_system_policy(page_size: int = 64 * KB) -> ClusterSystemPolicy:
    return ClusterSystemPolicy(
        page_size=page_size,
        migration_granule=max(page_size, 64 * KB),
        auto_migrate=False,  # no access counters: placement moves only
        # through the explicit prefetch/demote APIs
    )


def cluster_striped_policy(page_size: int = 64 * KB, *,
                           stripe_pages: int = 16) -> ClusterStripedPolicy:
    return ClusterStripedPolicy(
        page_size=page_size,
        migration_granule=max(page_size, 64 * KB),
        auto_migrate=False,
        stripe_pages=stripe_pages,
    )


register_policy("cluster_system", cluster_system_policy)
register_policy("cluster_striped", cluster_striped_policy)
