"""Gradient compression: blockwise int8 quantization with error feedback.

Cross-pod gradient sync at 512+ chips is ICI/DCN-bound; int8 halves bytes vs
bf16 (4x vs fp32). EF21-style error feedback keeps the compressed SGD
unbiased-in-the-limit: e_{t+1} = x - D(Q(x)), carried into the next step.
Property tests bound the roundtrip error and verify EF convergence on a
quadratic (tests/test_compression.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def int8_quant(x) -> Tuple[jax.Array, jax.Array]:
    """x: any shape f32 -> (int8 codes (padded, BLOCK-major), scales per block)."""
    flat, n = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_dequant(q, scale, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def ef_int8_roundtrip(g, err) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback compression step: returns (decompressed, new_err).

    The decompressed value is what crosses the wire (as int8+scales); the
    residual stays local and is added next step.
    """
    acc = g.astype(jnp.float32) + err
    q, s = int8_quant(acc)
    deq = int8_dequant(q, s, g.shape)
    return deq, acc - deq
