"""AdamW with fp32 master weights — ZeRO-1-shardable, host-offloadable state.

State layout mirrors the param tree: {'m','v','master'} per leaf + step count.
Sharding is decided at launch time (launch/sharding.py gives optimizer state
an extra 'data'-axis shard — ZeRO-1); the unified-memory integration places
'm'/'v'/'master' on pinned_host when umem decides they are cold (see
launch/sharding.py::offload_opt_specs and DESIGN.md §3.2.2).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt, params, *, lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: float = 1.0) -> Tuple[Any, Dict[str, Any], jax.Array]:
    """Returns (new_params, new_opt, grad_norm). All grad math in fp32."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(g32)))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    g32 = jax.tree.map(lambda g: g * scale, g32)

    count = opt["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, w):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        w = w - lr * (step + weight_decay * w)
        return m, v, w

    flat_g, tdef = jax.tree.flatten(g32)
    flat_m = tdef.flatten_up_to(opt["m"])
    flat_v = tdef.flatten_up_to(opt["v"])
    flat_w = tdef.flatten_up_to(opt["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = tdef.unflatten([o[0] for o in out])
    new_v = tdef.unflatten([o[1] for o in out])
    new_w = tdef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), new_w, params)
    new_opt = {"m": new_m, "v": new_v, "master": new_w, "count": count}
    return new_params, new_opt, gnorm
