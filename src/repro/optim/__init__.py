from repro.optim.adamw import adamw_init, adamw_update  # noqa: F401
from repro.optim.compression import ef_int8_roundtrip, int8_dequant, int8_quant  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
