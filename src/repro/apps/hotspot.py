"""Hotspot: thermal-simulation stencil (Rodinia). Regular access, CPU-init.

Paper roles: Fig. 3 (system > managed in-memory), Fig. 4 timeline shape,
Fig. 6/7 page-size sensitivity, Fig. 11 oversubscription robustness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.common import KB, AppResult, AppSpec, finish, make_um
from repro.core import Actor, KernelLaunch
from repro.kernels.stencil5 import stencil5

COEFF = 0.1


def run_hotspot(policy_kind: str = "system", *, rows: int = 1024, cols: int = 1024,
                iters: int = 8, page_size: int = 64 * KB,
                oversub_ratio: float = 0.0, auto_migrate: bool = True,
                hw=None, interpret: bool = True) -> AppResult:
    nbytes = rows * cols * 4
    um, pol = make_um(policy_kind, page_size=page_size, hw=hw,
                      oversub_ratio=oversub_ratio,
                      app_peak_bytes=3 * nbytes, auto_migrate=auto_migrate)

    with um.phase("alloc"):
        temp_m = um.from_host("temp", (rows, cols), jnp.float32, pol)
        power_m = um.from_host("power", (rows, cols), jnp.float32, pol)
        out_m = um.array("temp_out", (rows, cols), jnp.float32, pol)  # GPU scratch

    key = jax.random.PRNGKey(0)
    with um.phase("cpu_init"):
        temp = 300.0 + 50.0 * jax.random.uniform(key, (rows, cols), jnp.float32)
        power = jax.random.uniform(jax.random.PRNGKey(1), (rows, cols), jnp.float32)
        um.launch("init", writes=[temp_m[:], power_m[:]], actor=Actor.CPU)

    with um.staged(h2d=[temp_m, power_m], d2h=[temp_m]):
        with um.phase("compute"):
            src, dst = temp_m, out_m
            for it in range(iters):
                temp = stencil5(temp, COEFF, interpret=interpret) + 0.001 * power
                # submitted through the batched engine (sync-per-iteration
                # keeps the batch at one launch; charges are identical)
                um.launch_batch([KernelLaunch(
                    f"sweep{it}", reads=[src[:], power_m[:]],
                    writes=[dst[:]],
                    flops=7.0 * rows * cols, actor=Actor.GPU)])
                um.sync()
                src, dst = dst, src

    with um.phase("dealloc"):
        um.free_live()

    return finish(um, "hotspot", policy_kind, page_size, float(jnp.mean(temp)),
                  iters=iters, rows=rows, cols=cols)


SPEC = AppSpec(
    name="hotspot", run=run_hotspot, init_actor="cpu",
    sizes={"fig3": dict(rows=1024, cols=1024, iters=8),
           "fig11": dict(rows=1024, cols=1024, iters=6),
           "small": dict(rows=256, cols=256, iters=6)})
