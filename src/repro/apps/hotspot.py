"""Hotspot: thermal-simulation stencil (Rodinia). Regular access, CPU-init.

Paper roles: Fig. 3 (system > managed in-memory), Fig. 4 timeline shape,
Fig. 6/7 page-size sensitivity, Fig. 11 oversubscription robustness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.common import KB, AppResult, explicit_pair, finish, make_um
from repro.core import Actor
from repro.kernels.stencil5 import stencil5

COEFF = 0.1


def run_hotspot(policy_kind: str = "system", *, rows: int = 1024, cols: int = 1024,
                iters: int = 8, page_size: int = 64 * KB,
                oversub_ratio: float = 0.0, auto_migrate: bool = True,
                interpret: bool = True) -> AppResult:
    nbytes = rows * cols * 4
    um, pol = make_um(policy_kind, page_size=page_size, oversub_ratio=oversub_ratio,
                      app_peak_bytes=3 * nbytes, auto_migrate=auto_migrate)

    with um.phase("alloc"):
        if policy_kind == "explicit":
            temp_d, temp_h = explicit_pair(um, "temp", nbytes)
            power_d, power_h = explicit_pair(um, "power", nbytes)
            out_d = um.alloc("temp_out", nbytes, pol)  # GPU-only scratch
        else:
            temp_d = um.alloc("temp", nbytes, pol)
            power_d = um.alloc("power", nbytes, pol)
            out_d = um.alloc("temp_out", nbytes, pol)

    key = jax.random.PRNGKey(0)
    with um.phase("cpu_init"):
        temp = 300.0 + 50.0 * jax.random.uniform(key, (rows, cols), jnp.float32)
        power = jax.random.uniform(jax.random.PRNGKey(1), (rows, cols), jnp.float32)
        if policy_kind == "explicit":
            um.kernel(writes=[(temp_h, 0, nbytes), (power_h, 0, nbytes)],
                      actor=Actor.CPU, name="init")
        else:
            um.kernel(writes=[(temp_d, 0, nbytes), (power_d, 0, nbytes)],
                      actor=Actor.CPU, name="init")

    if policy_kind == "explicit":
        with um.phase("h2d"):
            um.copy(temp_d, 0, nbytes, "h2d")
            um.copy(power_d, 0, nbytes, "h2d")

    with um.phase("compute"):
        src, dst = temp_d, out_d
        for it in range(iters):
            temp = stencil5(temp, COEFF, interpret=interpret) + 0.001 * power
            um.kernel(reads=[(src, 0, nbytes), (power_d, 0, nbytes)],
                      writes=[(dst, 0, nbytes)],
                      flops=7.0 * rows * cols, actor=Actor.GPU, name=f"sweep{it}")
            um.sync()
            src, dst = dst, src

    if policy_kind == "explicit":
        with um.phase("d2h"):
            um.copy(temp_d, 0, nbytes, "d2h")

    with um.phase("dealloc"):
        for a in list(um.allocs.values()):
            if not a.freed and a.name != "__ballast__":
                um.free(a)

    return finish(um, "hotspot", policy_kind, page_size, float(jnp.mean(temp)),
                  iters=iters, rows=rows, cols=cols)
