"""Needleman-Wunsch sequence alignment (Rodinia). Irregular, CPU-init.

Anti-diagonal wavefront DP; the row-associative form lets JAX compute each
row with a cummax instead of a serial column loop (see _nw_rows)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.common import KB, AppResult, explicit_pair, finish, make_um
from repro.core import Actor


def _nw_rows(sim, penalty: int):
    """F[i,j] = max(F[i-1,j-1]+sim, F[i-1,j]-p, F[i,j-1]-p).

    Per-row: A[j] = max(F[i-1,j-1]+sim[i,j], F[i-1,j]-p);
    F[i,j] = cummax_j(A[j] + p*j) - p*j   (max-plus prefix identity).
    """
    n = sim.shape[1]
    jdx = jnp.arange(n, dtype=jnp.int32) * penalty

    def step(prev, srow):
        shifted = jnp.concatenate([jnp.array([-penalty], prev.dtype), prev[:-1]])
        A = jnp.maximum(shifted + srow, prev - penalty)
        F = jax.lax.cummax(A + jdx) - jdx
        return F, None

    init = -penalty * jnp.arange(n, dtype=jnp.int32)
    last, _ = jax.lax.scan(step, init, sim)
    return last


def run_needle(policy_kind: str = "system", *, n: int = 2048, penalty: int = 1,
               page_size: int = 64 * KB, waves_per_kernel: int = 64,
               oversub_ratio: float = 0.0, auto_migrate: bool = True,
               interpret: bool = True) -> AppResult:
    nbytes = n * n * 4
    um, pol = make_um(policy_kind, page_size=page_size, oversub_ratio=oversub_ratio,
                      app_peak_bytes=2 * nbytes, auto_migrate=auto_migrate)

    with um.phase("alloc"):
        if policy_kind == "explicit":
            ref_d, ref_h = explicit_pair(um, "reference", nbytes)
            mat_d, mat_h = explicit_pair(um, "matrix", nbytes)
        else:
            ref_d = um.alloc("reference", nbytes, pol)
            mat_d = um.alloc("matrix", nbytes, pol)

    key = jax.random.PRNGKey(11)
    with um.phase("cpu_init"):
        sim = jax.random.randint(key, (n, n), -2, 3, jnp.int32)
        tgts = [ref_h, mat_h] if policy_kind == "explicit" else [ref_d, mat_d]
        um.kernel(writes=[(t, 0, nbytes) for t in tgts], actor=Actor.CPU, name="init")

    if policy_kind == "explicit":
        with um.phase("h2d"):
            um.copy(ref_d, 0, nbytes, "h2d")
            um.copy(mat_d, 0, nbytes, "h2d")

    with um.phase("compute"):
        last_row = _nw_rows(sim, penalty)
        # wavefront sweeps touch growing/shrinking diagonal bands: model as
        # strided sub-range kernels (irregular pattern)
        waves = 2 * n - 1
        rows_per_wave = max(1, n // 64)
        for w0 in range(0, waves, waves_per_kernel):
            w1 = min(w0 + waves_per_kernel, waves)
            frac0, frac1 = w0 / waves, w1 / waves
            lo = int(frac0 * nbytes) // 4096 * 4096
            hi = max(lo + 4096, int(frac1 * nbytes) // 4096 * 4096)
            hi = min(hi, nbytes)
            um.kernel(
                reads=[(ref_d, lo, hi), (mat_d, lo, hi)],
                writes=[(mat_d, lo, hi)],
                flops=10.0 * (hi - lo) / 4, actor=Actor.GPU, name=f"wave{w0}")
            um.sync()

    if policy_kind == "explicit":
        with um.phase("d2h"):
            um.copy(mat_d, 0, nbytes, "d2h")

    with um.phase("dealloc"):
        for a in list(um.allocs.values()):
            if not a.freed and a.name != "__ballast__":
                um.free(a)

    return finish(um, "needle", policy_kind, page_size,
                  float(last_row[-1]), n=n)
