"""Needleman-Wunsch sequence alignment (Rodinia). Irregular, CPU-init.

Anti-diagonal wavefront DP; the row-associative form lets JAX compute each
row with a cummax instead of a serial column loop (see _nw_rows)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.common import KB, AppResult, AppSpec, finish, make_um
from repro.core import Actor, KernelLaunch


def _nw_rows(sim, penalty: int):
    """F[i,j] = max(F[i-1,j-1]+sim, F[i-1,j]-p, F[i,j-1]-p).

    Per-row: A[j] = max(F[i-1,j-1]+sim[i,j], F[i-1,j]-p);
    F[i,j] = cummax_j(A[j] + p*j) - p*j   (max-plus prefix identity).
    """
    n = sim.shape[1]
    jdx = jnp.arange(n, dtype=jnp.int32) * penalty

    def step(prev, srow):
        shifted = jnp.concatenate([jnp.array([-penalty], prev.dtype), prev[:-1]])
        A = jnp.maximum(shifted + srow, prev - penalty)
        F = jax.lax.cummax(A + jdx) - jdx
        return F, None

    init = -penalty * jnp.arange(n, dtype=jnp.int32)
    last, _ = jax.lax.scan(step, init, sim)
    return last


def run_needle(policy_kind: str = "system", *, n: int = 2048, penalty: int = 1,
               page_size: int = 64 * KB, waves_per_kernel: int = 64,
               oversub_ratio: float = 0.0, auto_migrate: bool = True,
               hw=None, interpret: bool = True) -> AppResult:
    nbytes = n * n * 4
    um, pol = make_um(policy_kind, page_size=page_size, hw=hw,
                      oversub_ratio=oversub_ratio,
                      app_peak_bytes=2 * nbytes, auto_migrate=auto_migrate)

    with um.phase("alloc"):
        ref = um.from_host("reference", (n, n), jnp.int32, pol)
        mat = um.from_host("matrix", (n, n), jnp.int32, pol)

    key = jax.random.PRNGKey(11)
    with um.phase("cpu_init"):
        sim = jax.random.randint(key, (n, n), -2, 3, jnp.int32)
        um.launch("init", writes=[ref[:], mat[:]], actor=Actor.CPU)

    with um.staged(h2d=[ref, mat], d2h=[mat]):
        with um.phase("compute"):
            last_row = _nw_rows(sim, penalty)
            # wavefront sweeps touch growing/shrinking diagonal bands: model as
            # strided sub-range kernels (irregular pattern)
            waves = 2 * n - 1
            for w0 in range(0, waves, waves_per_kernel):
                w1 = min(w0 + waves_per_kernel, waves)
                frac0, frac1 = w0 / waves, w1 / waves
                lo = int(frac0 * nbytes) // 4096 * 4096
                hi = max(lo + 4096, int(frac1 * nbytes) // 4096 * 4096)
                hi = min(hi, nbytes)
                um.launch_batch([KernelLaunch(
                    f"wave{w0}",
                    reads=[ref.byterange(lo, hi), mat.byterange(lo, hi)],
                    writes=[mat.byterange(lo, hi)],
                    flops=10.0 * (hi - lo) / 4, actor=Actor.GPU)])
                um.sync()

    with um.phase("dealloc"):
        um.free_live()

    return finish(um, "needle", policy_kind, page_size,
                  float(last_row[-1]), n=n)


SPEC = AppSpec(
    name="needle", run=run_needle, init_actor="cpu",
    sizes={"fig3": dict(n=1024),
           "fig11": dict(n=1024),
           "small": dict(n=512)})
