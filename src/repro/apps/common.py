"""Shared app harness: policy factories, phases, ballast oversubscription.

Every app follows the paper's Fig. 2 structure:
    alloc -> init (CPU- or GPU-side first touch) -> compute -> dealloc
in one of three memory-management versions: 'explicit' (original
cudaMalloc+memcpy), 'managed' (cudaMallocManaged), 'system' (malloc).

The math is real JAX executed on CPU; the *memory system* (placement,
faults, counters, migrations, traffic, modeled time) is the UnifiedMemory
runtime. Oversubscription uses the paper's own methodology (§3.2): a ballast
explicit allocation shrinks free device memory to hit a target ratio.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core import (
    GRACE_HOPPER,
    Actor,
    HardwareModel,
    UnifiedMemory,
    explicit_policy,
    managed_policy,
    system_policy,
)

KB = 1024
MB = 1024 * 1024


@dataclass
class AppResult:
    name: str
    policy: str
    page_size: int
    phase_times: Dict[str, float]
    checksum: float
    report: Dict[str, object]
    extra: Dict[str, object] = field(default_factory=dict)

    @property
    def total(self) -> float:
        return sum(self.phase_times.values())

    def time_excluding_cpu_init(self) -> float:
        """The paper excludes single-threaded CPU init when reporting (§3.1)."""
        return sum(v for k, v in self.phase_times.items() if k != "cpu_init")


def make_um(policy_kind: str, *, page_size: int = 64 * KB,
            hw: HardwareModel = GRACE_HOPPER, auto_migrate: bool = True,
            oversub_ratio: float = 0.0, app_peak_bytes: int = 0,
            speculative_prefetch: int = 4, threshold: int = 256):
    """Build a UnifiedMemory + the policy for app buffers (+ballast if oversub).

    oversub_ratio R > 1 shrinks free device memory so that
    app_peak_bytes / free == R (the paper's simulated oversubscription).
    """
    um = UnifiedMemory(hw=hw)
    if oversub_ratio and oversub_ratio > 1.0:
        assert app_peak_bytes > 0
        target_free = int(app_peak_bytes / oversub_ratio)
        ballast = hw.device_capacity - target_free
        if ballast > 0:
            um.alloc("__ballast__", ballast, explicit_policy())
    if policy_kind == "system":
        pol = system_policy(page_size, auto_migrate=auto_migrate, threshold=threshold)
    elif policy_kind == "managed":
        pol = managed_policy(page_size, speculative_prefetch=speculative_prefetch)
    elif policy_kind == "explicit":
        pol = explicit_policy()
    else:
        raise ValueError(policy_kind)
    return um, pol


def explicit_pair(um: UnifiedMemory, name: str, nbytes: int):
    """Explicit version: a host staging buffer + a device buffer."""
    dev = um.alloc(name, nbytes, explicit_policy())
    host = um.alloc(name + "__host", nbytes, system_policy(auto_migrate=False))
    return dev, host


def finish(um: UnifiedMemory, name: str, policy_kind: str, page_size: int,
           checksum: float, **extra) -> AppResult:
    rep = um.report()
    return AppResult(
        name=name,
        policy=policy_kind,
        page_size=page_size,
        phase_times=dict(um.prof.phase_times),
        checksum=float(checksum),
        report=rep,
        extra=extra,
    )
