"""Shared app harness: the AppSpec registry, policy factories, phases,
ballast oversubscription, charge fingerprints.

Every app follows the paper's Fig. 2 structure:
    alloc -> init (CPU- or GPU-side first touch) -> compute -> dealloc
in one of three memory-management versions: 'explicit' (original
cudaMalloc+memcpy), 'managed' (cudaMallocManaged), 'system' (malloc).

Apps are *buffer-centric*: they allocate typed UMBuffers via
``um.array``/``um.from_host``, launch tracked kernels over buffer slices
via ``um.launch``, and wrap their compute region in ``um.staged(...)``,
which charges the explicit version's h2d/d2h copies at the phase
boundaries. No app hand-writes ``(alloc, lo, hi)`` byte ranges or branches
on the policy kind for staging — the memory model follows the buffers.

The math is real JAX executed on CPU; the *memory system* (placement,
faults, counters, migrations, traffic, modeled time) is the UnifiedMemory
runtime. Oversubscription uses the paper's own methodology (§3.2): a ballast
explicit allocation shrinks free device memory to hit a target ratio.

Each app module exports an :class:`AppSpec` (uniform runner + per-figure
size presets); ``repro.apps.APPS`` is the registry the benchmarks, the
parity harness (scripts/check_parity.py) and the tests consume.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping

from repro.core import (
    Actor,
    HardwareModel,
    UnifiedMemory,
    explicit_policy,
    get_hardware,
    make_policy,
)

KB = 1024
MB = 1024 * 1024

# observers called with every UnifiedMemory make_um builds (the apps import
# make_um by name, so monkeypatching the module attribute would miss them);
# core/trace.record_app attaches its recorder through this
_UM_HOOKS = []


def add_um_hook(fn) -> None:
    """Register ``fn(um)`` to be called on every make_um-built runtime."""
    _UM_HOOKS.append(fn)


def remove_um_hook(fn) -> None:
    _UM_HOOKS.remove(fn)


@dataclass
class AppResult:
    name: str
    policy: str
    page_size: int
    phase_times: Dict[str, float]
    checksum: float
    report: Dict[str, object]
    extra: Dict[str, object] = field(default_factory=dict)

    @property
    def total(self) -> float:
        return sum(self.phase_times.values())

    def time_excluding_cpu_init(self) -> float:
        """The paper excludes single-threaded CPU init when reporting (§3.1)."""
        return sum(v for k, v in self.phase_times.items() if k != "cpu_init")


@dataclass(frozen=True)
class AppSpec:
    """One paper app: a uniform runner plus its per-figure size presets.

    ``run(policy_kind, **kw)`` is the single entry point for every
    memory-management version; ``sizes`` holds the canonical keyword presets
    ("fig3", "fig11", "small") that benchmarks/fig3_overview.py,
    fig11_oversub.py, fig67_pagesize.py, the charge-parity harness and
    tests/test_apps.py all share — one source of truth for the 66
    parity-pinned configurations."""
    name: str
    run: Callable[..., AppResult]
    init_actor: str  # "cpu" | "gpu": who first-touches the data (Fig. 3 class)
    sizes: Mapping[str, Mapping[str, object]]


def make_um(policy_kind: str, *, page_size: int = 64 * KB,
            hw: "HardwareModel | str | None" = None, auto_migrate: bool = True,
            oversub_ratio: float = 0.0, app_peak_bytes: int = 0,
            speculative_prefetch: int = 4, threshold: int = 256):
    """Build a UnifiedMemory + the policy for app buffers (+ballast if oversub).

    ``policy_kind`` is resolved through the backend registry
    (``repro.core.registry``), so any registered policy — including
    out-of-tree backends — runs through the same app harness. ``hw`` may be
    a HardwareModel, a registered hardware name, or None (grace-hopper).

    oversub_ratio R > 1 shrinks free device memory so that
    app_peak_bytes / free == R (the paper's simulated oversubscription).
    The runtime's staging page size follows the app's system page size, so
    explicit-version host staging buffers (um.from_host) are paged like the
    system-memory version instead of at a hard-wired 64 KB default.
    """
    hw = get_hardware(hw)
    um = UnifiedMemory(hw=hw, staging_page_size=page_size)
    if oversub_ratio and oversub_ratio > 1.0:
        assert app_peak_bytes > 0
        target_free = int(app_peak_bytes / oversub_ratio)
        ballast = hw.device_capacity - target_free
        if ballast > 0:
            um.alloc("__ballast__", ballast, explicit_policy())
    pol = make_policy(policy_kind, page_size=page_size,
                      auto_migrate=auto_migrate, threshold=threshold,
                      speculative_prefetch=speculative_prefetch)
    for hook in _UM_HOOKS:
        hook(um)
    return um, pol


def finish(um: UnifiedMemory, name: str, policy_kind: str, page_size: int,
           checksum: float, **extra) -> AppResult:
    rep = um.report()
    extra = dict(extra, hw=um.hw.name)
    return AppResult(
        name=name,
        policy=policy_kind,
        page_size=page_size,
        phase_times=dict(um.prof.phase_times),
        checksum=float(checksum),
        report=rep,
        extra=extra,
    )


def charge_snapshot(r: AppResult) -> Dict[str, object]:
    """Full-precision charge fingerprint of one app run.

    Phase times are serialized as float hex (bit-exact round trip), traffic
    counters as ints — this is what scripts/check_parity.py diffs against
    tests/fixtures/parity.json and what tests/test_parity.py pins in tier-1.
    """
    rep = r.report
    return {
        "phase_times": {k: float(v).hex() for k, v in sorted(r.phase_times.items())},
        "traffic_total": {k: int(v) for k, v in sorted(rep["traffic_total"].items())},
        "traffic_phases": {ph: {k: int(v) for k, v in sorted(tr.items())}
                           for ph, tr in sorted(rep["traffic"].items())},
    }
