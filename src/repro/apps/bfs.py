"""BFS: level-synchronous breadth-first search on a CSR graph (Rodinia).

Mixed access pattern, CPU-init (graph construction). Frontier expansion
touches scattered col_idx ranges — by default modeled as a per-level
partial-range read sized by a hand-estimated frontier fraction (the paper's
coarse model). With ``sparse_access=True`` the level kernels instead read
exactly the ``col_idx`` extents the frontier's adjacency gathers touch
(page-coalesced ``buf[...]`` slices) — fine-granularity partial access as a
first-class buffer expression. Off by default so the default-config charges
stay bit-identical to the coarse model.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.common import KB, AppResult, AppSpec, finish, make_um
from repro.core import Actor, KernelLaunch, UMBuffer, coalesce_runs


def _random_graph(n_nodes: int, deg: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, n_nodes, size=n_nodes * deg, dtype=np.int32)
    row_ptr = np.arange(0, n_nodes * deg + 1, deg, dtype=np.int32)
    return jnp.asarray(row_ptr), jnp.asarray(cols)


def _bfs_levels(row_ptr, cols, n_nodes: int, deg: int, src: int = 0,
                max_levels: int = 32, collect_frontiers: bool = False):
    """Returns (levels array, per-level frontier sizes[, expanded frontiers]).

    With collect_frontiers=True also returns, for each modeled level kernel,
    the node ids whose adjacency lists that kernel gathers (the frontier
    *being expanded*, driving sparse_access extent resolution)."""
    level = jnp.full((n_nodes,), -1, jnp.int32).at[src].set(0)
    frontier = jnp.zeros((n_nodes,), bool).at[src].set(True)
    sizes = []
    fronts: List[np.ndarray] = []
    neigh = cols.reshape(n_nodes, deg)
    for lv in range(1, max_levels):
        expanding = (np.flatnonzero(np.asarray(frontier))
                     if collect_frontiers else None)
        # neighbors of frontier nodes
        mask = frontier[:, None]
        touched = jnp.zeros((n_nodes,), bool).at[
            jnp.where(mask, neigh, 0).reshape(-1)].max(mask.repeat(deg, 1).reshape(-1))
        new = touched & (level < 0)
        if not bool(new.any()):
            break
        level = jnp.where(new, lv, level)
        sizes.append(int(new.sum()))
        if collect_frontiers:
            fronts.append(expanding)
        frontier = new
    if collect_frontiers:
        return level, sizes, fronts
    return level, sizes


def _frontier_views(edges: UMBuffer, nodes: np.ndarray, deg: int,
                    page_size: int):
    """The col_idx extents a frontier gather touches, as buffer slices.

    Each frontier node v reads its adjacency block — elements
    [v*deg, (v+1)*deg) — so the touched element set is the union of those
    blocks, coalesced to page granularity (pages are what the memory system
    moves/charges) and merged into maximal runs. Node runs are coalesced
    *before* the page conversion so a block spanning many pages contributes
    its full page range, interior pages included."""
    if len(nodes) == 0:
        return []
    per_page = max(1, page_size // edges.itemsize)
    views = []
    for v0, v1 in coalesce_runs(np.unique(nodes)):
        p0 = (v0 * deg) // per_page
        p1 = (v1 * deg - 1) // per_page + 1
        if views and p0 <= views[-1][1]:  # touches/overlaps the previous run
            views[-1][1] = max(views[-1][1], p1)
        else:
            views.append([p0, p1])
    return [edges[s * per_page:e * per_page] for s, e in views]


def run_bfs(policy_kind: str = "system", *, n_nodes: int = 1 << 16, deg: int = 8,
            page_size: int = 64 * KB, oversub_ratio: float = 0.0,
            auto_migrate: bool = True, sparse_access: bool = False,
            hw=None, interpret: bool = True) -> AppResult:
    edge_bytes = n_nodes * deg * 4
    node_bytes = n_nodes * 4
    um, pol = make_um(policy_kind, page_size=page_size, hw=hw,
                      oversub_ratio=oversub_ratio,
                      app_peak_bytes=edge_bytes + 3 * node_bytes,
                      auto_migrate=auto_migrate)

    with um.phase("alloc"):
        edges = um.from_host("col_idx", (n_nodes * deg,), jnp.int32, pol)
        rowp = um.from_host("row_ptr", (n_nodes,), jnp.int32, pol)
        cost = um.array("cost", (n_nodes,), jnp.int32, pol)

    with um.phase("cpu_init"):
        row_ptr, cols = _random_graph(n_nodes, deg)
        um.launch("build", writes=[edges[:], rowp[:]], actor=Actor.CPU)

    fronts: List[np.ndarray] = []
    with um.staged(h2d=[edges, rowp], d2h=[cost]):
        with um.phase("compute"):
            if sparse_access:
                level, sizes, fronts = _bfs_levels(
                    row_ptr, cols, n_nodes, deg, collect_frontiers=True)
            else:
                level, sizes = _bfs_levels(row_ptr, cols, n_nodes, deg)
            total = max(1, n_nodes)
            for lv, fsize in enumerate(sizes):
                if sparse_access:
                    # exactly the adjacency extents this level gathers
                    reads = _frontier_views(edges, fronts[lv], deg,
                                            pol.page_size)
                else:
                    # frontier covers fsize/n of nodes: estimate the touched
                    # fraction of the whole edge array (scattered pages)
                    frac = min(1.0, fsize * 4.0 / total)
                    hi = max(4096, int(frac * edge_bytes) // 4096 * 4096)
                    reads = [edges.byterange(0, min(hi, edge_bytes))]
                um.launch_batch([KernelLaunch(
                    f"level{lv}", reads=reads + [rowp[:]],
                    writes=[cost[:]],
                    flops=2.0 * fsize * deg, actor=Actor.GPU)])
                um.sync()

    with um.phase("dealloc"):
        um.free_live()

    visited = int((level >= 0).sum())
    return finish(um, "bfs", policy_kind, page_size, float(visited),
                  n_nodes=n_nodes, levels=len(sizes), sparse=sparse_access)


SPEC = AppSpec(
    name="bfs", run=run_bfs, init_actor="cpu",
    sizes={"fig3": dict(n_nodes=1 << 14),
           "fig11": dict(n_nodes=1 << 14),
           "small": dict(n_nodes=1 << 12)})
