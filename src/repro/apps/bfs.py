"""BFS: level-synchronous breadth-first search on a CSR graph (Rodinia).

Mixed access pattern, CPU-init (graph construction). Frontier expansion
touches scattered col_idx ranges — modeled as per-level partial-range reads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.common import KB, AppResult, explicit_pair, finish, make_um
from repro.core import Actor


def _random_graph(n_nodes: int, deg: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    cols = rng.integers(0, n_nodes, size=n_nodes * deg, dtype=np.int32)
    row_ptr = np.arange(0, n_nodes * deg + 1, deg, dtype=np.int32)
    return jnp.asarray(row_ptr), jnp.asarray(cols)


def _bfs_levels(row_ptr, cols, n_nodes: int, deg: int, src: int = 0, max_levels: int = 32):
    """Returns (levels array, per-level frontier sizes)."""
    level = jnp.full((n_nodes,), -1, jnp.int32).at[src].set(0)
    frontier = jnp.zeros((n_nodes,), bool).at[src].set(True)
    sizes = []
    neigh = cols.reshape(n_nodes, deg)
    for lv in range(1, max_levels):
        # neighbors of frontier nodes
        mask = frontier[:, None]
        touched = jnp.zeros((n_nodes,), bool).at[
            jnp.where(mask, neigh, 0).reshape(-1)].max(mask.repeat(deg, 1).reshape(-1))
        new = touched & (level < 0)
        if not bool(new.any()):
            break
        level = jnp.where(new, lv, level)
        sizes.append(int(new.sum()))
        frontier = new
    return level, sizes


def run_bfs(policy_kind: str = "system", *, n_nodes: int = 1 << 16, deg: int = 8,
            page_size: int = 64 * KB, oversub_ratio: float = 0.0,
            auto_migrate: bool = True, interpret: bool = True) -> AppResult:
    edge_bytes = n_nodes * deg * 4
    node_bytes = n_nodes * 4
    um, pol = make_um(policy_kind, page_size=page_size, oversub_ratio=oversub_ratio,
                      app_peak_bytes=edge_bytes + 3 * node_bytes,
                      auto_migrate=auto_migrate)

    with um.phase("alloc"):
        if policy_kind == "explicit":
            edges_d, edges_h = explicit_pair(um, "col_idx", edge_bytes)
            rowp_d, rowp_h = explicit_pair(um, "row_ptr", node_bytes)
        else:
            edges_d = um.alloc("col_idx", edge_bytes, pol)
            rowp_d = um.alloc("row_ptr", node_bytes, pol)
        cost_d = um.alloc("cost", node_bytes, pol)

    with um.phase("cpu_init"):
        row_ptr, cols = _random_graph(n_nodes, deg)
        tg = [edges_h, rowp_h] if policy_kind == "explicit" else [edges_d, rowp_d]
        um.kernel(writes=[(t, 0, t.nbytes) for t in tg], actor=Actor.CPU, name="build")

    if policy_kind == "explicit":
        with um.phase("h2d"):
            um.copy(edges_d, 0, edge_bytes, "h2d")
            um.copy(rowp_d, 0, node_bytes, "h2d")

    with um.phase("compute"):
        level, sizes = _bfs_levels(row_ptr, cols, n_nodes, deg)
        total = max(1, n_nodes)
        for lv, fsize in enumerate(sizes):
            # frontier covers fsize/n of nodes; edges touched ~ fsize*deg
            frac = min(1.0, fsize * 4.0 / total)  # scattered pages touched
            hi = max(4096, int(frac * edge_bytes) // 4096 * 4096)
            um.kernel(
                reads=[(edges_d, 0, min(hi, edge_bytes)), (rowp_d, 0, node_bytes)],
                writes=[(cost_d, 0, node_bytes)],
                flops=2.0 * fsize * deg, actor=Actor.GPU, name=f"level{lv}")
            um.sync()

    if policy_kind == "explicit":
        with um.phase("d2h"):
            um.copy(cost_d, 0, node_bytes, "d2h")

    with um.phase("dealloc"):
        for a in list(um.allocs.values()):
            if not a.freed and a.name != "__ballast__":
                um.free(a)

    visited = int((level >= 0).sum())
    return finish(um, "bfs", policy_kind, page_size, float(visited),
                  n_nodes=n_nodes, levels=len(sizes))
