"""Pathfinder: 2-D grid dynamic programming (Rodinia). Regular, CPU-init."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.common import KB, AppResult, AppSpec, finish, make_um
from repro.core import Actor, KernelLaunch


def _dp_all_rows(data):
    """min-path DP: cost[j] = data[i,j] + min(prev[j-1], prev[j], prev[j+1])."""

    def step(prev, row):
        left = jnp.concatenate([prev[:1], prev[:-1]])
        right = jnp.concatenate([prev[1:], prev[-1:]])
        cur = row + jnp.minimum(prev, jnp.minimum(left, right))
        return cur, None

    out, _ = jax.lax.scan(step, data[0].astype(jnp.int32), data[1:])
    return out


def run_pathfinder(policy_kind: str = "system", *, rows: int = 4096, cols: int = 1024,
                   page_size: int = 64 * KB, rows_per_kernel: int = 512,
                   oversub_ratio: float = 0.0, auto_migrate: bool = True,
                   hw=None, interpret: bool = True) -> AppResult:
    row_bytes = cols * 4
    um, pol = make_um(policy_kind, page_size=page_size, hw=hw,
                      oversub_ratio=oversub_ratio,
                      app_peak_bytes=rows * row_bytes + 2 * row_bytes,
                      auto_migrate=auto_migrate)

    with um.phase("alloc"):
        wall = um.from_host("wall", (rows, cols), jnp.int32, pol)
        res = um.array("result", (2, cols), jnp.int32, pol)  # prev/cur row pair

    key = jax.random.PRNGKey(3)
    with um.phase("cpu_init"):
        data = jax.random.randint(key, (rows, cols), 0, 10, jnp.int32)
        um.launch("init", writes=[wall[:]], actor=Actor.CPU)

    with um.staged(h2d=[wall], d2h=[res.rows(0, 1)]):
        with um.phase("compute"):
            result = _dp_all_rows(data)
            # model the row-sweep: one kernel per block of rows, streaming the wall
            for r0 in range(0, rows, rows_per_kernel):
                r1 = min(r0 + rows_per_kernel, rows)
                um.launch_batch([KernelLaunch(
                    f"rows{r0}",
                    reads=[wall.rows(r0, r1), res.rows(0, 1)],
                    writes=[res.rows(1, 2)],
                    flops=5.0 * (r1 - r0) * cols, actor=Actor.GPU)])
                um.sync()

    with um.phase("dealloc"):
        um.free_live()

    return finish(um, "pathfinder", policy_kind, page_size,
                  float(jnp.sum(result) % 1_000_003), rows=rows, cols=cols)


SPEC = AppSpec(
    name="pathfinder", run=run_pathfinder, init_actor="cpu",
    sizes={"fig3": dict(rows=2048, cols=512),
           "fig11": dict(rows=2048, cols=512),
           "small": dict(rows=1024, cols=256)})
