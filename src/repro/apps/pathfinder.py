"""Pathfinder: 2-D grid dynamic programming (Rodinia). Regular, CPU-init."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.common import KB, AppResult, explicit_pair, finish, make_um
from repro.core import Actor


def _dp_all_rows(data):
    """min-path DP: cost[j] = data[i,j] + min(prev[j-1], prev[j], prev[j+1])."""

    def step(prev, row):
        left = jnp.concatenate([prev[:1], prev[:-1]])
        right = jnp.concatenate([prev[1:], prev[-1:]])
        cur = row + jnp.minimum(prev, jnp.minimum(left, right))
        return cur, None

    out, _ = jax.lax.scan(step, data[0].astype(jnp.int32), data[1:])
    return out


def run_pathfinder(policy_kind: str = "system", *, rows: int = 4096, cols: int = 1024,
                   page_size: int = 64 * KB, rows_per_kernel: int = 512,
                   oversub_ratio: float = 0.0, auto_migrate: bool = True,
                   interpret: bool = True) -> AppResult:
    nbytes = rows * cols * 4
    row_bytes = cols * 4
    um, pol = make_um(policy_kind, page_size=page_size, oversub_ratio=oversub_ratio,
                      app_peak_bytes=nbytes + 2 * row_bytes,
                      auto_migrate=auto_migrate)

    with um.phase("alloc"):
        if policy_kind == "explicit":
            data_d, data_h = explicit_pair(um, "wall", nbytes)
        else:
            data_d = um.alloc("wall", nbytes, pol)
        res_d = um.alloc("result", 2 * row_bytes, pol)

    key = jax.random.PRNGKey(3)
    with um.phase("cpu_init"):
        data = jax.random.randint(key, (rows, cols), 0, 10, jnp.int32)
        tgt = data_h if policy_kind == "explicit" else data_d
        um.kernel(writes=[(tgt, 0, nbytes)], actor=Actor.CPU, name="init")

    if policy_kind == "explicit":
        with um.phase("h2d"):
            um.copy(data_d, 0, nbytes, "h2d")

    with um.phase("compute"):
        result = _dp_all_rows(data)
        # model the row-sweep: one kernel per block of rows, streaming the wall
        for r0 in range(0, rows, rows_per_kernel):
            r1 = min(r0 + rows_per_kernel, rows)
            um.kernel(
                reads=[(data_d, r0 * row_bytes, r1 * row_bytes),
                       (res_d, 0, row_bytes)],
                writes=[(res_d, row_bytes, 2 * row_bytes)],
                flops=5.0 * (r1 - r0) * cols, actor=Actor.GPU,
                name=f"rows{r0}")
            um.sync()

    if policy_kind == "explicit":
        with um.phase("d2h"):
            um.copy(res_d, 0, row_bytes, "d2h")

    with um.phase("dealloc"):
        for a in list(um.allocs.values()):
            if not a.freed and a.name != "__ballast__":
                um.free(a)

    return finish(um, "pathfinder", policy_kind, page_size,
                  float(jnp.sum(result) % 1_000_003), rows=rows, cols=cols)
