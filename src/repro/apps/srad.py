"""SRAD: speckle-reducing anisotropic diffusion (Rodinia). Irregular, GPU-init.

Paper roles: Fig. 3 (managed > system in-memory: GPU-first-touch PTE cost),
Fig. 10 (access-counter migration warm-up: 3 phases, crossover ~iter 5),
Fig. 11 (worst oversubscription sensitivity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.apps.common import KB, AppResult, AppSpec, finish, make_um
from repro.core import Actor, KernelBatch
from repro.kernels.stencil5 import stencil5


def _srad_iter(J, lam: float, interpret: bool):
    # diffusion coefficient from local statistics, then diffusion sweep
    dN = jnp.roll(J, 1, 0) - J
    dS = jnp.roll(J, -1, 0) - J
    dW = jnp.roll(J, 1, 1) - J
    dE = jnp.roll(J, -1, 1) - J
    g2 = (dN**2 + dS**2 + dW**2 + dE**2) / jnp.maximum(J * J, 1e-9)
    c = 1.0 / (1.0 + g2)
    J = J + 0.25 * lam * (c * (dN + dS + dW + dE))
    return stencil5(J, 0.02, interpret=interpret)


def run_srad(policy_kind: str = "system", *, rows: int = 1024, cols: int = 1024,
             iters: int = 12, page_size: int = 64 * KB, lam: float = 0.5,
             oversub_ratio: float = 0.0, auto_migrate: bool = True,
             threshold: int = 256, hw=None, interpret: bool = True) -> AppResult:
    um, pol = make_um(policy_kind, page_size=page_size, hw=hw,
                      oversub_ratio=oversub_ratio,
                      app_peak_bytes=2 * rows * cols * 4,
                      auto_migrate=auto_migrate, threshold=threshold)

    with um.phase("alloc"):
        J_m = um.array("J", (rows, cols), jnp.float32, pol)
        c_m = um.array("c", (rows, cols), jnp.float32, pol)

    # GPU-side initialization (the paper's srad/qiskit pattern, §5.1.2):
    # data is first-touched by device kernels.
    key = jax.random.PRNGKey(7)
    with um.phase("gpu_init"):
        img = jax.random.uniform(key, (rows, cols), jnp.float32)
        J = jnp.exp(img / 255.0)
        um.launch("extract", writes=[J_m[:]], flops=2.0 * rows * cols,
                  actor=Actor.GPU)

    per_iter = []
    with um.phase("compute"):
        for it in range(iters):
            J = _srad_iter(J, lam, interpret)
            # both sweeps of one iteration go down in a single batched
            # engine step (charges identical to two sequential launches)
            t = sum(um.launch_batch(
                KernelBatch()
                .launch(f"grad{it}", reads=[J_m[:]], writes=[c_m[:]],
                        flops=12.0 * rows * cols, actor=Actor.GPU)
                .launch(f"diff{it}", reads=[J_m[:], c_m[:]], writes=[J_m[:]],
                        flops=8.0 * rows * cols, actor=Actor.GPU)))
            t += um.sync()
            tr = um.prof.traffic()
            per_iter.append({
                "iter": it, "seconds": t,
                "link_h2d": tr.link_h2d, "device_local": tr.device_local,
            })

    with um.phase("dealloc"):
        um.free_live()

    # per-iteration deltas for the Fig. 10 plot
    for i in range(len(per_iter) - 1, 0, -1):
        per_iter[i]["link_h2d"] -= per_iter[i - 1]["link_h2d"]
        per_iter[i]["device_local"] -= per_iter[i - 1]["device_local"]
    return finish(um, "srad", policy_kind, page_size, float(jnp.mean(J)),
                  per_iter=per_iter, iters=iters)


SPEC = AppSpec(
    name="srad", run=run_srad, init_actor="gpu",
    sizes={"fig3": dict(rows=512, cols=512, iters=12),
           "fig11": dict(rows=512, cols=512, iters=8),
           "small": dict(rows=256, cols=256, iters=8)})
