"""Quantum Volume statevector simulation (Qiskit-Aer style). Mixed, GPU-init.

The paper's flagship app: statevector of 8 * 2^n bytes; each QV layer applies
floor(n/2) random SU(4) gates to disjoint qubit pairs (kernels/qv_gate). The
in-memory cases reproduce Fig. 5/8/9 (page-size x policy); n beyond device
capacity is the natural-oversubscription case of Fig. 12/13, where explicit
chunk prefetching rescues managed memory.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.common import KB, MB, AppResult, finish, make_um
from repro.core import Actor
from repro.kernels.qv_gate import apply_two_qubit_gate


def _random_su4(rng: np.random.Generator) -> jnp.ndarray:
    z = rng.normal(size=(4, 4)) + 1j * rng.normal(size=(4, 4))
    q, r = np.linalg.qr(z)
    q = q * (np.diagonal(r) / np.abs(np.diagonal(r)))
    return jnp.asarray(q, jnp.complex64)


def run_qsim(policy_kind: str = "system", *, n_qubits: int = 16,
             depth: Optional[int] = None, page_size: int = 64 * KB,
             oversub_ratio: float = 0.0, use_prefetch: bool = False,
             auto_migrate: bool = True, seed: int = 0,
             interpret: bool = True) -> AppResult:
    depth = depth if depth is not None else max(2, n_qubits // 4)
    nbytes = 8 * (1 << n_qubits)
    um, pol = make_um(policy_kind, page_size=page_size, oversub_ratio=oversub_ratio,
                      app_peak_bytes=nbytes, auto_migrate=auto_migrate)

    with um.phase("alloc"):
        sv = um.alloc("statevector", nbytes, pol)

    # GPU-side init: the simulator zeroes the statevector on device (|0...0>)
    with um.phase("gpu_init"):
        state = jnp.zeros((1 << n_qubits,), jnp.complex64).at[0].set(1.0)
        um.kernel(writes=[(sv, 0, nbytes)], actor=Actor.GPU, name="zero_state")
        um.sync()

    rng = np.random.default_rng(seed)
    with um.phase("compute"):
        for layer in range(depth):
            perm = rng.permutation(n_qubits)
            for g in range(n_qubits // 2):
                q1, q2 = int(perm[2 * g]), int(perm[2 * g + 1])
                gate = _random_su4(rng)
                state = apply_two_qubit_gate(state, gate, q1, q2, n_qubits,
                                             interpret=interpret)
                if use_prefetch:
                    # cudaMemPrefetchAsync chunking (Fig. 12): stream chunks
                    # device-side ahead of each partial gate sweep, so reads
                    # come from HBM instead of thrash-mode remote access
                    chunk = min(nbytes, 64 * MB)
                    for lo in range(0, nbytes, chunk):
                        hi = min(lo + chunk, nbytes)
                        um.prefetch(sv, lo, hi, overlap=True)
                        um.kernel(reads=[(sv, lo, hi)], writes=[(sv, lo, hi)],
                                  flops=32.0 * (hi - lo) / 16, actor=Actor.GPU,
                                  name=f"gate_l{layer}_{q1}_{q2}_c{lo}")
                else:
                    um.kernel(reads=[(sv, 0, nbytes)], writes=[(sv, 0, nbytes)],
                              flops=32.0 * (1 << n_qubits), actor=Actor.GPU,
                              name=f"gate_l{layer}_{q1}_{q2}")
            um.sync()

    with um.phase("dealloc"):
        um.free(sv)

    norm = float(jnp.abs(jnp.vdot(state, state)))
    return finish(um, "qsim", policy_kind, page_size, norm,
                  n_qubits=n_qubits, depth=depth, prefetch=use_prefetch)
