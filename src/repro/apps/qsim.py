"""Quantum Volume statevector simulation (Qiskit-Aer style). Mixed, GPU-init.

The paper's flagship app: statevector of 8 * 2^n bytes; each QV layer applies
floor(n/2) random SU(4) gates to disjoint qubit pairs (kernels/qv_gate). The
in-memory cases reproduce Fig. 5/8/9 (page-size x policy); n beyond device
capacity is the natural-oversubscription case of Fig. 12/13, where explicit
chunk prefetching rescues managed memory.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.common import KB, MB, AppResult, AppSpec, finish, make_um
from repro.core import Actor, KernelBatch
from repro.kernels.qv_gate import apply_two_qubit_gate


def _random_su4(rng: np.random.Generator) -> jnp.ndarray:
    z = rng.normal(size=(4, 4)) + 1j * rng.normal(size=(4, 4))
    q, r = np.linalg.qr(z)
    q = q * (np.diagonal(r) / np.abs(np.diagonal(r)))
    return jnp.asarray(q, jnp.complex64)


def run_qsim(policy_kind: str = "system", *, n_qubits: int = 16,
             depth: Optional[int] = None, page_size: int = 64 * KB,
             oversub_ratio: float = 0.0, use_prefetch: bool = False,
             auto_migrate: bool = True, seed: int = 0,
             hw=None, interpret: bool = True) -> AppResult:
    depth = depth if depth is not None else max(2, n_qubits // 4)
    n_amps = 1 << n_qubits  # statevector amplitudes, 8 B each (complex64)
    um, pol = make_um(policy_kind, page_size=page_size, hw=hw,
                      oversub_ratio=oversub_ratio,
                      app_peak_bytes=8 * n_amps, auto_migrate=auto_migrate)

    with um.phase("alloc"):
        sv = um.array("statevector", (n_amps,), jnp.complex64, pol)

    # GPU-side init: the simulator zeroes the statevector on device (|0...0>)
    with um.phase("gpu_init"):
        state = jnp.zeros((n_amps,), jnp.complex64).at[0].set(1.0)
        um.launch("zero_state", writes=[sv[:]], actor=Actor.GPU)
        um.sync()

    rng = np.random.default_rng(seed)
    with um.phase("compute"):
        for layer in range(depth):
            perm = rng.permutation(n_qubits)
            batch = KernelBatch()
            for g in range(n_qubits // 2):
                q1, q2 = int(perm[2 * g]), int(perm[2 * g + 1])
                gate = _random_su4(rng)
                state = apply_two_qubit_gate(state, gate, q1, q2, n_qubits,
                                             interpret=interpret)
                if use_prefetch:
                    # cudaMemPrefetchAsync chunking (Fig. 12): stream chunks
                    # device-side ahead of each partial gate sweep, so reads
                    # come from HBM instead of thrash-mode remote access
                    chunk = min(n_amps, 64 * MB // sv.itemsize)
                    for lo in range(0, n_amps, chunk):
                        band = sv[lo:lo + chunk]
                        um.prefetch(band, overlap=True)
                        um.launch(f"gate_l{layer}_{q1}_{q2}_c{lo * sv.itemsize}",
                                  reads=[band], writes=[band],
                                  flops=32.0 * band.nbytes / 16, actor=Actor.GPU)
                else:
                    # gates of one layer act on disjoint qubit pairs: defer
                    # them into one batched engine step per layer
                    batch.launch(f"gate_l{layer}_{q1}_{q2}",
                                 reads=[sv[:]], writes=[sv[:]],
                                 flops=32.0 * n_amps, actor=Actor.GPU)
            if len(batch):
                um.launch_batch(batch)
            um.sync()

    with um.phase("dealloc"):
        um.free_live()

    norm = float(jnp.abs(jnp.vdot(state, state)))
    return finish(um, "qsim", policy_kind, page_size, norm,
                  n_qubits=n_qubits, depth=depth, prefetch=use_prefetch)


SPEC = AppSpec(
    name="qiskit", run=run_qsim, init_actor="gpu",
    sizes={"fig3": dict(n_qubits=16, depth=3),
           "fig11": dict(n_qubits=16, depth=2),
           "small": dict(n_qubits=12, depth=3)})
