"""The paper's six applications (Table 2), each in explicit/managed/system
versions behind one buffer-centric code path.

``APPS`` is the AppSpec registry — the single source of truth for the
uniform runners and the canonical per-figure size presets that
benchmarks/fig3_overview.py, fig11_oversub.py, fig67_pagesize.py,
scripts/check_parity.py and tests/test_apps.py consume. ``run_app`` is the
uniform entry point; ``APP_RUNNERS`` is the legacy name->runner mapping.
"""
from repro.apps import bfs as _bfs
from repro.apps import hotspot as _hotspot
from repro.apps import needle as _needle
from repro.apps import pathfinder as _pathfinder
from repro.apps import qsim as _qsim
from repro.apps import srad as _srad
from repro.apps.bfs import run_bfs  # noqa: F401
from repro.apps.common import AppResult, AppSpec, charge_snapshot  # noqa: F401
from repro.apps.hotspot import run_hotspot  # noqa: F401
from repro.apps.needle import run_needle  # noqa: F401
from repro.apps.pathfinder import run_pathfinder  # noqa: F401
from repro.apps.qsim import run_qsim  # noqa: F401
from repro.apps.srad import run_srad  # noqa: F401

# canonical (paper Table 2) ordering — benchmarks emit rows in this order
APPS = {spec.name: spec for spec in (
    _qsim.SPEC, _needle.SPEC, _pathfinder.SPEC,
    _bfs.SPEC, _hotspot.SPEC, _srad.SPEC)}

APP_RUNNERS = {name: spec.run for name, spec in APPS.items()}


def run_app(name: str, policy_kind: str = "system", *,
            preset: str = None, **overrides) -> AppResult:
    """Uniform runner: look up the app's spec, apply a named size preset
    ("fig3" | "fig11" | "small") if given, then any keyword overrides."""
    spec = APPS[name]
    kw = dict(spec.sizes[preset]) if preset is not None else {}
    kw.update(overrides)
    return spec.run(policy_kind, **kw)
