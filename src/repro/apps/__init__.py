"""The paper's six applications (Table 2), each in explicit/managed/system versions."""
from repro.apps.bfs import run_bfs  # noqa: F401
from repro.apps.common import AppResult  # noqa: F401
from repro.apps.hotspot import run_hotspot  # noqa: F401
from repro.apps.needle import run_needle  # noqa: F401
from repro.apps.pathfinder import run_pathfinder  # noqa: F401
from repro.apps.qsim import run_qsim  # noqa: F401
from repro.apps.srad import run_srad  # noqa: F401

APP_RUNNERS = {
    "qiskit": run_qsim,
    "needle": run_needle,
    "pathfinder": run_pathfinder,
    "bfs": run_bfs,
    "hotspot": run_hotspot,
    "srad": run_srad,
}
