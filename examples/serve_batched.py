"""Serve a small model with batched requests through the paged-KV engine,
with the pool governed by the unified-memory runtime (the paper's system
policy applied to serving).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import TPU_V5E, UnifiedMemory
from repro.models import init_params
from repro.serve import ServeEngine


def main():
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    um = UnifiedMemory(hw=TPU_V5E)
    eng = ServeEngine(cfg, params, max_seqs=4, max_len=128, page_size=16, um=um)

    rng = np.random.default_rng(0)
    for i in range(6):  # 6 requests > 4 slots: continuous batching admits
        plen = int(rng.integers(8, 40))
        rid = eng.add_request(rng.integers(2, cfg.vocab_size, plen), 12)
        print(f"request {rid}: prompt_len={plen}")
    t0 = time.perf_counter()
    out = eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    print(f"\ngenerated {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s on CPU)")
    for rid, t in sorted(out.items()):
        print(f"  req {rid}: {t}")
    tr = um.report()["traffic_total"]
    print(f"\numem (modeled v5e): kv pool h2d={tr['link_h2d']/2**20:.1f} MiB, "
          f"gpu-first-touch PTEs={tr['pte_inits_gpu']}, "
          f"notifications={tr['notifications']}")


if __name__ == "__main__":
    main()
