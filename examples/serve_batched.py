"""Serve a small model with batched requests through the paged-KV engine,
with the pool governed by the unified-memory runtime (the paper's system
policy applied to serving).

The engine is oversubscription-aware: the run below gives it (a) a KV page
pool smaller than the workload's total demand, so the scheduler preempts
the youngest sequences (KV demoted host-side) and resumes them as pages
free up, and (b) a modeled device capacity smaller than the pool, so part
of the KV stays host-resident and decode reads it remotely over the
interconnect — the paper's graceful-oversubscription behavior (§7)
instead of an OOM.

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import TPU_V5E, UnifiedMemory
from repro.models import init_params
from repro.models.cache import kv_head_layout
from repro.serve import PagedKVCache, ServeEngine


def main():
    cfg = get_config("yi-6b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    # 6 requests > 4 slots (continuous batching), a 10-page pool that cannot
    # hold every admitted sequence (preemption), and a device that only fits
    # 2/3 of the pool (remote KV reads under 1.5x oversubscription)
    num_pages, page_size = 10, 16
    page_bytes = PagedKVCache.page_bytes_for(cfg, kv_head_layout(cfg, 1),
                                             page_size)
    hw = dataclasses.replace(
        TPU_V5E, device_capacity=int(num_pages * page_bytes / 1.5))
    um = UnifiedMemory(hw=hw)
    eng = ServeEngine(cfg, params, max_seqs=4, max_len=128,
                      page_size=page_size, num_pages=num_pages, um=um,
                      prefill_chunk=32)

    rng = np.random.default_rng(0)
    for i in range(6):
        plen = int(rng.integers(8, 40))
        rid = eng.add_request(rng.integers(2, cfg.vocab_size, plen), 16)
        print(f"request {rid}: prompt_len={plen}")
    t0 = time.perf_counter()
    out = eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    print(f"\ngenerated {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s on CPU)")
    for rid, t in sorted(out.items()):
        print(f"  req {rid}: {t}")
    s = eng.stats
    print(f"\nscheduler: admitted={s.admitted} preempted={s.preempted} "
          f"resumed={s.resumed} prefill_chunks={s.prefill_chunks} "
          f"decode_batches={s.decode_batches}")
    rep = um.report()
    tr = rep["traffic_total"]
    print(f"umem (modeled v5e, pool 1.5x HBM): "
          f"remote_share={rep['remote_access_share']:.3f}, "
          f"kv h2d={tr['link_h2d']/2**20:.2f} MiB, "
          f"gpu-first-touch PTEs={tr['pte_inits_gpu']}, "
          f"notifications={tr['notifications']}")


if __name__ == "__main__":
    main()
