"""Trace capture/replay: record one app, re-charge it under other backends.

Records a small srad run under the system policy, then

  1. replays the trace with no overrides and checks the charges are
     bit-identical to the recorded run (the round-trip guarantee), and
  2. replays the same trace with ``--policy`` overrides (default:
     mi300a_unified) and diffs the re-charged totals against a native run
     of the app under that backend — trace-replay "what-if" without
     re-running the application math.

Exits non-zero on any charge mismatch, so CI runs it as the replay smoke.

    PYTHONPATH=src python examples/trace_replay.py [--trace PATH]
        [--policy KIND ...]
"""
import argparse
import sys

from repro.apps import APPS, charge_snapshot
from repro.core.trace import record_app, replay


def fingerprint(um) -> dict:
    """charge_snapshot's sections, computed from a replayed runtime."""
    rep = um.report()
    return {
        "phase_times": {k: float(v).hex()
                        for k, v in sorted(um.prof.phase_times.items())},
        "traffic_total": {k: int(v)
                          for k, v in sorted(rep["traffic_total"].items())},
        "traffic_phases": {ph: {k: int(v) for k, v in sorted(tr.items())}
                           for ph, tr in sorted(rep["traffic"].items())},
    }


def diff(got: dict, want: dict, label: str) -> int:
    bad = 0
    for section in want:
        if got[section] != want[section]:
            print(f"MISMATCH {label}: {section}")
            print(f"  replayed: {got[section]}")
            print(f"  native:   {want[section]}")
            bad += 1
    if not bad:
        print(f"OK {label}: charges bit-identical")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="/tmp/srad_fig3.trace.gz")
    ap.add_argument("--policy", nargs="*", default=["mi300a_unified"],
                    help="override backends to re-charge the trace under")
    args = ap.parse_args(argv)

    kw = dict(APPS["srad"].sizes["small"])
    print(f"recording srad/system {kw} -> {args.trace}")
    native = record_app("srad", "system", args.trace, **kw)

    failures = diff(fingerprint(replay(args.trace)), charge_snapshot(native),
                    "replay (no override) vs recorded run")
    for kind in args.policy:
        want = charge_snapshot(APPS["srad"].run(kind, **kw))
        got = fingerprint(replay(args.trace, policy=kind))
        failures += diff(got, want, f"replay --policy {kind} vs native {kind}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
