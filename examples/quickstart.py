"""Quickstart: one forward, one train step, one decode — any assigned arch.

    PYTHONPATH=src python examples/quickstart.py [arch]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import RunPolicy, decode_step, forward, init_params, prefill
from repro.models.cache import init_cache
from repro.train import TrainerConfig, make_train_state, make_train_step


def main(arch: str = "yi-6b"):
    print(f"archs available: {list_archs()}")
    cfg = get_config(arch).reduced()  # same family, CPU-sized
    print(f"\n== {arch} (reduced: {cfg.num_layers}L d={cfg.d_model}) ==")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    pol = RunPolicy()

    B, S = 2, 32
    if cfg.input_kind == "embeddings":
        tokens = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    else:
        tokens = jax.random.randint(key, (B, S), 2, cfg.vocab_size)
    logits, _ = jax.jit(lambda p, t: forward(cfg, p, t, pol))(params, tokens)
    print("forward:", logits.shape, "->", float(logits.mean()))

    # one train step
    state = make_train_state(cfg, params)
    tc = TrainerConfig(grad_accum=2, total_steps=10, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, pol, tc))
    labels = jax.random.randint(key, (B, S), 2, cfg.vocab_size)
    state, metrics = step(state, {"tokens": tokens, "labels": labels})
    print(f"train step: loss={float(metrics['loss']):.4f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    # prefill + decode three tokens
    lg, _ = jax.jit(lambda p, t: prefill(cfg, p, t, pol))(params, tokens)
    cache = init_cache(cfg, B, S + 8, tp=1, dtype=jnp.float32)
    dec = jax.jit(lambda p, t, ps, c: decode_step(cfg, p, t, ps, c, pol))
    tok = tokens[:, :1] if cfg.input_kind != "embeddings" else tokens[:, :1, :]
    for i in range(3):
        lg, cache = dec(params, tok, jnp.full((B,), i, jnp.int32), cache)
        nxt = jnp.argmax(lg[:, 0], -1)
        print(f"decode step {i}: next={nxt.tolist()}")
        if cfg.input_kind != "embeddings":
            tok = nxt[:, None]


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "yi-6b")
