"""The buffer-centric UnifiedMemory front-end in ~60 lines: one typed code
path, three memory-management policies (docs/memspace.md).

    PYTHONPATH=src python examples/buffer_api.py
"""
import jax.numpy as jnp

from repro.core import (
    Actor,
    UnifiedMemory,
    explicit_policy,
    managed_policy,
    system_policy,
)

KB, MB = 1024, 1024 * 1024


def stream_app(pol, page_size=64 * KB):
    """A toy CPU-init streaming app — note: no policy branches, no byte math."""
    um = UnifiedMemory(staging_page_size=page_size)
    data = um.from_host("data", (4096, 256), jnp.float32, pol)  # 4 MiB
    acc = um.array("acc", (256,), jnp.float32, pol)

    with um.phase("cpu_init"):
        um.launch("init", writes=[data[:]], actor=Actor.CPU)

    with um.staged(h2d=[data], d2h=[acc]):
        with um.phase("compute"):
            for r0 in range(0, 4096, 1024):
                um.launch(f"rows{r0}",
                          reads=[data.rows(r0, r0 + 1024)],  # row band -> extent
                          writes=[acc[:]],
                          flops=2.0 * 1024 * 256, actor=Actor.GPU)
                um.sync()

    with um.phase("dealloc"):
        um.free_live()
    return um


def main():
    print(f"{'policy':9s} {'total ms':>9s} {'h2d MiB':>8s} {'remote MiB':>10s}")
    for name, pol in [("explicit", explicit_policy()),
                      ("managed", managed_policy(64 * KB)),
                      ("system", system_policy(64 * KB))]:
        um = stream_app(pol)
        rep = um.report()
        tr = rep["traffic_total"]
        print(f"{name:9s} {sum(rep['phase_times_s'].values())*1e3:9.3f} "
              f"{tr['link_h2d']/MB:8.2f} {tr['remote_h2d']/MB:10.2f}")

    # views resolve to exact byte extents — the same math the raw API used
    um = UnifiedMemory()
    buf = um.array("m", (128, 64), jnp.float32, system_policy(4 * KB))
    band = buf.rows(3, 17)
    print(f"\n{band!r} -> bytes [{band.lo}, {band.hi}), "
          f"pages {band.page_extent()}")


if __name__ == "__main__":
    main()
