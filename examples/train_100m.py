"""End-to-end driver: train a ~100M-param llama-style LM for a few hundred
steps on the synthetic pipeline, with checkpointing and failure recovery.

    PYTHONPATH=src python examples/train_100m.py [--steps 200] [--fail-at 60]
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataLoader, SyntheticLM
from repro.models import RunPolicy, init_params
from repro.runtime import FailureInjector
from repro.train import Trainer, TrainerConfig, make_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ~100M params: 12L d768 swiglu, vocab 8192 (llama/yi family)
    cfg = dataclasses.replace(
        get_config("yi-6b"),
        name="yi-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=8192)
    print(f"params: {cfg.param_count()/1e6:.1f}M")

    params = init_params(cfg, jax.random.PRNGKey(0))
    state = make_train_state(cfg, params)
    tc = TrainerConfig(lr=6e-4, grad_accum=1, total_steps=args.steps,
                       warmup_steps=max(2, args.steps // 20))
    step = jax.jit(make_train_step(cfg, RunPolicy(remat=False), tc))
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                     global_batch=args.batch, seed=0)
    loader = DataLoader(ds)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="ckpt100m-")
    cm = CheckpointManager(ckpt_dir, keep_last=2)
    inj = FailureInjector.at(args.fail_at) if args.fail_at else None
    tr = Trainer(cfg, state, step, loader, ckpt=cm, ckpt_every=25, injector=inj)
    out = tr.run(args.steps)
    loader.close()
    losses = [h["loss"] for h in out["history"]]
    k = max(1, len(losses) // 10)
    print(f"steps={len(losses)} restarts={out['restarts']} "
          f"loss {np.mean(losses[:k]):.4f} -> {np.mean(losses[-k:]):.4f} "
          f"(ckpt: {ckpt_dir})")
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not improve"


if __name__ == "__main__":
    main()
