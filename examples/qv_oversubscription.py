"""The paper's flagship scenario: Quantum Volume statevector simulation under
memory oversubscription, across all three memory-management policies
(+ the Fig. 12 prefetch rescue), with modeled Grace Hopper timings.

    PYTHONPATH=src python examples/qv_oversubscription.py [n_qubits]
"""
import sys

from repro.apps import run_qsim
from repro.core import OutOfDeviceMemory


def main(n: int = 16):
    print(f"== QV simulation, {n} qubits ({8*(1<<n)/2**20:.1f} MiB statevector), "
          f"depth {max(2, n//4)} ==")
    print("\n-- in-memory --")
    for pol in ("explicit", "managed", "system"):
        r = run_qsim(pol, n_qubits=n)
        pt = r.phase_times
        print(f"  {pol:9s} total={r.total*1e3:8.3f} ms  "
              f"init={pt.get('gpu_init',0)*1e3:7.3f}  compute={pt.get('compute',0)*1e3:7.3f}")

    print("\n-- 1.3x oversubscribed (paper's 34-qubit analogue) --")
    for pol, kw in [("explicit", {}), ("managed", {}), ("system", {}),
                    ("managed+prefetch", {"use_prefetch": True})]:
        base = pol.split("+")[0]
        try:
            r = run_qsim(base, n_qubits=n, oversub_ratio=1.3, **kw)
            tr = r.report["traffic_total"]
            print(f"  {pol:17s} total={r.total*1e3:8.3f} ms  "
                  f"c2c={tr['link_h2d']/2**20:7.1f} MiB  "
                  f"migrated={tr['migrated_in']/2**20:7.1f} MiB")
        except OutOfDeviceMemory as e:
            print(f"  {pol:17s} OOM (cudaMalloc cannot oversubscribe): {e}")

    print("\npage-size sensitivity (system memory, §5.2):")
    for ps in (4 * 1024, 64 * 1024):
        r = run_qsim("system", n_qubits=n, page_size=ps)
        print(f"  {ps//1024:3d} KiB pages: init={r.phase_times.get('gpu_init',0)*1e3:8.3f} ms "
              f"total={r.total*1e3:8.3f} ms")


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 16)
